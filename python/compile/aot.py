"""AOT compile path: lower the L2 assignment graphs to HLO text artifacts.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via ``make artifacts``:

    cd python && python -m compile.aot --out-dir ../artifacts

Each configuration (kind, b, k, M, d) becomes ``artifacts/<name>.hlo.txt``
plus an entry in ``artifacts/manifest.json`` that the Rust runtime uses to
pick an executable for a run configuration (exact b/k/d match, M ≥ the
window capacity — padded slots carry zero weight so a larger M is sound).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (b, k, m, d) grid for the Gaussian assign-step graphs. Chosen to cover the
# quickstart example, the backend cross-check tests, and the paper-figure
# proxy runs (synth_pendigits d=16, synth_har d=64, synth_mnist d=128,
# synth_letters d=16 at k=26). M must be ≥ τ + b + 1 for the τ grid
# {50,100,200,300}; we round up generously so one artifact serves many τ.
GAUSSIAN_CONFIGS = [
    # (b, k, m, d)
    (64, 4, 192, 8),      # integration tests
    (256, 5, 640, 8),     # quickstart (blobs)
    (256, 10, 640, 16),   # synth_pendigits, small b
    (1024, 10, 1408, 16), # synth_pendigits, paper b=1024
    (512, 26, 896, 16),   # synth_letters
    (256, 6, 640, 64),    # synth_har
    (1024, 6, 1408, 64),  # synth_har, b=1024
    (256, 10, 640, 128),  # synth_mnist
    (1024, 10, 1408, 128),# synth_mnist, b=1024
]

# (b, k, m) grid for the precomputed-kernel graphs (graph kernels).
PRECOMPUTED_CONFIGS = [
    (64, 4, 192),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_gaussian(b: int, k: int, m: int, d: int) -> str:
    spec = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.float32)  # noqa: E731
    lowered = jax.jit(model.assign_step).lower(
        spec(b, d), spec(k, m, d), spec(k, m), jax.ShapeDtypeStruct((), jnp.float32)
    )
    return to_hlo_text(lowered)


def lower_precomputed(b: int, k: int, m: int) -> str:
    spec = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.float32)  # noqa: E731
    lowered = jax.jit(model.assign_step_precomputed).lower(
        spec(b), spec(b, k, m), spec(k, m, m), spec(k, m)
    )
    return to_hlo_text(lowered)


def build(out_dir: str, quick: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []
    gaussian = GAUSSIAN_CONFIGS[:2] if quick else GAUSSIAN_CONFIGS
    for b, k, m, d in gaussian:
        name = f"assign_gaussian_b{b}_k{k}_m{m}_d{d}"
        path = os.path.join(out_dir, name + ".hlo.txt")
        text = lower_gaussian(b, k, m, d)
        with open(path, "w") as f:
            f.write(text)
        artifacts.append(
            {"name": name, "file": name + ".hlo.txt", "kind": "assign_gaussian",
             "b": b, "k": k, "m": m, "d": d}
        )
        print(f"[aot] {name}: {len(text)} chars")
    for b, k, m in PRECOMPUTED_CONFIGS:
        name = f"assign_precomputed_b{b}_k{k}_m{m}"
        path = os.path.join(out_dir, name + ".hlo.txt")
        text = lower_precomputed(b, k, m)
        with open(path, "w") as f:
            f.write(text)
        artifacts.append(
            {"name": name, "file": name + ".hlo.txt", "kind": "assign_precomputed",
             "b": b, "k": k, "m": m}
        )
        print(f"[aot] {name}: {len(text)} chars")
    manifest = {"version": 1, "artifacts": artifacts}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote manifest with {len(artifacts)} artifacts to {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only the first two configs (CI smoke)")
    args = ap.parse_args()
    build(args.out_dir, quick=args.quick)


if __name__ == "__main__":
    main()
