"""Layer 2 — the per-iteration assignment step of Algorithm 2 as a JAX graph.

The graph computes, for a batch ``B`` and k truncated centers
``Ĉ^j = Σ_m w_jm φ(s_jm)`` (each padded to M support slots, weight 0 on
padding):

    dist[x, j] = K(x,x) − 2·Σ_m w_jm K(x, s_jm) + Σ_{m,n} w_jm w_jn K(s_jm, s_jn)

All kernel blocks go through the Layer-1 Pallas kernel
(:func:`compile.kernels.gram.gaussian_gram`) so the whole step lowers into
one fused HLO module. ``aot.py`` lowers these functions per (b, k, M, d)
configuration; the Rust runtime (``rust/src/runtime``) executes them on the
hot path. Python never runs at serving time.

Two variants:

* :func:`assign_step` — feature kernels (Gaussian): inputs are raw
  features; the graph evaluates the kernel itself. This is the fast path.
* :func:`assign_step_precomputed` — graph kernels (knn/heat): inputs are
  pre-gathered kernel values; the graph does the weighted reductions.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.gram import gaussian_gram


def assign_step(batch, support, weights, inv_kappa):
    """Distances of batch points to truncated centers (Gaussian kernel).

    Args:
      batch: (b, d) f32.
      support: (k, M, d) f32 — per-center support points, zero-padded.
      weights: (k, M) f32 — coefficients, 0 on padded slots.
      inv_kappa: () f32 — 1/κ.

    Returns:
      dist: (b, k) f32, clamped at 0.
    """
    k, m, d = support.shape
    b = batch.shape[0]
    # Cross terms via ONE flattened gram block (better tiling than k small
    # ones): (b, k·M) → (b, k, M) → weighted reduce.
    flat_support = support.reshape(k * m, d)
    kxs = gaussian_gram(batch, flat_support, inv_kappa).reshape(b, k, m)
    cross = jnp.einsum("bkm,km->bk", kxs, weights)
    # Center self-products: per-center (M × M) gram. Static python loop —
    # unrolled into the same HLO module at trace time.
    ccs = []
    for j in range(k):
        kss = gaussian_gram(support[j], support[j], inv_kappa)
        ccs.append(weights[j] @ kss @ weights[j])
    cc = jnp.stack(ccs)
    # Gaussian kernel ⇒ K(x, x) = 1.
    return jnp.maximum(1.0 - 2.0 * cross + cc[None, :], 0.0)


def assign_step_precomputed(kxx, kxs, kss, weights):
    """Distances when kernel values are pre-gathered (graph kernels).

    Args:
      kxx: (b,) f32 — K(x,x) per batch point.
      kxs: (b, k, M) f32 — batch × support kernel values.
      kss: (k, M, M) f32 — support × support kernel values per center.
      weights: (k, M) f32.

    Returns:
      dist: (b, k) f32, clamped at 0.
    """
    cross = jnp.einsum("bkm,km->bk", kxs, weights)
    cc = jnp.einsum("km,kmn,kn->k", weights, kss, weights)
    return jnp.maximum(kxx[:, None] - 2.0 * cross + cc[None, :], 0.0)
