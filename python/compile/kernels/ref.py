"""Pure-jnp oracles for the Pallas kernel and the L2 assignment step.

These are the correctness references: no Pallas, no tiling, just the
textbook formulas. Every Pallas/model output is compared against them in
``python/tests``.
"""

from __future__ import annotations

import jax.numpy as jnp


def gaussian_gram_ref(x, y, inv_kappa):
    """Reference ``K[i,j] = exp(−‖x_i−y_j‖²·inv_kappa)``, O(b·m·d) direct."""
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    diff = x[:, None, :] - y[None, :, :]          # (b, m, d)
    d2 = jnp.sum(diff * diff, axis=-1)            # (b, m)
    return jnp.exp(-d2 * jnp.float32(inv_kappa))


def assign_step_ref(batch, support, weights, inv_kappa):
    """Reference distances for Algorithm 2's assignment step.

    Args:
      batch: (b, d) batch features.
      support: (k, M, d) per-center support points (zero-padded).
      weights: (k, M) per-support-point coefficients (0 on padding).
      inv_kappa: scalar 1/κ.

    Returns:
      dist: (b, k) — ``Δ(x, Ĉ^j) = 1 − 2·Σ_m w_jm K(x, s_jm) + ⟨Ĉ^j, Ĉ^j⟩``
        (Gaussian kernel ⇒ K(x,x) = 1), clamped at 0.
    """
    k = support.shape[0]
    dists = []
    for j in range(k):
        kxs = gaussian_gram_ref(batch, support[j], inv_kappa)     # (b, M)
        cross = kxs @ weights[j]                                  # (b,)
        kss = gaussian_gram_ref(support[j], support[j], inv_kappa)
        cc = weights[j] @ kss @ weights[j]
        dists.append(1.0 - 2.0 * cross + cc)
    return jnp.maximum(jnp.stack(dists, axis=1), 0.0)


def assign_step_precomputed_ref(kxx, kxs, kss, weights):
    """Reference for the precomputed-kernel variant.

    Args:
      kxx: (b,) self kernel values of batch points.
      kxs: (b, k, M) kernel values batch × per-center support.
      kss: (k, M, M) kernel values support × support per center.
      weights: (k, M) coefficients.

    Returns:
      dist: (b, k).
    """
    cross = jnp.einsum("bkm,km->bk", kxs, weights)
    cc = jnp.einsum("km,kmn,kn->k", weights, kss, weights)
    return jnp.maximum(kxx[:, None] - 2.0 * cross + cc[None, :], 0.0)
