"""Layer 1 — Pallas gram kernel.

The compute hot-spot of truncated mini-batch kernel k-means (Algorithm 2)
is the kernel block ``K(B, S)`` between a batch and the sliding-window
support points. This module expresses it as a Pallas kernel tiled for TPU:

* the (b × m) output is split into (TILE_B × TILE_M) tiles — 128×128 by
  default, the MXU-native shape;
* each tile computes squared distances via the factorization
  ``‖x−y‖² = ‖x‖² + ‖y‖² − 2·x·yᵀ`` so the inner loop is a single
  (TILE_B × d) @ (d × TILE_M) matmul (MXU) followed by a VPU `exp`;
* the feature dimension stays resident per tile; VMEM footprint is
  ``(TILE_B·d + TILE_M·d + TILE_B·TILE_M)·4`` bytes — ~1.2 MiB at d=1024,
  far below the ~16 MiB VMEM budget, leaving room for double buffering.

On this CPU-only image the kernel runs with ``interpret=True`` (the CPU
PJRT client cannot execute Mosaic custom-calls); correctness is checked
against the pure-jnp oracle in ``ref.py``, and the same graph is what
``aot.py`` lowers into the HLO artifacts the Rust runtime executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile shape.
TILE_B = 128
TILE_M = 128


def _gaussian_tile_kernel(x_ref, y_ref, inv_kappa_ref, o_ref):
    """One (TILE_B × TILE_M) tile: K = exp(−‖x−y‖²·inv_kappa)."""
    x = x_ref[...]  # (TILE_B, d)
    y = y_ref[...]  # (TILE_M, d)
    inv_kappa = inv_kappa_ref[0, 0]
    xx = jnp.sum(x * x, axis=1, keepdims=True)          # (TILE_B, 1)
    yy = jnp.sum(y * y, axis=1, keepdims=True).T        # (1, TILE_M)
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(xx + yy - 2.0 * xy, 0.0)
    o_ref[...] = jnp.exp(-d2 * inv_kappa)


def _pad_to(a, rows, cols=None):
    """Zero-pad a 2-d array up to (rows, cols)."""
    r, c = a.shape
    cols = c if cols is None else cols
    if r == rows and c == cols:
        return a
    return jnp.pad(a, ((0, rows - r), (0, cols - c)))


def _ceil_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@functools.partial(jax.jit, static_argnames=("tile_b", "tile_m"))
def gaussian_gram(x, y, inv_kappa, *, tile_b: int = TILE_B, tile_m: int = TILE_M):
    """``K[i, j] = exp(−‖x_i − y_j‖² · inv_kappa)`` via the Pallas kernel.

    Args:
      x: (b, d) f32 batch features.
      y: (m, d) f32 support features.
      inv_kappa: scalar (or ()-shaped array) — ``1/κ`` of the Gaussian
        kernel ``exp(−‖x−y‖²/κ)``.

    Returns:
      (b, m) f32 kernel block.
    """
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    b, d = x.shape
    m, d2 = y.shape
    assert d == d2, f"feature dims differ: {d} vs {d2}"
    bp, mp = _ceil_to(max(b, 1), tile_b), _ceil_to(max(m, 1), tile_m)
    # Zero rows are harmless: padded outputs are sliced away below.
    xp = _pad_to(x, bp)
    yp = _pad_to(y, mp)
    ik = jnp.reshape(jnp.asarray(inv_kappa, jnp.float32), (1, 1))

    out = pl.pallas_call(
        _gaussian_tile_kernel,
        grid=(bp // tile_b, mp // tile_m),
        in_specs=[
            pl.BlockSpec((tile_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_m, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, tile_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, yp, ik)
    return out[:b, :m]


def vmem_bytes(tile_b: int, tile_m: int, d: int) -> int:
    """Estimated VMEM footprint of one tile invocation (f32)."""
    return 4 * (tile_b * d + tile_m * d + tile_b * tile_m + 1)
