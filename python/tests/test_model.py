"""L2 correctness: assignment-step graphs vs naive references, padding
semantics, and agreement between the feature and precomputed variants."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels.ref import (
    assign_step_precomputed_ref,
    assign_step_ref,
    gaussian_gram_ref,
)

hypothesis.settings.register_profile(
    "mbkk", deadline=None, max_examples=15,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("mbkk")


def _case(rng, b, k, m, d, pad_frac=0.3):
    batch = rng.standard_normal((b, d)).astype(np.float32)
    support = rng.standard_normal((k, m, d)).astype(np.float32)
    weights = rng.random((k, m)).astype(np.float32)
    # Zero-pad a suffix of each center's support (simulating a window
    # shorter than capacity) and renormalize the rest to sum ≤ 1.
    pad = int(m * pad_frac)
    if pad:
        support[:, m - pad:, :] = 0.0
        weights[:, m - pad:] = 0.0
    weights /= np.maximum(weights.sum(axis=1, keepdims=True), 1e-9)
    return batch, support, weights


@hypothesis.given(
    b=st.integers(1, 48),
    k=st.integers(1, 6),
    m=st.integers(1, 64),
    d=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_assign_step_matches_reference(b, k, m, d, seed):
    rng = np.random.default_rng(seed)
    batch, support, weights = _case(rng, b, k, m, d)
    got = model.assign_step(batch, support, weights, jnp.float32(0.7))
    want = assign_step_ref(batch, support, weights, 0.7)
    assert got.shape == (b, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_padding_slots_do_not_contribute():
    # Same window expressed at two capacities must give identical distances.
    rng = np.random.default_rng(7)
    b, k, m, d = 16, 3, 20, 8
    batch, support, weights = _case(rng, b, k, m, d, pad_frac=0.0)
    big_support = np.zeros((k, m + 13, d), np.float32)
    big_support[:, :m] = support
    big_weights = np.zeros((k, m + 13), np.float32)
    big_weights[:, :m] = weights
    small = model.assign_step(batch, support, weights, jnp.float32(0.5))
    big = model.assign_step(batch, big_support, big_weights, jnp.float32(0.5))
    np.testing.assert_allclose(np.asarray(small), np.asarray(big), atol=2e-5)


def test_distance_to_pure_point_center():
    # A center that is exactly one support point with weight 1 must give the
    # plain kernel distance 2·(1 − K(x, s)).
    rng = np.random.default_rng(8)
    b, d = 10, 5
    batch = rng.standard_normal((b, d)).astype(np.float32)
    s = rng.standard_normal((1, 1, d)).astype(np.float32)
    w = np.ones((1, 1), np.float32)
    dist = np.asarray(model.assign_step(batch, s, w, jnp.float32(1.0)))[:, 0]
    kxs = np.asarray(gaussian_gram_ref(batch, s[0], 1.0))[:, 0]
    np.testing.assert_allclose(dist, 2.0 * (1.0 - kxs), atol=2e-6)


def test_feature_and_precomputed_variants_agree():
    rng = np.random.default_rng(9)
    b, k, m, d = 12, 4, 24, 6
    batch, support, weights = _case(rng, b, k, m, d)
    inv_kappa = 0.8
    feat = model.assign_step(batch, support, weights, jnp.float32(inv_kappa))
    kxx = np.ones(b, np.float32)
    kxs = np.stack(
        [np.asarray(gaussian_gram_ref(batch, support[j], inv_kappa)) for j in range(k)],
        axis=1,
    )
    kss = np.stack(
        [np.asarray(gaussian_gram_ref(support[j], support[j], inv_kappa)) for j in range(k)]
    )
    pre = model.assign_step_precomputed(kxx, kxs, kss, weights)
    np.testing.assert_allclose(np.asarray(feat), np.asarray(pre), atol=3e-5)


@hypothesis.given(
    b=st.integers(1, 32),
    k=st.integers(1, 5),
    m=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_precomputed_matches_reference(b, k, m, seed):
    rng = np.random.default_rng(seed)
    kxx = rng.random(b).astype(np.float32)
    kxs = rng.random((b, k, m)).astype(np.float32)
    kss = rng.random((k, m, m)).astype(np.float32)
    weights = rng.random((k, m)).astype(np.float32)
    got = model.assign_step_precomputed(kxx, kxs, kss, weights)
    want = assign_step_precomputed_ref(kxx, kxs, kss, weights)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_distances_nonnegative():
    rng = np.random.default_rng(10)
    batch, support, weights = _case(rng, 30, 4, 50, 10)
    dist = np.asarray(model.assign_step(batch, support, weights, jnp.float32(2.0)))
    assert (dist >= 0).all()
