"""AOT path smoke tests: lowering produces loadable HLO text and a
well-formed manifest."""

import json
import os

from compile import aot


def test_lower_gaussian_produces_hlo_text():
    text = aot.lower_gaussian(8, 2, 16, 4)
    assert "HloModule" in text
    # jit function name survives into the module name.
    assert "assign_step" in text.splitlines()[0]
    # Tuple return convention (rust unwraps with to_tuple1).
    assert "ROOT" in text


def test_lower_precomputed_produces_hlo_text():
    text = aot.lower_precomputed(8, 2, 16)
    assert "HloModule" in text


def test_build_quick_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build(out, quick=True)
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert on_disk["version"] == 1
    arts = on_disk["artifacts"]
    # quick: two gaussian configs + the precomputed test config.
    kinds = {a["kind"] for a in arts}
    assert "assign_gaussian" in kinds and "assign_precomputed" in kinds
    for a in arts:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path), a["file"]
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head
        assert a["b"] > 0 and a["k"] > 0 and a["m"] > 0


def test_hlo_text_is_deterministic():
    a = aot.lower_gaussian(8, 2, 16, 4)
    b = aot.lower_gaussian(8, 2, 16, 4)
    assert a == b
