"""L1 correctness: Pallas gram kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-tile-multiple ragged edges) and
kappa values; explicit tests pin the identities a Gaussian gram must obey.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.gram import gaussian_gram, vmem_bytes
from compile.kernels.ref import gaussian_gram_ref

hypothesis.settings.register_profile(
    "mbkk", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("mbkk")


def _rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@hypothesis.given(
    b=st.integers(1, 200),
    m=st.integers(1, 200),
    d=st.integers(1, 40),
    kappa=st.floats(0.05, 50.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_reference_on_random_shapes(b, m, d, kappa, seed):
    rng = np.random.default_rng(seed)
    x, y = _rand(rng, b, d), _rand(rng, m, d)
    got = gaussian_gram(x, y, 1.0 / kappa, tile_b=64, tile_m=64)
    want = gaussian_gram_ref(x, y, 1.0 / kappa)
    # The MXU-friendly ‖x‖²+‖y‖²−2x·y factorization loses ~‖x‖²·ε₃₂ of the
    # squared distance to cancellation; scaled by 1/κ in the exponent that
    # bounds the kernel-value error at ≈ (xx+yy)·ε₃₂/κ ≲ 1e-4 on this domain.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


@pytest.mark.parametrize("tile", [32, 128])
@pytest.mark.parametrize(
    "b,m,d",
    [(1, 1, 1), (128, 128, 16), (129, 257, 17), (7, 300, 64), (300, 7, 3)],
)
def test_edge_shapes(b, m, d, tile):
    rng = np.random.default_rng(b * 1000 + m * 10 + d)
    x, y = _rand(rng, b, d), _rand(rng, m, d)
    got = gaussian_gram(x, y, 0.5, tile_b=tile, tile_m=tile)
    want = gaussian_gram_ref(x, y, 0.5)
    assert got.shape == (b, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)


def test_self_gram_diagonal_is_one():
    rng = np.random.default_rng(0)
    x = _rand(rng, 50, 8)
    g = np.asarray(gaussian_gram(x, x, 2.0))
    np.testing.assert_allclose(np.diag(g), 1.0, atol=1e-5)
    # Symmetric.
    np.testing.assert_allclose(g, g.T, atol=2e-6)


def test_values_in_unit_interval():
    rng = np.random.default_rng(1)
    x, y = _rand(rng, 40, 5), _rand(rng, 30, 5)
    g = np.asarray(gaussian_gram(x, y, 1.0))
    assert (g > 0).all() and (g <= 1.0 + 1e-6).all()


def test_kappa_monotonicity():
    # Larger kappa (smaller inv_kappa) ⇒ larger kernel values off-diagonal.
    rng = np.random.default_rng(2)
    x, y = _rand(rng, 10, 4), _rand(rng, 10, 4)
    wide = np.asarray(gaussian_gram(x, y, 0.1))
    narrow = np.asarray(gaussian_gram(x, y, 10.0))
    assert (wide >= narrow - 1e-7).all()


def test_identical_points_give_one():
    x = np.ones((3, 6), np.float32)
    g = np.asarray(gaussian_gram(x, x, 5.0))
    np.testing.assert_allclose(g, 1.0, atol=1e-6)


def test_float64_inputs_are_cast():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((9, 4))  # f64
    y = rng.standard_normal((11, 4))
    g = gaussian_gram(x, y, 1.0)
    assert g.dtype == jnp.float32
    assert g.shape == (9, 11)


def test_vmem_budget_for_paper_shapes():
    # The §Hardware-Adaptation claim: default tiles fit VMEM with room for
    # double buffering at every feature width the proxies use.
    for d in (8, 16, 64, 128, 784):
        assert vmem_bytes(128, 128, d) < 2 * 1024 * 1024, d
