//! The paper's headline claim (§1, Figure 1 timing bars): mini-batch kernel
//! k-means achieves a **10–100× speedup** over the full-batch algorithm
//! with minimal quality loss.
//!
//! Runs full-batch, Algorithm 1, and Algorithm 2 on each paper-proxy
//! dataset for a fixed iteration budget and reports total clustering time,
//! the speedup ratios, and the ARI gap. Two extra cases per dataset track
//! the ISSUE-6 additions: the nested (geometric-growth) batch schedule and
//! the ε-terminated run (windowed confidence rule), whose cost depends on
//! how early the rule fires.
//!
//! ```bash
//! cargo bench --bench bench_speedup
//! ```

use mbkk::bench::BenchRunner;
use mbkk::coordinator::experiment::{run_with_gram, AlgoSpec, KernelSpec, RunSpec};
use mbkk::data::registry;
use mbkk::kkmeans::{LearningRate, ScheduleSpec};
use mbkk::util::rng::Rng;

fn main() {
    let mut runner = BenchRunner::new("speedup vs full batch (Fig 1 / headline)");
    let scale = std::env::var("MBKK_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.15f64);
    let iters = 50;

    println!(
        "  (scale={scale}, {iters} iterations per algorithm, gaussian kernel)\n"
    );
    let mut lines = Vec::new();
    for &dataset in registry::PAPER_PROXIES {
        let ds = registry::load(dataset, scale, 7);
        let k = registry::default_k(dataset);
        let kernel = KernelSpec::Gaussian { multiplier: 1.0 };
        let mut rng = Rng::seeded(7);
        let (gram, kernel_secs) = kernel.build(&ds, &mut rng);

        let mut run = |algo: AlgoSpec,
                       b: usize,
                       schedule: ScheduleSpec,
                       tau: usize,
                       epsilon: Option<f64>| {
            let spec = RunSpec {
                dataset: dataset.to_string(),
                scale,
                kernel,
                algo,
                k,
                batch_size: b,
                schedule,
                tau,
                max_iters: iters,
                epsilon,
                seed: 3,
                numerics: mbkk::kernels::NumericsMode::Deterministic,
            };
            run_with_gram(&spec, &ds, Some(&gram), kernel_secs)
        };

        let fixed = ScheduleSpec::Fixed;
        let nested = ScheduleSpec::Nested { growth: 2.0 };
        let full = run(AlgoSpec::FullKkm, 1024, fixed, usize::MAX, None);
        let alg1 = run(AlgoSpec::MbKkm(LearningRate::Beta), 256, fixed, usize::MAX, None);
        let alg2_big = run(AlgoSpec::TruncKkm(LearningRate::Beta), 1024, fixed, 200, None);
        let alg2 = run(AlgoSpec::TruncKkm(LearningRate::Beta), 256, fixed, 100, None);
        let alg2_nested = run(AlgoSpec::TruncKkm(LearningRate::Beta), 256, nested, 200, None);
        let alg2_eps = run(
            AlgoSpec::TruncKkm(LearningRate::Beta),
            256,
            fixed,
            200,
            Some(1e-3),
        );

        runner.record(&format!("{dataset}/full-kkm"), full.cluster_secs);
        runner.record(&format!("{dataset}/bmb-kkm (alg1, b=256)"), alg1.cluster_secs);
        runner.record(&format!("{dataset}/btrunc-kkm (alg2, b=1024)"), alg2_big.cluster_secs);
        runner.record(&format!("{dataset}/btrunc-kkm (alg2, b=256)"), alg2.cluster_secs);
        runner.record(
            &format!("{dataset}/btrunc-kkm (alg2, nested g=2)"),
            alg2_nested.cluster_secs,
        );
        runner.record(
            &format!("{dataset}/btrunc-kkm (alg2, eps-term)"),
            alg2_eps.cluster_secs,
        );

        lines.push(format!(
            "  {dataset:<16} full {:>7.2}s (ARI {:.3}) | alg1 b=256 {:>6.2}s ({:.1}x, ARI {:.3}) | alg2 b=1024 {:>6.2}s ({:.1}x, ARI {:.3}) | alg2 b=256 {:>6.2}s ({:.1}x, ARI {:.3}) | nested {:>6.2}s ({:.1}x) | eps {:>6.2}s ({} iters)",
            full.cluster_secs, full.ari,
            alg1.cluster_secs, full.cluster_secs / alg1.cluster_secs.max(1e-9), alg1.ari,
            alg2_big.cluster_secs, full.cluster_secs / alg2_big.cluster_secs.max(1e-9), alg2_big.ari,
            alg2.cluster_secs, full.cluster_secs / alg2.cluster_secs.max(1e-9), alg2.ari,
            alg2_nested.cluster_secs, full.cluster_secs / alg2_nested.cluster_secs.max(1e-9),
            alg2_eps.cluster_secs, alg2_eps.iterations,
        ));
    }
    println!("\n  == speedup summary (paper: 10-100x with minimal quality loss) ==");
    for l in &lines {
        println!("{l}");
    }
    runner.write_csv();
    runner.write_baseline(&BenchRunner::baseline_path());
}
