//! Prediction-service benchmarks (ISSUE 4): the batched [`PredictEngine`]
//! against the scalar per-query `KernelKMeansModel::predict` path it
//! replaces on the serving hot path, plus the artifact round-trip cost.
//!
//! Merges its samples into the repo-root `BENCH_baseline.json` perf
//! trajectory (suite "prediction service" — the same suite the CLI's
//! `serve-bench` loop records into).
//!
//! ```bash
//! RUSTFLAGS="-C target-cpu=native" cargo bench --bench bench_predict
//! ```
//!
//! `MBKK_BENCH_SCALE` shrinks the query set for smoke runs (CI uses 0.1).

use mbkk::bench::BenchRunner;
use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::kernels::{Gram, KernelFunction};
use mbkk::kkmeans::{
    KernelKMeansModel, NativeBackend, TruncatedConfig, TruncatedMiniBatchKernelKMeans,
};
use mbkk::serve::PredictEngine;
use mbkk::util::rng::Rng;

fn main() {
    let mut runner = BenchRunner::new("prediction service");
    let scale: f64 = std::env::var("MBKK_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let n = ((4000.0 * scale) as usize).max(512);
    let mut rng = Rng::seeded(19);

    for &d in &[16usize, 128] {
        let ds = blobs(&SyntheticSpec::new(n, d, 8), &mut rng);
        let kernel = KernelFunction::Gaussian { kappa: d as f64 };
        let gram = Gram::on_the_fly(&ds, kernel);
        let mut fit_rng = Rng::seeded(7);
        let mut fit = TruncatedMiniBatchKernelKMeans::new(TruncatedConfig {
            k: 8,
            batch_size: 256,
            tau: 100,
            max_iters: 20,
            ..Default::default()
        })
        .fit_with_backend(&gram, &mut NativeBackend, &mut fit_rng);
        let model = KernelKMeansModel::freeze(&ds, kernel, &mut fit.centers);
        let engine = PredictEngine::new(&model);
        println!(
            "  [setup] d={d}: {} queries x {} support points x {} centers",
            ds.n,
            model.support_points(),
            model.k()
        );

        let scalar_name = format!("scalar predict batch d={d}");
        let engine_name = format!("batched engine predict d={d}");
        runner.bench(&scalar_name, || model.predict_all(&ds));
        runner.bench(&engine_name, || engine.predict_batch(&ds.features));
        if let Some(speedup) = runner.ratio(&scalar_name, &engine_name) {
            println!("  -> batched speedup {speedup:.2}x at d={d}");
        }

        if d == 16 {
            runner.bench("model save+load round-trip d=16", || {
                KernelKMeansModel::from_bytes(&model.to_bytes()).expect("round-trip")
            });
            // Format v2 checksums the header and payload on both ends of
            // that round-trip (DESIGN.md §12). This case isolates one CRC
            // pass over the serialized artifact so the round-trip's
            // integrity overhead is attributable: roughly 2x this number
            // per save and per load.
            let bytes = model.to_bytes();
            println!("  [setup] artifact size {} bytes", bytes.len());
            runner.bench("artifact crc32 pass d=16", || {
                mbkk::util::crc32::crc32(&bytes)
            });
        }
    }

    runner.write_csv();
    runner.write_baseline(&BenchRunner::baseline_path());
}
