//! Serving SLO benchmarks (ISSUE 7): end-to-end latency of the zero-dep
//! HTTP prediction service under concurrent clients, plus the lazy-scan
//! vs full-tree JSON parsing cost on the request hot path.
//!
//! Starts an in-process [`Server`] on a loopback ephemeral port, drives it
//! with 4 keep-alive client threads at three request mixes (1 / 8 / 64
//! rows), and records p50 / p99 latency and mean seconds-per-request for
//! each mix. The coalescing counters printed at the end show batches <
//! requests — the admission queue's whole point.
//!
//! Merges its samples into the repo-root `BENCH_baseline.json` perf
//! trajectory (suite "serving SLO").
//!
//! ```bash
//! RUSTFLAGS="-C target-cpu=native" cargo bench --bench bench_serving
//! ```
//!
//! `MBKK_BENCH_SCALE` shrinks the request count for smoke runs (CI uses
//! 0.1); `MBKK_BENCH_SECS` bounds the two parse micro-benches.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

use mbkk::bench::BenchRunner;
use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::data::Dataset;
use mbkk::kernels::{Gram, KernelFunction};
use mbkk::kkmeans::{
    KernelKMeansModel, NativeBackend, TruncatedConfig, TruncatedMiniBatchKernelKMeans,
};
use mbkk::serve::http::{ServeConfig, Server};
use mbkk::util::json::{lazy, Json};
use mbkk::util::rng::Rng;

/// Concurrent keep-alive clients driving each mix (matches the CI e2e job).
const CLIENT_THREADS: usize = 4;

/// Minimal blocking HTTP/1.1 client: one keep-alive connection, enough
/// response parsing to frame bodies by Content-Length. Deliberately tiny —
/// the server under test is the thing being measured.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: &str) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to bench server");
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone().expect("clone stream");
        Client { reader: BufReader::new(stream), writer }
    }

    /// POST `body` to /v1/predict and return the response's `rows` count.
    fn predict(&mut self, body: &str) -> usize {
        let head = format!(
            "POST /v1/predict HTTP/1.1\r\nHost: bench\r\n\
             Content-Type: application/json\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );
        self.writer.write_all(head.as_bytes()).expect("write head");
        self.writer.write_all(body.as_bytes()).expect("write body");
        let mut status = String::new();
        self.reader.read_line(&mut status).expect("status line");
        assert!(status.starts_with("HTTP/1.1 200"), "unexpected response: {status}");
        let mut content_length = 0usize;
        loop {
            let mut line = String::new();
            self.reader.read_line(&mut line).expect("header line");
            let lower = line.trim().to_ascii_lowercase();
            if lower.is_empty() {
                break;
            }
            if let Some(v) = lower.strip_prefix("content-length:") {
                content_length = v.trim().parse().expect("content-length");
            }
        }
        let mut resp = vec![0u8; content_length];
        self.reader.read_exact(&mut resp).expect("response body");
        let json = Json::parse(std::str::from_utf8(&resp).expect("utf-8")).expect("json");
        json.get("rows").as_usize().unwrap_or(0)
    }
}

/// Serialize rows `0..rows` of `ds` as a `/v1/predict` request body, using
/// `{}` formatting (shortest round-trip) so the wire text re-parses to the
/// exact same f32 bits.
fn points_body(ds: &Dataset, rows: usize) -> String {
    let mut s = String::from("{\"points\": [");
    for i in 0..rows {
        if i > 0 {
            s.push(',');
        }
        s.push('[');
        for (j, v) in ds.row(i % ds.n).iter().enumerate() {
            if j > 0 {
                s.push(',');
            }
            s.push_str(&format!("{v}"));
        }
        s.push(']');
    }
    s.push_str("]}");
    s
}

/// Drive one request mix with [`CLIENT_THREADS`] concurrent keep-alive
/// clients and record p50 / p99 / mean latency samples.
fn drive_mix(runner: &mut BenchRunner, addr: &str, rows: usize, body: &str, per_thread: usize) {
    let latencies = Arc::new(Mutex::new(Vec::new()));
    let barrier = Arc::new(Barrier::new(CLIENT_THREADS));
    let started = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..CLIENT_THREADS {
            let latencies = Arc::clone(&latencies);
            let barrier = Arc::clone(&barrier);
            scope.spawn(move || {
                let mut client = Client::connect(addr);
                let mut local = Vec::with_capacity(per_thread);
                barrier.wait();
                for _ in 0..per_thread {
                    let t = Instant::now();
                    let got = client.predict(body);
                    local.push(t.elapsed().as_secs_f64());
                    assert_eq!(got, rows, "response rows mismatch");
                }
                latencies.lock().expect("latencies").extend(local);
            });
        }
    });
    let wall = started.elapsed().as_secs_f64();
    let mut lat = latencies.lock().expect("latencies").clone();
    lat.sort_by(|a, b| a.total_cmp(b));
    let total = lat.len();
    let p50 = lat[total / 2];
    let p99 = lat[(total * 99 / 100).min(total - 1)];
    let mean = lat.iter().sum::<f64>() / total as f64;
    let unit = if rows == 1 { "row" } else { "rows" };
    runner.record(&format!("p50 latency mix={rows} {unit}"), p50);
    runner.record(&format!("p99 latency mix={rows} {unit}"), p99);
    runner.record(&format!("seconds/request mix={rows} {unit}"), mean);
    println!(
        "  -> mix={rows} {unit}: {total} requests from {CLIENT_THREADS} clients, {:.0} req/s",
        total as f64 / wall
    );
}

fn main() {
    let mut runner = BenchRunner::new("serving SLO");
    let scale: f64 = std::env::var("MBKK_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let n = ((3000.0 * scale) as usize).max(512);
    let d = 16;
    let mut rng = Rng::seeded(23);
    let ds = blobs(&SyntheticSpec::new(n, d, 8), &mut rng);
    let kernel = KernelFunction::Gaussian { kappa: d as f64 };
    let gram = Gram::on_the_fly(&ds, kernel);
    let mut fit_rng = Rng::seeded(7);
    let mut fit = TruncatedMiniBatchKernelKMeans::new(TruncatedConfig {
        k: 8,
        batch_size: 256,
        tau: 100,
        max_iters: 20,
        ..Default::default()
    })
    .fit_with_backend(&gram, &mut NativeBackend, &mut fit_rng);
    let model = KernelKMeansModel::freeze(&ds, kernel, &mut fit.centers);
    println!(
        "  [setup] d={d}: {} support points x {} centers",
        model.support_points(),
        model.k()
    );

    let cfg = ServeConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() };
    let server = Server::bind(&model, "bench", &cfg).expect("bind bench server");
    let addr = server.local_addr().expect("local addr").to_string();
    let shutdown = server.shutdown_flag();
    let handle = std::thread::spawn(move || server.run().expect("serve"));

    let per_thread = ((400.0 * scale) as usize).max(25);
    for &rows in &[1usize, 8, 64] {
        let body = points_body(&ds, rows);
        drive_mix(&mut runner, &addr, rows, &body, per_thread);
    }
    shutdown.store(true, Ordering::SeqCst);
    let stats = handle.join().expect("server thread");
    println!(
        "  [coalescing] {} requests in {} batches ({} coalesced batches, {} rows total)",
        stats.requests, stats.batches, stats.coalesced_batches, stats.rows
    );
    assert!(stats.batches <= stats.requests, "batches can never exceed requests");

    // Request-parsing micro-benches: the lazy offset scanner the service
    // uses vs the full-tree parse it rejected (ADR-003).
    let parse_body = points_body(&ds, 64);
    runner.bench("parse 64x16 points lazy scan", || {
        let fields = lazy::fields(parse_body.as_bytes(), &["points"]).expect("scan");
        fields[0].as_ref().expect("points present").parse_points().expect("points")
    });
    runner.bench("parse 64x16 points full tree", || {
        let tree = Json::parse(&parse_body).expect("parse");
        let rows = tree.get("points").as_arr().expect("points array");
        let mut features = Vec::with_capacity(rows.len() * 16);
        for row in rows {
            for v in row.as_arr().expect("row array") {
                features.push(v.as_f64().expect("number") as f32);
            }
        }
        features
    });
    let ratio = runner.ratio("parse 64x16 points full tree", "parse 64x16 points lazy scan");
    if let Some(r) = ratio {
        println!("  -> lazy scan {r:.2}x faster than full-tree parse");
    }

    // Failpoint overhead: every I/O boundary on the serving path calls
    // `failpoint::armed()` (ADR-004). Unarmed it must cost one relaxed
    // atomic load — this case measures 1M checks so the per-call cost is
    // resolvable, and keeps the "unobservable in production" claim in
    // the perf trajectory rather than in prose.
    assert!(
        !mbkk::util::failpoint::armed(),
        "bench must run with MBKK_FAILPOINTS unset"
    );
    runner.bench("failpoint armed() x1M disabled", || {
        let mut any = false;
        for _ in 0..1_000_000u32 {
            any |= std::hint::black_box(mbkk::util::failpoint::armed());
        }
        any
    });

    // Shard-scaling: the same 64-row batch scored through 1 / 2 / 4
    // in-process shards at a fixed support size (DESIGN.md §14). S=1
    // isolates the dispatch/merge plumbing cost over the plain engine;
    // S>1 shows what parallel per-shard panels buy (or cost) at this
    // support size.
    let shard_rows: Vec<f32> = (0..64).flat_map(|i| ds.row(i % ds.n).to_vec()).collect();
    for s in [1usize, 2, 4] {
        let set = mbkk::serve::shard::ShardSet::local(
            &model,
            mbkk::serve::shard::ShardPlan::contiguous(model.k(), s),
            1,
            mbkk::kernels::NumericsMode::Deterministic,
            mbkk::serve::shard::ShardSetConfig::default(),
        )
        .expect("shard set");
        runner.bench(&format!("shard score 64x16 rows S={s}"), || {
            set.score_batch(std::hint::black_box(&shard_rows)).expect("score").assignments
        });
    }

    // Retry-path overhead: a delay(2) fault on every dispatch attempt
    // bounds what one slow replica hop costs a fully-covered answer —
    // the backoff/failover machinery itself, not the outage. Runs after
    // the unarmed case above so that case's assertion stays meaningful.
    let set = mbkk::serve::shard::ShardSet::local(
        &model,
        mbkk::serve::shard::ShardPlan::contiguous(model.k(), 2),
        1,
        mbkk::kernels::NumericsMode::Deterministic,
        mbkk::serve::shard::ShardSetConfig::default(),
    )
    .expect("shard set");
    mbkk::util::failpoint::configure("shard.dispatch=delay(2)").expect("arm delay");
    runner.bench("shard score 64x16 rows S=2 delay(2ms)", || {
        set.score_batch(std::hint::black_box(&shard_rows)).expect("score").assignments
    });
    mbkk::util::failpoint::reset();

    runner.write_csv();
    runner.write_baseline(&BenchRunner::baseline_path());
}
