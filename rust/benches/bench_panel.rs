//! Panel micro-kernel + worker-pool benchmarks (ISSUE 3, extended by
//! ISSUE 9 with the Fast numerics mode).
//!
//! * Panel block fill vs the pre-panel scalar engine (difference-form
//!   per-pair evaluation, reimplemented here as the baseline) at d = 16
//!   and d = 128 — the acceptance criterion asks ≥ 2x at d = 128.
//! * The same block fill under `NumericsMode::Fast` (runtime-dispatched
//!   SIMD dot micro-kernels + batched exp), so both numerics modes land
//!   in the perf trajectory side by side.
//! * The batched exponential alone: `f64::exp` per value (Deterministic)
//!   vs the dispatched `exp_slice` arm (Fast) over a Gaussian-range
//!   argument buffer.
//! * Dispatch latency of the persistent pool vs scoped per-call spawning
//!   (the old `util::parallel` implementation, reimplemented here) — the
//!   overhead that used to sit on every 1-2 ms Algorithm-2 iteration.
//!
//! Merges its samples into the repo-root `BENCH_baseline.json` perf
//! trajectory (suite "panel micro-kernels"); `write_baseline` stamps the
//! worker-thread count into every case's metadata.
//!
//! ```bash
//! cargo bench --bench bench_panel                     # runtime dispatch
//! RUSTFLAGS="-C target-cpu=native" cargo bench --bench bench_panel
//! ```

use mbkk::bench::BenchRunner;
use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::data::Dataset;
use mbkk::kernels::{Gram, KernelFunction, NumericsMode};
use mbkk::util::rng::Rng;
use mbkk::util::{parallel, simd};
use std::sync::atomic::{AtomicUsize, Ordering};

/// The pre-panel scalar engine: difference-form Gaussian per pair,
/// parallel over batch rows — what `Gram::block_into` compiled to before
/// the panel rewrite (per-pair loop-carried f64 chain).
fn scalar_block(ds: &Dataset, kappa: f64, rows: &[usize], cols: &[usize], out: &mut [f64]) {
    let nc = cols.len();
    parallel::par_rows_mut(out, nc, |r0, chunk| {
        for (r, orow) in chunk.chunks_mut(nc).enumerate() {
            let xi = ds.row(rows[r0 + r]);
            for (o, &j) in orow.iter_mut().zip(cols.iter()) {
                let mut s = 0.0f64;
                for (x, y) in xi.iter().zip(ds.row(j)) {
                    let d = (*x - *y) as f64;
                    s += d * d;
                }
                *o = (-s / kappa).exp();
            }
        }
    });
}

/// The pre-pool dispatcher: spawn scoped threads for one parallel region,
/// atomic-counter claimed — what `par_dynamic` compiled to before the
/// persistent pool. `workers` is hoisted to the caller so the timed
/// region measures dispatch alone, not the thread-count probe.
fn scoped_spawn_dispatch(workers: usize, count: usize, f: &(dyn Fn(usize) + Sync)) {
    if workers <= 1 {
        for i in 0..count {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= count {
                    break;
                }
                f(i);
            });
        }
    });
}

fn main() {
    let mut runner = BenchRunner::new("panel micro-kernels");
    let mut rng = Rng::seeded(17);
    println!(
        "numerics: fast arm = {:?}, threads = {}",
        simd::detected_arch(),
        parallel::num_threads()
    );

    for &d in &[16usize, 128] {
        let ds = blobs(&SyntheticSpec::new(8000, d, 5), &mut rng);
        let kappa = d as f64;
        let func = KernelFunction::Gaussian { kappa };
        let fly = Gram::on_the_fly(&ds, func);
        let fast = Gram::on_the_fly_with(&ds, func, NumericsMode::Fast);
        let rows: Vec<usize> = (0..256).map(|_| rng.below(ds.n)).collect();
        let cols: Vec<usize> = (0..512).map(|_| rng.below(ds.n)).collect();
        let mut out = vec![0.0f64; rows.len() * cols.len()];
        let det_case = format!("panel block 256x512 d={d}");
        let fast_case = format!("panel block 256x512 d={d} [fast]");
        let scalar_case = format!("scalar block 256x512 d={d}");
        // Warm the norm cache outside the timed region (one-time cost,
        // amortized over a whole run).
        let _ = ds.sq_norms();
        runner.bench(&det_case, || {
            fly.block_into(&rows, &cols, &mut out);
        });
        runner.bench(&fast_case, || {
            fast.block_into(&rows, &cols, &mut out);
        });
        runner.bench(&scalar_case, || {
            scalar_block(&ds, kappa, &rows, &cols, &mut out);
        });
        if let Some(r) = runner.ratio(&scalar_case, &det_case) {
            println!("  -> panel speedup over scalar at d={d}: {r:.2}x");
        }
        if let Some(r) = runner.ratio(&det_case, &fast_case) {
            println!("  -> fast-mode speedup over deterministic at d={d}: {r:.2}x");
        }
    }

    // The batched exponential alone, over the argument range the Gaussian
    // finish actually produces (exp of a non-positive scaled distance).
    let args: Vec<f64> = (0..4096).map(|_| -rng.f64() * 40.0).collect();
    let mut buf = args.clone();
    runner.bench("batched exp 4096", || {
        buf.copy_from_slice(&args);
        simd::exp_slice(NumericsMode::Deterministic, &mut buf);
    });
    runner.bench("batched exp 4096 [fast]", || {
        buf.copy_from_slice(&args);
        simd::exp_slice(NumericsMode::Fast, &mut buf);
    });
    if let Some(r) = runner.ratio("batched exp 4096", "batched exp 4096 [fast]") {
        println!("  -> fast exp speedup over f64::exp: {r:.2}x");
    }

    // Dispatch latency: tiny tasks, so the measurement is dominated by
    // region setup/teardown rather than payload.
    let payload = |i: usize| {
        std::hint::black_box((0..64u64).fold(i as u64, |a, b| a ^ (a + b)));
    };
    let workers = parallel::num_threads().min(64);
    runner.bench("pool dispatch 64 tasks", || {
        parallel::par_dynamic(64, payload);
    });
    runner.bench("scoped-spawn dispatch 64 tasks", || {
        scoped_spawn_dispatch(workers, 64, &payload);
    });
    if let Some(r) = runner.ratio("scoped-spawn dispatch 64 tasks", "pool dispatch 64 tasks") {
        println!("  -> pool dispatch speedup over scoped spawn: {r:.2}x");
    }

    runner.write_csv();
    runner.write_baseline(&BenchRunner::baseline_path());
}
