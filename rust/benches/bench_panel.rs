//! Panel micro-kernel + worker-pool benchmarks (ISSUE 3).
//!
//! * Panel block fill vs the pre-panel scalar engine (difference-form
//!   per-pair evaluation, reimplemented here as the baseline) at d = 16
//!   and d = 128 — the acceptance criterion asks ≥ 2x at d = 128.
//! * Dispatch latency of the persistent pool vs scoped per-call spawning
//!   (the old `util::parallel` implementation, reimplemented here) — the
//!   overhead that used to sit on every 1-2 ms Algorithm-2 iteration.
//!
//! Merges its samples into the repo-root `BENCH_baseline.json` perf
//! trajectory (suite "panel micro-kernels").
//!
//! ```bash
//! RUSTFLAGS="-C target-cpu=native" cargo bench --bench bench_panel
//! ```

use mbkk::bench::BenchRunner;
use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::data::Dataset;
use mbkk::kernels::{Gram, KernelFunction};
use mbkk::util::parallel;
use mbkk::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};

/// The pre-panel scalar engine: difference-form Gaussian per pair,
/// parallel over batch rows — what `Gram::block_into` compiled to before
/// the panel rewrite (per-pair loop-carried f64 chain).
fn scalar_block(ds: &Dataset, kappa: f64, rows: &[usize], cols: &[usize], out: &mut [f64]) {
    let nc = cols.len();
    parallel::par_rows_mut(out, nc, |r0, chunk| {
        for (r, orow) in chunk.chunks_mut(nc).enumerate() {
            let xi = ds.row(rows[r0 + r]);
            for (o, &j) in orow.iter_mut().zip(cols.iter()) {
                let mut s = 0.0f64;
                for (x, y) in xi.iter().zip(ds.row(j)) {
                    let d = (*x - *y) as f64;
                    s += d * d;
                }
                *o = (-s / kappa).exp();
            }
        }
    });
}

/// The pre-pool dispatcher: spawn scoped threads for one parallel region,
/// atomic-counter claimed — what `par_dynamic` compiled to before the
/// persistent pool.
fn scoped_spawn_dispatch(count: usize, f: &(dyn Fn(usize) + Sync)) {
    let workers = parallel::num_threads().min(count);
    if workers <= 1 {
        for i in 0..count {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= count {
                    break;
                }
                f(i);
            });
        }
    });
}

fn main() {
    let mut runner = BenchRunner::new("panel micro-kernels");
    let mut rng = Rng::seeded(17);

    for &d in &[16usize, 128] {
        let ds = blobs(&SyntheticSpec::new(8000, d, 5), &mut rng);
        let kappa = d as f64;
        let fly = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa });
        let rows: Vec<usize> = (0..256).map(|_| rng.below(ds.n)).collect();
        let cols: Vec<usize> = (0..512).map(|_| rng.below(ds.n)).collect();
        let mut out = vec![0.0f64; rows.len() * cols.len()];
        // Warm the norm cache outside the timed region (one-time cost,
        // amortized over a whole run).
        let _ = ds.sq_norms();
        runner.bench(&format!("panel block 256x512 d={d}"), || {
            fly.block_into(&rows, &cols, &mut out);
        });
        runner.bench(&format!("scalar block 256x512 d={d}"), || {
            scalar_block(&ds, kappa, &rows, &cols, &mut out);
        });
        if let Some(r) =
            runner.ratio(&format!("scalar block 256x512 d={d}"), &format!("panel block 256x512 d={d}"))
        {
            println!("  -> panel speedup over scalar at d={d}: {r:.2}x");
        }
    }

    // Dispatch latency: tiny tasks, so the measurement is dominated by
    // region setup/teardown rather than payload.
    let payload = |i: usize| {
        std::hint::black_box((0..64u64).fold(i as u64, |a, b| a ^ (a + b)));
    };
    runner.bench("pool dispatch 64 tasks", || {
        parallel::par_dynamic(64, payload);
    });
    runner.bench("scoped-spawn dispatch 64 tasks", || {
        scoped_spawn_dispatch(64, &payload);
    });
    if let Some(r) = runner.ratio("scoped-spawn dispatch 64 tasks", "pool dispatch 64 tasks") {
        println!("  -> pool dispatch speedup over scoped spawn: {r:.2}x");
    }

    runner.write_csv();
    runner.write_baseline(&BenchRunner::baseline_path());
}
