//! Ablations for the design choices DESIGN.md calls out:
//!
//! 1. **τ sweep** (Lemma 3 / §6 "tiny τ works"): iteration time and the
//!    empirical truncation error ‖Ĉ−C‖ as τ shrinks below the Lemma 3
//!    threshold — including the error bound check.
//! 2. **Learning-rate ablation** (§6 discussion): β vs sklearn rate —
//!    truncation error under each (the β rate's exponential decay is what
//!    makes truncation sound; sklearn's 1/i decay is not).
//! 3. **Early stopping** (Theorem 1(2)): iterations to terminate vs ε.
//!
//! ```bash
//! cargo bench --bench bench_ablation
//! ```

use mbkk::bench::BenchRunner;
use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::kernels::{Gram, KernelFunction};
use mbkk::kkmeans::learning_rate::{LearningRate, RateState};
use mbkk::kkmeans::{CenterWindow, TruncatedConfig, TruncatedMiniBatchKernelKMeans};
use mbkk::util::rng::Rng;

/// Feed identical update streams to an exact window and a τ-truncated one;
/// return max ‖Ĉ−C‖ over the run.
fn truncation_error(gram: &Gram, tau: usize, lr: LearningRate, iters: usize) -> f64 {
    let n = gram.n();
    let b = 64;
    let mut exact = CenterWindow::new(0, usize::MAX);
    let mut trunc = CenterWindow::new(0, tau);
    let mut rate = RateState::new(lr, 1);
    let mut rng = Rng::seeded(99);
    let mut worst = 0.0f64;
    for _ in 0..iters {
        let bj = 1 + rng.below(b);
        let pts: Vec<usize> = (0..bj).map(|_| rng.below(n)).collect();
        let alpha = rate.alpha(0, bj, b);
        exact.apply_update(alpha, &pts, None);
        trunc.apply_update(alpha, &pts, None);
        worst = worst.max(trunc.sqdist_to(&exact, gram).sqrt());
    }
    worst
}

fn main() {
    let mut runner = BenchRunner::new("ablations (tau, learning rate, epsilon)");
    let mut rng = Rng::seeded(5);
    let ds = blobs(&SyntheticSpec::new(4000, 8, 6).with_separation(4.0), &mut rng);
    let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 16.0 }).materialize();

    // ---- 1. τ sweep: time + truncation error --------------------------------
    println!("\n  == tau ablation (b=256, beta rate) ==");
    let eps = 0.5;
    let lemma3 = CenterWindow::lemma3_tau(64, 1.0, eps);
    for tau in [25usize, 50, 100, 200, 400, lemma3] {
        let cfg = TruncatedConfig {
            k: 6,
            batch_size: 256,
            tau,
            max_iters: 10,
            ..Default::default()
        };
        let mut r = Rng::seeded(2);
        let sw = mbkk::util::timing::Stopwatch::start();
        let res = TruncatedMiniBatchKernelKMeans::new(cfg).fit(&gram, &mut r);
        let per_iter = (res.profiler.phase_secs("assign") + res.profiler.phase_secs("update"))
            / res.iterations as f64;
        runner.record(&format!("alg2/iter tau={tau}"), per_iter);
        let err = truncation_error(&gram, tau, LearningRate::Beta, 80);
        println!(
            "  tau={tau:<5} per-iter {:>9.3}ms  max||C_trunc - C_exact|| = {err:.2e}{}",
            per_iter * 1e3,
            if tau == lemma3 {
                format!("  <= eps/28 = {:.2e} (Lemma 3 tau)", eps / 28.0)
            } else {
                String::new()
            }
        );
        if tau == lemma3 {
            assert!(
                err <= eps / 28.0 + 1e-9,
                "Lemma 3 violated: err={err} bound={}",
                eps / 28.0
            );
        }
        let _ = sw;
    }

    // ---- 2. learning-rate ablation -------------------------------------------
    println!("\n  == learning-rate ablation: truncation error at tau=100 ==");
    for lr in [LearningRate::Beta, LearningRate::Sklearn] {
        let err = truncation_error(&gram, 100, lr, 200);
        println!("  {:<8} max truncation error = {err:.3e}", lr.name());
    }
    println!("  (beta's non-vanishing rate decays history exponentially; sklearn's 1/i rate does not — paper §6)");

    // ---- 3. ε sweep: iterations to early-stop (Theorem 1(2)) -----------------
    println!("\n  == epsilon sweep: iterations until the stopping condition fires ==");
    for eps in [0.01f64, 0.003, 0.001] {
        let cfg = TruncatedConfig {
            k: 6,
            batch_size: 512,
            tau: 200,
            max_iters: 400,
            epsilon: Some(eps),
            ..Default::default()
        };
        let mut r = Rng::seeded(3);
        let res = TruncatedMiniBatchKernelKMeans::new(cfg).fit(&gram, &mut r);
        println!(
            "  eps={eps:<6} terminated after {:>4} iterations (converged={}, O(gamma^2/eps) predicts growth ~1/eps)",
            res.iterations, res.converged
        );
    }
    runner.write_csv();
}
