//! Streaming-provider benchmarks (ISSUE 2): the tile-LRU-cached provider
//! vs the materialized table on the assignment hot path, plus end-to-end
//! mini-batch fits at large-n scales where the table could not exist.
//!
//! Scenario sizes scale with `MBKK_BENCH_SCALE` (default 0.05):
//!
//! * the assignment comparison runs at `n = 160_000·scale` (default 8000),
//!   where both providers fit in memory and can be compared head to head;
//! * the large-n fits run at `n = 1_000_000·scale` (default 50_000) through
//!   the streaming provider only — at scale 1.0 this is the full
//!   million-point `blobs_1m` scenario, whose dense gram would be 4 TB.
//!
//! CI's `bench-smoke` job runs this suite at `MBKK_BENCH_SCALE=0.02` and
//! uploads the merged `BENCH_baseline.json` as a workflow artifact. Case
//! names are scale-independent so re-runs overwrite their own entries; the
//! printed banner records the concrete n of each run.
//!
//! ```bash
//! cargo bench --bench bench_stream                      # default preset
//! MBKK_BENCH_SCALE=1.0 cargo bench --bench bench_stream # full 1M points
//! ```

use mbkk::bench::BenchRunner;
use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::kernels::{CachedGram, Gram, KernelFunction};
use mbkk::kkmeans::{
    AssignBackend, CenterWindow, Init, LearningRate, MiniBatchConfig,
    MiniBatchKernelKMeans, NativeBackend, TruncatedConfig, TruncatedMiniBatchKernelKMeans,
};
use mbkk::util::rng::Rng;
use mbkk::util::timing::Stopwatch;

fn scale() -> f64 {
    std::env::var("MBKK_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&s: &f64| s > 0.0)
        .unwrap_or(0.05)
}

fn windows(rng: &mut Rng, n: usize, k: usize, tau: usize) -> Vec<CenterWindow> {
    let mut centers: Vec<CenterWindow> = (0..k).map(|j| CenterWindow::new(j, tau)).collect();
    for c in centers.iter_mut() {
        for _ in 0..(tau / 16).max(1) {
            let pts: Vec<usize> = (0..16).map(|_| rng.below(n)).collect();
            c.apply_update(0.4, &pts, None);
        }
    }
    centers
}

fn main() {
    let mut runner = BenchRunner::new("streaming provider");
    let s = scale();

    // ---- assignment step: materialized table vs tile-LRU cache -------------
    let n_cmp = ((160_000.0 * s) as usize).clamp(2_000, 20_000);
    let (k, b, tau, d) = (10usize, 256usize, 200usize, 16usize);
    println!("  [setup] assignment comparison at n={n_cmp} (b={b}, k={k}, tau={tau})");
    let mut rng = Rng::seeded(17);
    let ds = blobs(&SyntheticSpec::new(n_cmp, d, k).with_separation(4.0), &mut rng);
    let kernel = KernelFunction::Gaussian { kappa: 2.0 * d as f64 };
    let mat = Gram::on_the_fly(&ds, kernel).materialize();
    let cached = CachedGram::new(Gram::on_the_fly(&ds, kernel), 64 << 20);
    let mut centers = windows(&mut rng, ds.n, k, tau);
    let batch: Vec<usize> = (0..b).map(|_| rng.below(ds.n)).collect();
    let mut native = NativeBackend;
    runner.bench("assign b=256 materialized", || {
        native.distances(&mat, &batch, &mut centers)
    });
    // One priming pass, then the steady-state (warm-cache) rate — the
    // regime consecutive mini-batch iterations actually see, because the
    // support set changes by at most one batch per iteration.
    let _ = native.distances(&cached, &batch, &mut centers);
    runner.bench("assign b=256 streaming-warm", || {
        native.distances(&cached, &batch, &mut centers)
    });
    println!("  [cache] {}", cached.cache_stats().summary());

    // ---- large-n fits through the streaming provider only ------------------
    let n_big = ((1_000_000.0 * s) as usize).max(10_000);
    println!("  [setup] streaming fits at n={n_big} (4·n² = {:.1} GB table avoided)",
        4.0 * (n_big as f64) * (n_big as f64) / 1e9);
    let mut rng = Rng::seeded(23);
    let ds_big = blobs(&SyntheticSpec::new(n_big, d, k).with_separation(3.0), &mut rng);
    let big = CachedGram::new(Gram::on_the_fly(&ds_big, kernel), 64 << 20);

    let sw = Stopwatch::start();
    let cfg = TruncatedConfig {
        k,
        batch_size: b,
        tau,
        max_iters: 20,
        epsilon: None,
        learning_rate: LearningRate::Beta,
        init: Init::KMeansPlusPlusOnSample(2000),
        weights: None,
        ..Default::default()
    };
    let mut fit_rng = Rng::seeded(1);
    let fit = TruncatedMiniBatchKernelKMeans::new(cfg).fit(&big, &mut fit_rng);
    runner.record("trunc-fit streaming (20 iters)", sw.secs());
    println!(
        "  [trunc] objective {:.5} in {} iters; cache: {}",
        fit.objective,
        fit.iterations,
        big.cache_stats().summary()
    );

    let sw = Stopwatch::start();
    let cfg = MiniBatchConfig {
        k,
        batch_size: b,
        max_iters: 5,
        epsilon: None,
        learning_rate: LearningRate::Beta,
        init: Init::KMeansPlusPlusOnSample(2000),
        weights: None,
        ..Default::default()
    };
    let mut fit_rng = Rng::seeded(2);
    let fit = MiniBatchKernelKMeans::new(cfg).fit(&big, &mut fit_rng);
    runner.record("mb-fit streaming (5 iters)", sw.secs());
    println!("  [mb]    objective {:.5} in {} iters", fit.objective, fit.iterations);

    runner.write_csv();
    runner.write_baseline(&BenchRunner::baseline_path());
}
