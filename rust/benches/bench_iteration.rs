//! Theorem 1(1) / §4 cost model: per-iteration cost of the three kernel
//! k-means algorithms.
//!
//! Reproduces the paper's complexity claims empirically:
//! * Algorithm 2 (truncated): `Õ(kb²)` — scales with b, k, τ but NOT n.
//! * Algorithm 1: `O(n(b+k))` — linear in n.
//! * Full batch: `O(n²)` — quadratic in n.
//!
//! Merges its samples into the repo-root `BENCH_baseline.json` perf
//! trajectory (see README.md "Benchmarks").
//!
//! ```bash
//! cargo bench --bench bench_iteration
//! ```

use mbkk::bench::BenchRunner;
use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::kernels::{Gram, KernelFunction};
use mbkk::kkmeans::{
    FullBatchConfig, FullBatchKernelKMeans, Init, MiniBatchConfig, MiniBatchKernelKMeans,
    TruncatedConfig, TruncatedMiniBatchKernelKMeans,
};
use mbkk::util::rng::Rng;

const ITERS: usize = 10;

fn dataset(n: usize) -> mbkk::data::Dataset {
    let mut rng = Rng::seeded(42);
    blobs(&SyntheticSpec::new(n, 16, 8).with_separation(4.0), &mut rng)
}

fn trunc_secs_per_iter(gram: &Gram, k: usize, b: usize, tau: usize) -> f64 {
    let cfg = TruncatedConfig {
        k,
        batch_size: b,
        tau,
        max_iters: ITERS,
        epsilon: None,
        init: Init::Uniform,
        ..Default::default()
    };
    let mut rng = Rng::seeded(1);
    let sw = mbkk::util::timing::Stopwatch::start();
    let res = TruncatedMiniBatchKernelKMeans::new(cfg).fit(gram, &mut rng);
    // Subtract init+finalize via the profiler: report the assign+update time.
    let hot = res.profiler.phase_secs("assign") + res.profiler.phase_secs("update");
    let _ = sw;
    hot / ITERS as f64
}

fn main() {
    let mut runner = BenchRunner::new("iteration cost (Theorem 1)");

    // ---- Algorithm 2: scaling in b (fixed n, k, τ) -------------------------
    let ds = dataset(8000);
    let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 30.0 }).materialize();
    for b in [128usize, 256, 512, 1024] {
        let secs = trunc_secs_per_iter(&gram, 8, b, 200);
        runner.record(&format!("alg2/iter b={b} (k=8, tau=200, n=8000)"), secs);
    }
    // ---- Algorithm 2: scaling in τ ----------------------------------------
    for tau in [50usize, 100, 200, 400] {
        let secs = trunc_secs_per_iter(&gram, 8, 256, tau);
        runner.record(&format!("alg2/iter tau={tau} (k=8, b=256)"), secs);
    }
    // ---- Algorithm 2: scaling in k ----------------------------------------
    for k in [2usize, 8, 32] {
        let secs = trunc_secs_per_iter(&gram, k, 256, 200);
        runner.record(&format!("alg2/iter k={k} (b=256, tau=200)"), secs);
    }
    // ---- Algorithm 2: INDEPENDENCE of n (the headline) ---------------------
    for n in [2000usize, 8000] {
        let ds_n = dataset(n);
        let gram_n =
            Gram::on_the_fly(&ds_n, KernelFunction::Gaussian { kappa: 30.0 }).materialize();
        let secs = trunc_secs_per_iter(&gram_n, 8, 256, 200);
        runner.record(&format!("alg2/iter n={n} (b=256, tau=200)"), secs);
    }

    // ---- Algorithm 1: linear in n ------------------------------------------
    for n in [2000usize, 4000, 8000] {
        let ds_n = dataset(n);
        let gram_n =
            Gram::on_the_fly(&ds_n, KernelFunction::Gaussian { kappa: 30.0 }).materialize();
        let cfg = MiniBatchConfig {
            k: 8,
            batch_size: 256,
            max_iters: ITERS,
            init: Init::Uniform,
            ..Default::default()
        };
        let mut rng = Rng::seeded(1);
        let res = MiniBatchKernelKMeans::new(cfg).fit(&gram_n, &mut rng);
        let hot = res.profiler.phase_secs("assign")
            + res.profiler.phase_secs("update")
            + res.profiler.phase_secs("moments");
        runner.record(&format!("alg1/iter n={n} (b=256, k=8)"), hot / ITERS as f64);
    }

    // ---- Full batch: quadratic in n ----------------------------------------
    for n in [2000usize, 4000, 8000] {
        let ds_n = dataset(n);
        let gram_n =
            Gram::on_the_fly(&ds_n, KernelFunction::Gaussian { kappa: 30.0 }).materialize();
        let cfg = FullBatchConfig {
            k: 8,
            max_iters: 3,
            init: Init::Uniform,
            ..Default::default()
        };
        let mut rng = Rng::seeded(1);
        let res = FullBatchKernelKMeans::new(cfg).fit(&gram_n, &mut rng);
        let hot = res.profiler.phase_secs("assign") + res.profiler.phase_secs("term3");
        runner.record(
            &format!("full/iter n={n} (k=8)"),
            hot / res.iterations as f64,
        );
    }

    // Shape checks the paper's claims imply (soft-printed, not asserted:
    // absolute machines vary, ratios should hold approximately).
    if let Some(r) = runner.ratio("full/iter n=8000 (k=8)", "alg2/iter n=8000 (b=256, tau=200)") {
        println!("\n  full-batch / truncated per-iteration ratio at n=8000: {r:.1}x");
    }
    if let (Some(a), Some(b)) = (
        runner
            .samples()
            .iter()
            .find(|s| s.name.contains("alg2/iter n=2000"))
            .map(|s| s.mean),
        runner
            .samples()
            .iter()
            .find(|s| s.name.contains("alg2/iter n=8000"))
            .map(|s| s.mean),
    ) {
        println!("  alg2 n-independence: t(n=8000)/t(n=2000) = {:.2} (≈1 expected)", b / a);
    }
    runner.write_csv();
    runner.write_baseline(&BenchRunner::baseline_path());
}
