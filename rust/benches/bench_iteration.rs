//! Theorem 1(1) / §4 cost model: per-iteration cost of the three kernel
//! k-means algorithms.
//!
//! Reproduces the paper's complexity claims empirically:
//! * Algorithm 2 (truncated): `Õ(kb²)` — scales with b, k, τ but NOT n.
//! * Algorithm 1 (lazy DP state): iterations touch only the batch —
//!   per-iteration time flat in n (the `alg1-scaling` cases sweep
//!   n ∈ {4096, 65536, 262144} at fixed k, b and, under
//!   `MBKK_BENCH_ASSERT_SCALING=1`, *assert* sublinear growth).
//! * Full batch: `O(n²)` — quadratic in n.
//!
//! Merges its samples into the repo-root `BENCH_baseline.json` perf
//! trajectory (see README.md "Benchmarks").
//!
//! ```bash
//! cargo bench --bench bench_iteration                  # everything
//! cargo bench --bench bench_iteration -- alg1-scaling  # scaling cases only
//! ```

use mbkk::bench::BenchRunner;
use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::kernels::{Gram, KernelFunction};
use mbkk::kkmeans::{
    FullBatchConfig, FullBatchKernelKMeans, Init, MiniBatchConfig, MiniBatchKernelKMeans,
    TruncatedConfig, TruncatedMiniBatchKernelKMeans,
};
use mbkk::util::rng::Rng;

const ITERS: usize = 10;

fn dataset(n: usize) -> mbkk::data::Dataset {
    let mut rng = Rng::seeded(42);
    blobs(&SyntheticSpec::new(n, 16, 8).with_separation(4.0), &mut rng)
}

fn trunc_secs_per_iter(gram: &Gram, k: usize, b: usize, tau: usize) -> f64 {
    let cfg = TruncatedConfig {
        k,
        batch_size: b,
        tau,
        max_iters: ITERS,
        epsilon: None,
        init: Init::Uniform,
        ..Default::default()
    };
    let mut rng = Rng::seeded(1);
    let sw = mbkk::util::timing::Stopwatch::start();
    let res = TruncatedMiniBatchKernelKMeans::new(cfg).fit(gram, &mut rng);
    // Subtract init+finalize via the profiler: report the assign+update time.
    let hot = res.profiler.phase_secs("assign") + res.profiler.phase_secs("update");
    let _ = sw;
    hot / ITERS as f64
}

/// Mean per-iteration hot-loop time of Algorithm 1 (lazy DP state): the
/// refresh + assign + moments + update phases, excluding init and the
/// single finalize pass, per the profiler's split.
fn alg1_secs_per_iter(gram: &Gram, k: usize, b: usize) -> f64 {
    let cfg = MiniBatchConfig {
        k,
        batch_size: b,
        max_iters: ITERS,
        init: Init::Uniform,
        ..Default::default()
    };
    let mut rng = Rng::seeded(1);
    let res = MiniBatchKernelKMeans::new(cfg).fit(gram, &mut rng);
    let hot = res.profiler.phase_secs("refresh")
        + res.profiler.phase_secs("assign")
        + res.profiler.phase_secs("update")
        + res.profiler.phase_secs("moments");
    hot / ITERS as f64
}

fn main() {
    let mut runner = BenchRunner::new("iteration cost (Theorem 1)");
    // `-- alg1-scaling` runs only the lazy-state scaling sweep (the CI
    // bench-smoke preset): the legacy cases below would still *execute*
    // under the runner's record-level filter, so skip them wholesale.
    let only_scaling = std::env::args().skip(1).any(|a| a == "alg1-scaling");

    // ---- Algorithm 1 (lazy DP state): per-iteration time flat in n ---------
    // Fixed k and b; the generation-stamped state touches only the b
    // sampled points per iteration, so n must not show up. On-the-fly
    // gram: materializing 262144² would need 275 GB, and the lazy loop
    // never asks for it.
    let mut scaling: Vec<(usize, f64)> = Vec::new();
    for n in [4096usize, 65_536, 262_144] {
        let ds_n = dataset(n);
        let gram_n = Gram::on_the_fly(&ds_n, KernelFunction::Gaussian { kappa: 30.0 });
        let secs = alg1_secs_per_iter(&gram_n, 8, 256);
        runner.record(&format!("alg1-scaling/iter n={n} (b=256, k=8)"), secs);
        scaling.push((n, secs));
    }
    if let (Some(&(n0, t0)), Some(&(n1, t1))) = (scaling.first(), scaling.last()) {
        let ratio = t1 / t0.max(1e-12);
        println!("\n  alg1 lazy n-independence: t(n={n1})/t(n={n0}) = {ratio:.2} (≈1 expected)");
        if std::env::var("MBKK_BENCH_ASSERT_SCALING").is_ok() {
            assert!(
                ratio < 2.0,
                "Algorithm 1 per-iteration time grew {ratio:.2}x while n grew \
                 {}x at fixed k, b — the iteration loop is doing O(n) work",
                n1 / n0
            );
            println!("  [assert] sublinear scaling holds (ratio {ratio:.2} < 2.0)");
        }
    }
    if only_scaling {
        runner.write_csv();
        runner.write_baseline(&BenchRunner::baseline_path());
        return;
    }

    // ---- Algorithm 2: scaling in b (fixed n, k, τ) -------------------------
    let ds = dataset(8000);
    let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 30.0 }).materialize();
    for b in [128usize, 256, 512, 1024] {
        let secs = trunc_secs_per_iter(&gram, 8, b, 200);
        runner.record(&format!("alg2/iter b={b} (k=8, tau=200, n=8000)"), secs);
    }
    // ---- Algorithm 2: scaling in τ ----------------------------------------
    for tau in [50usize, 100, 200, 400] {
        let secs = trunc_secs_per_iter(&gram, 8, 256, tau);
        runner.record(&format!("alg2/iter tau={tau} (k=8, b=256)"), secs);
    }
    // ---- Algorithm 2: scaling in k ----------------------------------------
    for k in [2usize, 8, 32] {
        let secs = trunc_secs_per_iter(&gram, k, 256, 200);
        runner.record(&format!("alg2/iter k={k} (b=256, tau=200)"), secs);
    }
    // ---- Algorithm 2: INDEPENDENCE of n (the headline) ---------------------
    for n in [2000usize, 8000] {
        let ds_n = dataset(n);
        let gram_n =
            Gram::on_the_fly(&ds_n, KernelFunction::Gaussian { kappa: 30.0 }).materialize();
        let secs = trunc_secs_per_iter(&gram_n, 8, 256, 200);
        runner.record(&format!("alg2/iter n={n} (b=256, tau=200)"), secs);
    }

    // ---- Algorithm 1 on materialized tables (legacy points: these were
    // linear in n under the eager sweep; the lazy state flattens them too,
    // keeping the cases comparable across the perf trajectory) ---------------
    for n in [2000usize, 4000, 8000] {
        let ds_n = dataset(n);
        let gram_n =
            Gram::on_the_fly(&ds_n, KernelFunction::Gaussian { kappa: 30.0 }).materialize();
        let secs = alg1_secs_per_iter(&gram_n, 8, 256);
        runner.record(&format!("alg1/iter n={n} (b=256, k=8)"), secs);
    }

    // ---- Full batch: quadratic in n ----------------------------------------
    for n in [2000usize, 4000, 8000] {
        let ds_n = dataset(n);
        let gram_n =
            Gram::on_the_fly(&ds_n, KernelFunction::Gaussian { kappa: 30.0 }).materialize();
        let cfg = FullBatchConfig {
            k: 8,
            max_iters: 3,
            init: Init::Uniform,
            ..Default::default()
        };
        let mut rng = Rng::seeded(1);
        let res = FullBatchKernelKMeans::new(cfg).fit(&gram_n, &mut rng);
        let hot = res.profiler.phase_secs("assign") + res.profiler.phase_secs("term3");
        runner.record(
            &format!("full/iter n={n} (k=8)"),
            hot / res.iterations as f64,
        );
    }

    // Shape checks the paper's claims imply (soft-printed, not asserted:
    // absolute machines vary, ratios should hold approximately).
    if let Some(r) = runner.ratio("full/iter n=8000 (k=8)", "alg2/iter n=8000 (b=256, tau=200)") {
        println!("\n  full-batch / truncated per-iteration ratio at n=8000: {r:.1}x");
    }
    if let (Some(a), Some(b)) = (
        runner
            .samples()
            .iter()
            .find(|s| s.name.contains("alg2/iter n=2000"))
            .map(|s| s.mean),
        runner
            .samples()
            .iter()
            .find(|s| s.name.contains("alg2/iter n=8000"))
            .map(|s| s.mean),
    ) {
        println!("  alg2 n-independence: t(n=8000)/t(n=2000) = {:.2} (≈1 expected)", b / a);
    }
    runner.write_csv();
    runner.write_baseline(&BenchRunner::baseline_path());
}
