//! Backend ablation: the assignment hot path served by the pure-Rust
//! native backend vs the AOT-compiled JAX/Pallas graph through PJRT.
//!
//! Requires `make artifacts`. Benchmarks the `distances()` call on the
//! artifact configurations, which is exactly the Õ(kb²) step Theorem 1(1)
//! prices.
//!
//! ```bash
//! cargo bench --bench bench_backend
//! ```

use mbkk::bench::BenchRunner;
use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::kernels::{Gram, KernelFunction};
use mbkk::kkmeans::{AssignBackend, CenterWindow, NativeBackend};
use mbkk::runtime::XlaBackend;
use mbkk::util::rng::Rng;
use std::path::Path;

fn windows(rng: &mut Rng, n: usize, k: usize, tau: usize, fill: usize) -> Vec<CenterWindow> {
    let mut centers: Vec<CenterWindow> = (0..k).map(|j| CenterWindow::new(j, tau)).collect();
    for c in centers.iter_mut() {
        for _ in 0..(fill / 16).max(1) {
            let pts: Vec<usize> = (0..16).map(|_| rng.below(n)).collect();
            c.apply_update(0.4, &pts, None);
        }
    }
    centers
}

fn main() {
    let mut runner = BenchRunner::new("assignment backend (native vs xla)");
    let dir = Path::new(mbkk::runtime::DEFAULT_ARTIFACT_DIR);
    let have_artifacts = mbkk::runtime::artifacts_available(
        dir.to_str().unwrap_or("artifacts"),
    );
    if !have_artifacts {
        println!("  artifacts missing — run `make artifacts` for the XLA rows");
    }

    // Match the artifact grid: (b, k, d) with window fill ≈ τ.
    for &(b, k, d, tau) in &[(64usize, 4usize, 8usize, 100usize), (256, 10, 16, 300), (256, 10, 128, 300), (1024, 10, 16, 300)] {
        let mut rng = Rng::seeded(11);
        let ds = blobs(&SyntheticSpec::new(4000, d, k), &mut rng);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 2.0 * d as f64 });
        let mut centers = windows(&mut rng, ds.n, k, tau, tau);
        let batch: Vec<usize> = (0..b).map(|_| rng.below(ds.n)).collect();

        let mut native = NativeBackend;
        runner.bench(&format!("native b={b} k={k} d={d} tau={tau}"), || {
            native.distances(&gram, &batch, &mut centers)
        });

        if have_artifacts {
            if let Ok(mut xla) = XlaBackend::load(dir) {
                // Warm the executable cache outside the timed region.
                let _ = xla.distances(&gram, &batch, &mut centers);
                if xla.xla_calls > 0 {
                    runner.bench(&format!("xla    b={b} k={k} d={d} tau={tau}"), || {
                        xla.distances(&gram, &batch, &mut centers)
                    });
                } else {
                    println!("  (no artifact for b={b} k={k} d={d}; skipping xla row)");
                }
            }
        }
    }
    runner.write_csv();
}
