//! Substrate benchmarks: the gram/kernel machinery under the hot path.
//!
//! * `Gram::block` (the native analogue of the L1 Pallas kernel) — on-the-fly
//!   Gaussian evaluation vs materialized lookup.
//! * Full gram materialization (the paper's "kernel time" black bars).
//! * Dense GEMM + `expm` (the heat-kernel substrate).
//!
//! Merges its samples into the repo-root `BENCH_baseline.json` perf
//! trajectory (see README.md "Benchmarks").
//!
//! ```bash
//! cargo bench --bench bench_gram
//! ```

use mbkk::bench::BenchRunner;
use mbkk::data::synthetic::{blobs, SyntheticSpec};
use mbkk::kernels::{Gram, KernelFunction};
use mbkk::linalg::{expm, Matrix};
use mbkk::util::rng::Rng;

fn main() {
    let mut runner = BenchRunner::new("gram + linalg substrates");
    let mut rng = Rng::seeded(9);

    for &d in &[16usize, 128] {
        let ds = blobs(&SyntheticSpec::new(8000, d, 5), &mut rng);
        let fly = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: d as f64 });
        let rows: Vec<usize> = (0..256).map(|_| rng.below(ds.n)).collect();
        let cols: Vec<usize> = (0..512).map(|_| rng.below(ds.n)).collect();
        runner.bench(&format!("block 256x512 on-the-fly d={d}"), || {
            fly.block(&rows, &cols)
        });
    }

    let ds = blobs(&SyntheticSpec::new(3000, 16, 5), &mut rng);
    let fly = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 16.0 });
    runner.bench("materialize gram n=3000 d=16", || fly.materialize());
    let mat = fly.materialize();
    let rows: Vec<usize> = (0..256).map(|_| rng.below(ds.n)).collect();
    let cols: Vec<usize> = (0..512).map(|_| rng.below(ds.n)).collect();
    runner.bench("block 256x512 materialized", || mat.block(&rows, &cols));

    // Dense linalg substrate (heat kernel path).
    for &n in &[256usize, 768] {
        let mut a = Matrix::zeros(n, n);
        for v in a.data.iter_mut() {
            *v = rng.normal() * 0.05;
        }
        let b = a.clone();
        runner.bench(&format!("gemm {n}x{n}"), || a.matmul(&b));
        runner.bench(&format!("expm {n}x{n}"), || expm(&a));
    }
    runner.write_csv();
    runner.write_baseline(&BenchRunner::baseline_path());
}
