//! The versioned on-disk artifact format behind `fit` → `predict`
//! (DESIGN.md §8, fault model in §12, replication in §14).
//!
//! Four artifact kinds share one container:
//!
//! * **`model`** — a frozen [`KernelKMeansModel`]: per-center support
//!   feature rows, coefficients, cached squared norms, and ⟨Ĉ,Ĉ⟩. May
//!   additionally record a shard plan (`shards` header key: the
//!   contiguous center-range bounds the serving tier splits the support
//!   set at) — loaders that predate sharding ignore the key.
//! * **`stream`** — a [`StreamingKernelKMeans`] checkpoint: the reservoir
//!   dataset, every window's raw entry structure, the learning-rate
//!   counters, and the iteration count — everything a bit-for-bit
//!   `resume` needs.
//! * **`train`** — a mid-fit [`TrainSnapshot`] of Algorithm 2: the fit
//!   RNG, every center window, the learning-rate counters, the objective
//!   history, the ε-stopper replay log, and the schedule carry — what
//!   `--resume auto` restores to continue a SIGKILLed training run
//!   bit-identically (DESIGN.md §12).
//! * **`delta`** — a [`LogDelta`](crate::serve::replicate::LogDelta):
//!   the coefficient-log suffix between two generations of one
//!   streaming fit, so a replica catches up by replay instead of
//!   re-downloading a full `stream` snapshot (DESIGN.md §14).
//!
//! Version-2 layout (all integers little-endian):
//!
//! ```text
//! offset 0     8 bytes   magic "MBKKMDL\0"
//! offset 8     u32       header length H
//! offset 12    H bytes   JSON header (util::json): format_version, kind,
//!                        kernel parameters, dimensions, and every count
//!                        needed to compute the exact payload size
//! offset 12+H  u32       CRC-32 of bytes [0, 12+H) — magic, length, header
//! offset 16+H  P bytes   binary payload: f32/f64/u32/u64 arrays in the
//!                        order the header describes
//! offset 16+H+P u32      CRC-32 of the payload section
//! ```
//!
//! Float *scalars* that only parameterize the kernel live in the JSON
//! header (Rust's shortest-round-trip formatting re-parses bit-exactly);
//! every float *array* lives in the binary payload verbatim, so a
//! save→load round trip is bit-identical by construction.
//!
//! **Version policy**: writers always emit [`FORMAT_VERSION`]; loaders
//! accept [`MIN_FORMAT_VERSION`]..=[`FORMAT_VERSION`] and reject anything
//! else with a clear error — never a silent best-effort parse. Version 1
//! (PR 4–7 artifacts) is the same layout without the two CRC sections;
//! v1 artifacts still load, unchecksummed. **Robustness contract**:
//! malformed input of any kind (bad magic, truncated header or payload,
//! corrupt JSON, checksum mismatch, unknown kernels, out-of-range
//! indices) yields an [`Error`](crate::util::error) — the loaders never
//! panic, never return a silently wrong model, and never allocate more
//! than the input's own length. On-disk writes go through
//! [`atomic_write`] (same-dir temp file + fsync file and directory +
//! rename), so a crash leaves the previous artifact intact, never a torn
//! mix. The serving conformance suite (`rust/tests/conformance_serve.rs`)
//! and this module's corruption-matrix test pin all of this.

use crate::data::Dataset;
use crate::kernels::KernelFunction;
use crate::kkmeans::learning_rate::RateState;
use crate::kkmeans::state::{WindowState, WindowView};
use crate::kkmeans::{
    CenterWindow, KernelKMeansModel, LearningRate, StreamingKernelKMeans, TrainSnapshot,
};
use crate::serve::replicate::{LogDelta, WinDelta};
use crate::util::crc32::crc32;
use crate::util::error::{Context, Result};
use crate::util::failpoint;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::{bail, format_err};
use std::path::Path;

/// Artifact magic: identifies every kind; the header's `kind` field
/// disambiguates.
pub const MAGIC: [u8; 8] = *b"MBKKMDL\0";

/// The format version this build writes.
pub const FORMAT_VERSION: usize = 2;

/// The oldest format version this build still reads (v1 = the same
/// container without CRC sections).
pub const MIN_FORMAT_VERSION: usize = 1;

// ---- container ------------------------------------------------------------

fn assemble(header: Json, payload: Vec<u8>) -> Vec<u8> {
    let htext = header.to_string();
    let mut out = Vec::with_capacity(20 + htext.len() + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&(htext.len() as u32).to_le_bytes());
    out.extend_from_slice(htext.as_bytes());
    let header_crc = crc32(&out);
    out.extend_from_slice(&header_crc.to_le_bytes());
    out.extend_from_slice(&payload);
    out.extend_from_slice(&crc32(&payload).to_le_bytes());
    out
}

/// Validate magic + version + checksums, parse the header, and return it
/// with the payload slice. `want_kind` cross-checks that a model artifact
/// is not opened as a checkpoint or vice versa.
fn split_artifact<'a>(bytes: &'a [u8], want_kind: &str) -> Result<(Json, &'a [u8])> {
    if bytes.len() < 12 {
        bail!("artifact too short ({} bytes): not an mbkk artifact", bytes.len());
    }
    if bytes[..8] != MAGIC {
        bail!("bad magic: not an mbkk model/checkpoint artifact");
    }
    let hlen = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
    let rest = &bytes[12..];
    if hlen > rest.len() {
        bail!(
            "artifact header truncated (header claims {hlen} bytes, {} available)",
            rest.len()
        );
    }
    let text =
        std::str::from_utf8(&rest[..hlen]).context("artifact header is not UTF-8")?;
    let header = Json::parse(text).context("artifact header is not valid JSON")?;
    let version = header
        .get("format_version")
        .as_usize()
        .context("artifact header missing format_version")?;
    if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version) {
        bail!(
            "unsupported artifact format version {version} \
             (this build reads versions {MIN_FORMAT_VERSION}..={FORMAT_VERSION})"
        );
    }
    let kind = header
        .get("kind")
        .as_str()
        .context("artifact header missing kind")?;
    if kind != want_kind {
        bail!(
            "artifact kind {kind:?} where {want_kind:?} was expected \
             (a {kind:?} artifact cannot be opened as a {want_kind:?})"
        );
    }
    if version == 1 {
        // Legacy unchecksummed layout: payload is everything after the
        // header. Torn v1 artifacts are still caught by the exact
        // payload-size pre-checks, just without bit-flip detection.
        return Ok((header, &rest[hlen..]));
    }
    // v2: 4-byte header CRC after the header, 4-byte payload CRC at the end.
    let after_header = &rest[hlen..];
    if after_header.len() < 8 {
        bail!(
            "artifact truncated: version {version} needs 8 checksum bytes \
             after the header, found {}",
            after_header.len()
        );
    }
    let stored_hcrc = u32::from_le_bytes([
        after_header[0],
        after_header[1],
        after_header[2],
        after_header[3],
    ]);
    let computed_hcrc = crc32(&bytes[..12 + hlen]);
    if stored_hcrc != computed_hcrc {
        bail!(
            "artifact header checksum mismatch (stored {stored_hcrc:#010x}, \
             computed {computed_hcrc:#010x}): corrupt or torn artifact"
        );
    }
    let payload = &after_header[4..after_header.len() - 4];
    let tail = &after_header[after_header.len() - 4..];
    let stored_pcrc = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
    let computed_pcrc = crc32(payload);
    if stored_pcrc != computed_pcrc {
        bail!(
            "artifact payload checksum mismatch (stored {stored_pcrc:#010x}, \
             computed {computed_pcrc:#010x}): corrupt or torn artifact"
        );
    }
    Ok((header, payload))
}

// ---- binary payload helpers -----------------------------------------------

fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn push_f64s(buf: &mut Vec<u8>, xs: &[f64]) {
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn push_u32s(buf: &mut Vec<u8>, xs: &[u32]) {
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn push_u64s(buf: &mut Vec<u8>, xs: &[u64]) {
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked little-endian payload reader. Every `take` is validated
/// against the remaining input, so a truncated payload is an error at the
/// exact offset, never a slice panic. (The loaders additionally pre-check
/// the *total* payload size from the header's counts before reading, so
/// in practice the per-take errors only fire on internally inconsistent
/// input.)
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(bytes: &'a [u8]) -> Reader<'a> {
        Reader { bytes, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| {
                format_err!(
                    "artifact payload truncated at byte {} ({} more wanted, {} left)",
                    self.pos,
                    n,
                    self.bytes.len() - self.pos
                )
            })?;
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn f64s(&mut self, n: usize) -> Result<Vec<f64>> {
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| {
                f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
            })
            .collect())
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn u64s(&mut self, n: usize) -> Result<Vec<u64>> {
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| {
                u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
            })
            .collect())
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(self.f64s(1)?[0])
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.bytes.len() {
            bail!(
                "artifact payload has {} trailing bytes (corrupt or a newer writer)",
                self.bytes.len() - self.pos
            );
        }
        Ok(())
    }
}

// ---- kernel parameters ----------------------------------------------------

/// Kernel parameters as the artifact header (and `/v1/models`) spell them.
pub(crate) fn kernel_to_json(f: KernelFunction) -> Json {
    match f {
        KernelFunction::Gaussian { kappa } => Json::obj(vec![
            ("name", Json::Str("gaussian".into())),
            ("kappa", Json::Num(kappa)),
        ]),
        KernelFunction::Laplacian { sigma } => Json::obj(vec![
            ("name", Json::Str("laplacian".into())),
            ("sigma", Json::Num(sigma)),
        ]),
        KernelFunction::Polynomial { gamma, coef0, degree } => Json::obj(vec![
            ("name", Json::Str("polynomial".into())),
            ("gamma", Json::Num(gamma)),
            ("coef0", Json::Num(coef0)),
            ("degree", Json::Num(degree as f64)),
        ]),
        KernelFunction::Linear => {
            Json::obj(vec![("name", Json::Str("linear".into()))])
        }
    }
}

fn kernel_from_json(j: &Json) -> Result<KernelFunction> {
    let name = j
        .get("name")
        .as_str()
        .context("artifact header missing kernel name")?;
    let num = |key: &str| -> Result<f64> {
        let v = j
            .get(key)
            .as_f64()
            .with_context(|| format!("kernel {name:?} missing parameter {key:?}"))?;
        if !v.is_finite() {
            bail!("kernel {name:?} parameter {key:?} is not finite");
        }
        Ok(v)
    };
    match name {
        "gaussian" => Ok(KernelFunction::Gaussian { kappa: num("kappa")? }),
        "laplacian" => Ok(KernelFunction::Laplacian { sigma: num("sigma")? }),
        "polynomial" => {
            let degree = j
                .get("degree")
                .as_usize()
                .context("kernel \"polynomial\" missing integer degree")?;
            Ok(KernelFunction::Polynomial {
                gamma: num("gamma")?,
                coef0: num("coef0")?,
                degree: u32::try_from(degree)
                    .ok()
                    .with_context(|| format!("polynomial degree {degree} exceeds u32"))?,
            })
        }
        "linear" => Ok(KernelFunction::Linear),
        other => bail!(
            "unknown kernel {other:?} in artifact header \
             (this build knows gaussian|laplacian|polynomial|linear)"
        ),
    }
}

// ---- kind "model" ---------------------------------------------------------

/// Serialize a frozen model (kind `model`).
pub fn model_to_bytes(model: &KernelKMeansModel) -> Vec<u8> {
    model_to_bytes_with_plan(model, None)
}

/// Serialize a frozen model, optionally recording a serving shard plan
/// (the contiguous center-range bounds, `bounds[0]=0 ..= bounds[S]=k`)
/// in the header. The plan is advisory serving metadata: it changes no
/// payload byte, and loaders without shard support skip the key.
pub fn model_to_bytes_with_plan(
    model: &KernelKMeansModel,
    plan_bounds: Option<&[usize]>,
) -> Vec<u8> {
    let support: Vec<Json> = model
        .centers
        .iter()
        .map(|(_, coefs, _)| Json::Num(coefs.len() as f64))
        .collect();
    let mut fields = vec![
        ("format_version", Json::Num(FORMAT_VERSION as f64)),
        ("kind", Json::Str("model".into())),
        ("kernel", kernel_to_json(model.kernel)),
        ("d", Json::Num(model.d as f64)),
        ("k", Json::Num(model.k() as f64)),
        ("support", Json::Arr(support)),
    ];
    if let Some(bounds) = plan_bounds {
        fields.push(("shards", Json::arr_num(bounds.iter().map(|&b| b as f64))));
    }
    let header = Json::obj(fields);
    let mut payload = Vec::new();
    for (feats, coefs, norms) in model.centers.iter() {
        push_f32s(&mut payload, feats);
        push_f64s(&mut payload, coefs);
        push_f64s(&mut payload, norms);
    }
    push_f64s(&mut payload, &model.cc);
    assemble(header, payload)
}

/// Parse a kind-`model` artifact. See the module docs for the validation
/// and robustness contract.
pub fn model_from_bytes(bytes: &[u8]) -> Result<KernelKMeansModel> {
    let (header, payload) = split_artifact(bytes, "model")?;
    let kernel = kernel_from_json(header.get("kernel"))?;
    let d = header.get("d").as_usize().context("artifact header missing d")?;
    let k = header.get("k").as_usize().context("artifact header missing k")?;
    if d == 0 {
        bail!("artifact header has d=0 (a model must have a feature dimension)");
    }
    if k == 0 {
        bail!("artifact header has k=0 (a model must have at least one center)");
    }
    let support = header
        .get("support")
        .as_arr()
        .context("artifact header missing support counts")?;
    if support.len() != k {
        bail!(
            "artifact header has {} support counts for k={k} centers",
            support.len()
        );
    }
    let counts: Vec<usize> = support
        .iter()
        .map(|s| s.as_usize().context("artifact header has a non-integer support count"))
        .collect::<Result<_>>()?;
    // Exact payload-size pre-check in u128 (immune to adversarial counts)
    // before any array is read: a short payload is "truncated", a long one
    // is "trailing bytes", both with byte-accurate messages.
    let mut expect: u128 = (k as u128) * 8;
    for &s in &counts {
        expect += (s as u128) * (d as u128) * 4 + (s as u128) * 16;
    }
    if expect != payload.len() as u128 {
        bail!(
            "model payload truncated or corrupt: header describes {expect} bytes, \
             found {}",
            payload.len()
        );
    }
    let mut r = Reader::new(payload);
    let mut centers = Vec::with_capacity(k);
    for &s in &counts {
        // s * d cannot overflow usize here: the pre-check above bounds it
        // by the actual payload length.
        let feats = r.f32s(s * d)?;
        let coefs = r.f64s(s)?;
        let norms = r.f64s(s)?;
        centers.push((feats, coefs, norms));
    }
    let cc = r.f64s(k)?;
    r.done()?;
    Ok(KernelKMeansModel { kernel, d, centers, cc })
}

/// Read the serving shard plan recorded in a kind-`model` artifact's
/// header, if any: the contiguous center-range bounds written by
/// [`model_to_bytes_with_plan`]. `Ok(None)` for artifacts without one.
/// Structural validation (0-start, k-end, monotone) is the caller's —
/// `serve::shard::ShardPlan::from_bounds` — so one validator serves both
/// CLI flags and artifact headers.
pub fn model_shard_plan(bytes: &[u8]) -> Result<Option<Vec<usize>>> {
    let (header, _payload) = split_artifact(bytes, "model")?;
    let shards = header.get("shards");
    if matches!(shards, Json::Null) {
        return Ok(None);
    }
    let arr = shards
        .as_arr()
        .context("artifact header shards key is not an array")?;
    let bounds: Vec<usize> = arr
        .iter()
        .map(|b| b.as_usize().context("artifact header has a non-integer shard bound"))
        .collect::<Result<_>>()?;
    Ok(Some(bounds))
}

/// Crash-safe durable file write (ADR-004): write a same-directory temp
/// file, fsync it, rename it over the target, then fsync the directory so
/// the rename itself survives power loss. A crash at any step leaves
/// either the complete old file or the complete new file — never a torn
/// mix — because rename(2) is atomic within a filesystem and the temp
/// file shares the target's directory. Each step evaluates a failpoint
/// (`artifact.write.tmp` / `.fsync` / `.rename`) so the chaos suite can
/// kill or fail a writer inside every window; on any error the temp file
/// is removed best-effort.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write as _;
    let dir = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    let name = path
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "artifact".into());
    // The PID suffix keeps concurrent writers (e.g. two fits sharing a
    // checkpoint dir by mistake) from clobbering each other's temp files.
    let tmp = dir.join(format!(".{name}.tmp.{}", std::process::id()));
    let result = (|| -> Result<()> {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating temp file {}", tmp.display()))?;
        f.write_all(bytes)
            .with_context(|| format!("writing temp file {}", tmp.display()))?;
        failpoint::fire("artifact.write.tmp")?;
        f.sync_all()
            .with_context(|| format!("fsyncing temp file {}", tmp.display()))?;
        failpoint::fire("artifact.write.fsync")?;
        drop(f);
        std::fs::rename(&tmp, path).with_context(|| {
            format!("renaming {} over {}", tmp.display(), path.display())
        })?;
        failpoint::fire("artifact.write.rename")?;
        // Durability of the rename: fsync the containing directory.
        // Best-effort — not every platform lets a directory fd sync, and
        // the data itself is already safe in both the old and new inode.
        if let Ok(d) = std::fs::File::open(&dir) {
            let _ = d.sync_all();
        }
        Ok(())
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Write a model artifact to `path` via [`atomic_write`].
pub fn save_model(model: &KernelKMeansModel, path: &Path) -> Result<()> {
    atomic_write(path, &model_to_bytes(model))
        .with_context(|| format!("writing model artifact {}", path.display()))
}

/// Read + decode an artifact through one path, so *every* loader error —
/// I/O or decode — names the offending file. HTTP 500s and CLI failures
/// both surface these messages; `conformance_http.rs` pins the guarantee.
fn load_with_path<T>(
    path: &Path,
    what: &str,
    decode: impl FnOnce(&[u8]) -> Result<T>,
) -> Result<T> {
    failpoint::fire("artifact.read")
        .with_context(|| format!("reading {what} artifact {}", path.display()))?;
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {what} artifact {}", path.display()))?;
    decode(&bytes).with_context(|| format!("loading {what} artifact {}", path.display()))
}

/// Load a model artifact from `path`.
pub fn load_model(path: &Path) -> Result<KernelKMeansModel> {
    load_with_path(path, "model", model_from_bytes)
}

// ---- kind "stream" --------------------------------------------------------

/// Serialize a streaming checkpoint (kind `stream`). The window state is
/// read through borrowed [`WindowView`]s — no copy of the O(k·(τ+b))
/// support arrays is made on the checkpoint path.
pub fn stream_to_bytes(s: &StreamingKernelKMeans) -> Vec<u8> {
    let states: Vec<WindowView<'_>> = s
        .windows
        .as_ref()
        .map(|ws| ws.iter().map(|w| w.state_view()).collect())
        .unwrap_or_default();
    let windows_json: Vec<Json> = states
        .iter()
        .map(|w| {
            Json::obj(vec![
                (
                    "entries",
                    Json::arr_num(w.entries.iter().map(|(p, _)| p.len() as f64)),
                ),
                ("has_init", Json::Bool(w.init_point.is_some())),
                (
                    "init_idx",
                    match w.init_point {
                        Some((idx, _)) => Json::Num(idx as f64),
                        None => Json::Null,
                    },
                ),
                ("has_cc", Json::Bool(w.cc_cache.is_some())),
                ("updates_since_exact", Json::Num(w.updates_since_exact as f64)),
            ])
        })
        .collect();
    let header = Json::obj(vec![
        ("format_version", Json::Num(FORMAT_VERSION as f64)),
        ("kind", Json::Str("stream".into())),
        ("kernel", kernel_to_json(s.kernel)),
        ("d", Json::Num(s.store.d as f64)),
        ("k", Json::Num(s.k as f64)),
        ("tau", Json::Num(s.tau as f64)),
        ("batch_size", Json::Num(s.batch_size as f64)),
        ("iterations", Json::Num(s.iterations as f64)),
        ("rate", Json::Str(s.rate.kind().name().into())),
        ("rate_counts", Json::Num(s.rate.counts().len() as f64)),
        ("store_n", Json::Num(s.store.n as f64)),
        ("has_windows", Json::Bool(s.windows.is_some())),
        ("windows", Json::Arr(windows_json)),
    ]);
    let mut payload = Vec::new();
    push_f32s(&mut payload, &s.store.features);
    push_f64s(&mut payload, s.rate.counts());
    for w in &states {
        for (points, raws) in &w.entries {
            push_u32s(&mut payload, points);
            push_f64s(&mut payload, raws);
        }
        push_f64s(&mut payload, &[w.scale]);
        if let Some((_, raw)) = w.init_point {
            push_f64s(&mut payload, &[raw]);
        }
        if let Some(cc) = w.cc_cache {
            push_f64s(&mut payload, &[cc]);
        }
    }
    assemble(header, payload)
}

/// Per-window structure pulled from the header before the payload is read.
struct WinMeta {
    entry_lens: Vec<usize>,
    has_init: bool,
    init_idx: u32,
    has_cc: bool,
    updates_since_exact: u32,
}

/// Parse a kind-`stream` checkpoint artifact.
pub fn stream_from_bytes(bytes: &[u8]) -> Result<StreamingKernelKMeans> {
    let (header, payload) = split_artifact(bytes, "stream")?;
    let kernel = kernel_from_json(header.get("kernel"))?;
    let want = |key: &str| -> Result<usize> {
        header
            .get(key)
            .as_usize()
            .with_context(|| format!("artifact header missing {key}"))
    };
    let d = want("d")?;
    let k = want("k")?;
    let tau = want("tau")?;
    let batch_size = want("batch_size")?;
    let iterations = want("iterations")?;
    let rate_counts_len = want("rate_counts")?;
    let store_n = want("store_n")?;
    if d == 0 {
        bail!("artifact header has d=0 (a stream must have a feature dimension)");
    }
    if k == 0 {
        bail!("artifact header has k=0 (a stream must have at least one center)");
    }
    if tau == 0 {
        bail!("artifact header has tau=0 (truncation windows need tau >= 1)");
    }
    // Writer invariants the loader must enforce, or a corrupt checkpoint
    // loads fine and panics later inside partial_fit (out-of-bounds rate
    // counts, empty-window assignment) — violating the never-panic
    // contract above.
    if rate_counts_len != k {
        bail!(
            "artifact header has {rate_counts_len} learning-rate counters for \
             k={k} centers"
        );
    }
    let rate_kind = match header
        .get("rate")
        .as_str()
        .context("artifact header missing rate")?
    {
        "beta" => LearningRate::Beta,
        "sklearn" => LearningRate::Sklearn,
        other => bail!("unknown learning-rate schedule {other:?} in artifact header"),
    };
    let has_windows = header
        .get("has_windows")
        .as_bool()
        .context("artifact header missing has_windows")?;
    let windows_json = header
        .get("windows")
        .as_arr()
        .context("artifact header missing windows")?;
    if !has_windows && !windows_json.is_empty() {
        bail!("artifact header lists windows but has_windows=false");
    }
    // The writer emits min(k, first-batch size) ≥ 1 windows once
    // initialized; anything outside [1, k] is corrupt.
    if has_windows && (windows_json.is_empty() || windows_json.len() > k) {
        bail!(
            "artifact header has {} windows for k={k} centers",
            windows_json.len()
        );
    }
    let mut metas = Vec::with_capacity(windows_json.len());
    for w in windows_json {
        let entry_lens: Vec<usize> = w
            .get("entries")
            .as_arr()
            .context("window header missing entries")?
            .iter()
            .map(|e| e.as_usize().context("window header has a non-integer entry length"))
            .collect::<Result<_>>()?;
        let has_init = w
            .get("has_init")
            .as_bool()
            .context("window header missing has_init")?;
        let init_idx = if has_init {
            let idx = w
                .get("init_idx")
                .as_usize()
                .context("window header missing init_idx")?;
            u32::try_from(idx).ok().context("window init_idx exceeds u32")?
        } else {
            0
        };
        let updates = w
            .get("updates_since_exact")
            .as_usize()
            .context("window header missing updates_since_exact")?;
        metas.push(WinMeta {
            entry_lens,
            has_init,
            init_idx,
            has_cc: w
                .get("has_cc")
                .as_bool()
                .context("window header missing has_cc")?,
            updates_since_exact: u32::try_from(updates)
                .ok()
                .context("window updates_since_exact exceeds u32")?,
        });
    }
    // Exact payload-size pre-check (u128; see model_from_bytes).
    let mut expect: u128 =
        (store_n as u128) * (d as u128) * 4 + (rate_counts_len as u128) * 8;
    for m in &metas {
        for &len in &m.entry_lens {
            expect += (len as u128) * 12; // u32 points + f64 raws
        }
        expect += 8; // scale
        expect += 8 * u128::from(m.has_init) + 8 * u128::from(m.has_cc);
    }
    if expect != payload.len() as u128 {
        bail!(
            "checkpoint payload truncated or corrupt: header describes {expect} \
             bytes, found {}",
            payload.len()
        );
    }
    let mut r = Reader::new(payload);
    let features = r.f32s(store_n * d)?;
    let counts = r.f64s(rate_counts_len)?;
    let mut windows = Vec::with_capacity(metas.len());
    for m in &metas {
        let mut entries = Vec::with_capacity(m.entry_lens.len());
        for &len in &m.entry_lens {
            let points = r.u32s(len)?;
            if let Some(&bad) = points.iter().find(|&&p| p as usize >= store_n) {
                bail!(
                    "checkpoint window references store row {bad} but the \
                     reservoir has only {store_n} rows"
                );
            }
            let raws = r.f64s(len)?;
            entries.push((points, raws));
        }
        let scale = r.f64()?;
        let init_point = if m.has_init {
            if m.init_idx as usize >= store_n {
                bail!(
                    "checkpoint window init point {} is outside the {store_n}-row \
                     reservoir",
                    m.init_idx
                );
            }
            Some((m.init_idx, r.f64()?))
        } else {
            None
        };
        let cc_cache = if m.has_cc { Some(r.f64()?) } else { None };
        windows.push(CenterWindow::from_state(WindowState {
            entries,
            scale,
            init_point,
            tau,
            cc_cache,
            updates_since_exact: m.updates_since_exact,
        }));
    }
    r.done()?;
    Ok(StreamingKernelKMeans {
        kernel,
        k,
        tau,
        batch_size,
        rate: RateState::from_parts(rate_kind, counts),
        store: Dataset::new("stream", features, store_n, d),
        windows: has_windows.then_some(windows),
        iterations,
    })
}

/// Write a checkpoint artifact to `path` via [`atomic_write`].
pub fn save_stream(s: &StreamingKernelKMeans, path: &Path) -> Result<()> {
    atomic_write(path, &stream_to_bytes(s))
        .with_context(|| format!("writing checkpoint artifact {}", path.display()))
}

/// Load a checkpoint artifact from `path`.
pub fn load_stream(path: &Path) -> Result<StreamingKernelKMeans> {
    load_with_path(path, "checkpoint", stream_from_bytes)
}

// ---- kind "delta" ---------------------------------------------------------

/// Serialize a replication delta (kind `delta`, DESIGN.md §14): the
/// coefficient-log suffix between two generations of one streaming fit.
/// Same container, CRCs, and bit-exactness contract as the other kinds —
/// `apply_delta` on a replica at the base generation reproduces the
/// primary's `stream` snapshot byte-for-byte.
pub fn delta_to_bytes(delta: &LogDelta) -> Vec<u8> {
    let windows_json: Vec<Json> = delta
        .windows
        .iter()
        .map(|w| {
            Json::obj(vec![
                ("base_entries", Json::Num(w.base_entries as f64)),
                ("dropped", Json::Num(w.dropped as f64)),
                (
                    "appended",
                    Json::arr_num(w.appended.iter().map(|(p, _)| p.len() as f64)),
                ),
                ("has_init", Json::Bool(w.init_point.is_some())),
                (
                    "init_idx",
                    match w.init_point {
                        Some((idx, _)) => Json::Num(idx as f64),
                        None => Json::Null,
                    },
                ),
                ("has_cc", Json::Bool(w.cc_cache.is_some())),
                ("updates_since_exact", Json::Num(w.updates_since_exact as f64)),
            ])
        })
        .collect();
    let header = Json::obj(vec![
        ("format_version", Json::Num(FORMAT_VERSION as f64)),
        ("kind", Json::Str("delta".into())),
        ("kernel", kernel_to_json(delta.kernel)),
        ("d", Json::Num(delta.d as f64)),
        ("k", Json::Num(delta.k as f64)),
        ("tau", Json::Num(delta.tau as f64)),
        ("batch_size", Json::Num(delta.batch_size as f64)),
        ("rate", Json::Str(delta.rate_kind.name().into())),
        ("rate_counts", Json::Num(delta.rate_counts.len() as f64)),
        ("base_iterations", Json::Num(delta.base_iterations as f64)),
        ("base_store_n", Json::Num(delta.base_store_n as f64)),
        ("base_store_crc", Json::Num(delta.base_store_crc as f64)),
        ("iterations", Json::Num(delta.iterations as f64)),
        ("store_n", Json::Num(delta.store_n as f64)),
        ("base_windows", Json::Num(delta.base_windows as f64)),
        ("windows", Json::Arr(windows_json)),
    ]);
    let mut payload = Vec::new();
    push_f32s(&mut payload, &delta.store_rows);
    push_f64s(&mut payload, &delta.rate_counts);
    for w in &delta.windows {
        for (points, raws) in &w.appended {
            push_u32s(&mut payload, points);
            push_f64s(&mut payload, raws);
        }
        push_f64s(&mut payload, &[w.scale]);
        if let Some((_, raw)) = w.init_point {
            push_f64s(&mut payload, &[raw]);
        }
        if let Some(cc) = w.cc_cache {
            push_f64s(&mut payload, &[cc]);
        }
    }
    assemble(header, payload)
}

/// Parse a kind-`delta` artifact. Same robustness contract as the other
/// loaders; the base-identity checks (is this replica actually at the
/// delta's base generation?) are `apply_delta`'s — this loader validates
/// structure, sizes, and index bounds.
pub fn delta_from_bytes(bytes: &[u8]) -> Result<LogDelta> {
    let (header, payload) = split_artifact(bytes, "delta")?;
    let kernel = kernel_from_json(header.get("kernel"))?;
    let want = |key: &str| -> Result<usize> {
        header
            .get(key)
            .as_usize()
            .with_context(|| format!("delta artifact header missing {key}"))
    };
    let d = want("d")?;
    let k = want("k")?;
    let tau = want("tau")?;
    let batch_size = want("batch_size")?;
    let rate_counts_len = want("rate_counts")?;
    let base_iterations = want("base_iterations")?;
    let base_store_n = want("base_store_n")?;
    let base_store_crc = want("base_store_crc")?;
    let iterations = want("iterations")?;
    let store_n = want("store_n")?;
    let base_windows = want("base_windows")?;
    if d == 0 {
        bail!("delta artifact has d=0 (a stream must have a feature dimension)");
    }
    if k == 0 {
        bail!("delta artifact has k=0 (a stream must have at least one center)");
    }
    if tau == 0 {
        bail!("delta artifact has tau=0 (truncation windows need tau >= 1)");
    }
    if rate_counts_len != k {
        bail!(
            "delta artifact has {rate_counts_len} learning-rate counters for \
             k={k} centers"
        );
    }
    let base_store_crc = u32::try_from(base_store_crc)
        .ok()
        .context("delta artifact base_store_crc exceeds u32")?;
    if iterations < base_iterations {
        bail!(
            "delta artifact runs backwards: generation {iterations} from a base \
             at {base_iterations}"
        );
    }
    if store_n < base_store_n {
        bail!(
            "delta artifact shrinks the reservoir ({base_store_n} -> {store_n} \
             rows): deltas only append"
        );
    }
    let rate_kind = match header
        .get("rate")
        .as_str()
        .context("delta artifact header missing rate")?
    {
        "beta" => LearningRate::Beta,
        "sklearn" => LearningRate::Sklearn,
        other => bail!("unknown learning-rate schedule {other:?} in delta artifact"),
    };
    let windows_json = header
        .get("windows")
        .as_arr()
        .context("delta artifact header missing windows")?;
    if windows_json.len() > k {
        bail!(
            "delta artifact has {} window updates for k={k} centers",
            windows_json.len()
        );
    }
    if base_windows > 0 && !windows_json.is_empty() && windows_json.len() != base_windows {
        bail!(
            "delta artifact carries {} window updates for a base with \
             {base_windows} windows",
            windows_json.len()
        );
    }
    struct DeltaWinMeta {
        base_entries: usize,
        dropped: usize,
        appended_lens: Vec<usize>,
        has_init: bool,
        init_idx: u32,
        has_cc: bool,
        updates_since_exact: u32,
    }
    let mut metas = Vec::with_capacity(windows_json.len());
    for w in windows_json {
        let base_entries = w
            .get("base_entries")
            .as_usize()
            .context("delta window header missing base_entries")?;
        let dropped = w
            .get("dropped")
            .as_usize()
            .context("delta window header missing dropped")?;
        if dropped > base_entries {
            bail!("delta window drops {dropped} of {base_entries} base entries");
        }
        let appended_lens: Vec<usize> = w
            .get("appended")
            .as_arr()
            .context("delta window header missing appended")?
            .iter()
            .map(|e| {
                e.as_usize().context("delta window header has a non-integer entry length")
            })
            .collect::<Result<_>>()?;
        let has_init = w
            .get("has_init")
            .as_bool()
            .context("delta window header missing has_init")?;
        let init_idx = if has_init {
            let idx = w
                .get("init_idx")
                .as_usize()
                .context("delta window header missing init_idx")?;
            u32::try_from(idx).ok().context("delta window init_idx exceeds u32")?
        } else {
            0
        };
        let updates = w
            .get("updates_since_exact")
            .as_usize()
            .context("delta window header missing updates_since_exact")?;
        metas.push(DeltaWinMeta {
            base_entries,
            dropped,
            appended_lens,
            has_init,
            init_idx,
            has_cc: w
                .get("has_cc")
                .as_bool()
                .context("delta window header missing has_cc")?,
            updates_since_exact: u32::try_from(updates)
                .ok()
                .context("delta window updates_since_exact exceeds u32")?,
        });
    }
    // Exact payload-size pre-check (u128; see model_from_bytes).
    let mut expect: u128 = ((store_n - base_store_n) as u128) * (d as u128) * 4
        + (rate_counts_len as u128) * 8;
    for m in &metas {
        for &len in &m.appended_lens {
            expect += (len as u128) * 12; // u32 points + f64 raws
        }
        expect += 8; // scale
        expect += 8 * u128::from(m.has_init) + 8 * u128::from(m.has_cc);
    }
    if expect != payload.len() as u128 {
        bail!(
            "delta payload truncated or corrupt: header describes {expect} bytes, \
             found {}",
            payload.len()
        );
    }
    let mut r = Reader::new(payload);
    let store_rows = r.f32s((store_n - base_store_n) * d)?;
    let rate_counts = r.f64s(rate_counts_len)?;
    let mut windows = Vec::with_capacity(metas.len());
    for m in &metas {
        let mut appended = Vec::with_capacity(m.appended_lens.len());
        for &len in &m.appended_lens {
            let points = r.u32s(len)?;
            if let Some(&bad) = points.iter().find(|&&p| p as usize >= store_n) {
                bail!(
                    "delta window references store row {bad} but the reservoir \
                     reaches only {store_n} rows"
                );
            }
            let raws = r.f64s(len)?;
            appended.push((points, raws));
        }
        let scale = r.f64()?;
        let init_point = if m.has_init {
            if m.init_idx as usize >= store_n {
                bail!(
                    "delta window init point {} is outside the {store_n}-row \
                     reservoir",
                    m.init_idx
                );
            }
            Some((m.init_idx, r.f64()?))
        } else {
            None
        };
        let cc_cache = if m.has_cc { Some(r.f64()?) } else { None };
        windows.push(WinDelta {
            base_entries: m.base_entries,
            dropped: m.dropped,
            appended,
            scale,
            init_point,
            cc_cache,
            updates_since_exact: m.updates_since_exact,
        });
    }
    r.done()?;
    Ok(LogDelta {
        kernel,
        d,
        k,
        tau,
        batch_size,
        rate_kind,
        base_iterations,
        base_store_n,
        base_store_crc,
        iterations,
        store_n,
        store_rows,
        rate_counts,
        base_windows,
        windows,
    })
}

/// Write a delta artifact to `path` via [`atomic_write`].
pub fn save_delta(delta: &LogDelta, path: &Path) -> Result<()> {
    atomic_write(path, &delta_to_bytes(delta))
        .with_context(|| format!("writing delta artifact {}", path.display()))
}

/// Load a delta artifact from `path`.
pub fn load_delta(path: &Path) -> Result<LogDelta> {
    load_with_path(path, "delta", delta_from_bytes)
}

// ---- kind "train" ---------------------------------------------------------

/// Sidecar facts a training checkpoint carries beyond the loop state:
/// the run-spec fingerprint (resume refuses a snapshot from a different
/// configuration) and the dataset size (for index validation).
pub(crate) struct TrainMeta {
    /// Canonical description of the producing run's configuration.
    pub fingerprint: String,
    /// Dataset row count — every stored index must be below it.
    pub n: usize,
}

/// Serialize a mid-fit training snapshot (kind `train`).
pub(crate) fn train_to_bytes(snap: &TrainSnapshot, fingerprint: &str, n: usize) -> Vec<u8> {
    let windows_json: Vec<Json> = snap
        .windows
        .iter()
        .map(|w| {
            Json::obj(vec![
                (
                    "entries",
                    Json::arr_num(w.entries.iter().map(|(p, _)| p.len() as f64)),
                ),
                ("has_init", Json::Bool(w.init_point.is_some())),
                (
                    "init_idx",
                    match w.init_point {
                        Some((idx, _)) => Json::Num(idx as f64),
                        None => Json::Null,
                    },
                ),
                ("has_cc", Json::Bool(w.cc_cache.is_some())),
                ("updates_since_exact", Json::Num(w.updates_since_exact as f64)),
            ])
        })
        .collect();
    let (rng_words, gauss_cache) = snap.rng.state();
    let tau = snap.windows.first().map_or(1, |w| w.tau);
    let header = Json::obj(vec![
        ("format_version", Json::Num(FORMAT_VERSION as f64)),
        ("kind", Json::Str("train".into())),
        ("fingerprint", Json::Str(fingerprint.into())),
        ("n", Json::Num(n as f64)),
        ("k", Json::Num(snap.windows.len() as f64)),
        ("tau", Json::Num(tau.min(u32::MAX as usize) as f64)),
        ("untruncated", Json::Bool(tau == usize::MAX)),
        ("next_iter", Json::Num(snap.next_iter as f64)),
        ("rate", Json::Str(snap.rate_kind.name().into())),
        ("rate_counts", Json::Num(snap.rate_counts.len() as f64)),
        ("history", Json::Num(snap.history.len() as f64)),
        ("improvements", Json::Num(snap.improvements.len() as f64)),
        ("prev_batch", Json::Num(snap.prev_batch.len() as f64)),
        ("has_gauss", Json::Bool(gauss_cache.is_some())),
        ("windows", Json::Arr(windows_json)),
    ]);
    let mut payload = Vec::new();
    push_u64s(&mut payload, &rng_words);
    if let Some(g) = gauss_cache {
        push_f64s(&mut payload, &[g]);
    }
    push_f64s(&mut payload, &snap.rate_counts);
    push_f64s(&mut payload, &snap.history);
    let improvement_iters: Vec<u32> = snap.improvements.iter().map(|&(i, _)| i).collect();
    let improvement_vals: Vec<f64> = snap.improvements.iter().map(|&(_, v)| v).collect();
    push_u32s(&mut payload, &improvement_iters);
    push_f64s(&mut payload, &improvement_vals);
    let prev: Vec<u32> = snap.prev_batch.iter().map(|&x| x as u32).collect();
    push_u32s(&mut payload, &prev);
    for w in &snap.windows {
        for (points, raws) in &w.entries {
            push_u32s(&mut payload, points);
            push_f64s(&mut payload, raws);
        }
        push_f64s(&mut payload, &[w.scale]);
        if let Some((_, raw)) = w.init_point {
            push_f64s(&mut payload, &[raw]);
        }
        if let Some(cc) = w.cc_cache {
            push_f64s(&mut payload, &[cc]);
        }
    }
    assemble(header, payload)
}

/// Parse a kind-`train` checkpoint artifact. Same robustness contract as
/// the other loaders: errors, never panics, never a silently wrong state.
pub(crate) fn train_from_bytes(bytes: &[u8]) -> Result<(TrainSnapshot, TrainMeta)> {
    let (header, payload) = split_artifact(bytes, "train")?;
    let fingerprint = header
        .get("fingerprint")
        .as_str()
        .context("train checkpoint header missing fingerprint")?
        .to_string();
    let want = |key: &str| -> Result<usize> {
        header
            .get(key)
            .as_usize()
            .with_context(|| format!("train checkpoint header missing {key}"))
    };
    let n = want("n")?;
    let k = want("k")?;
    let tau = if header.get("untruncated").as_bool().unwrap_or(false) {
        usize::MAX
    } else {
        want("tau")?
    };
    let next_iter = want("next_iter")?;
    let rate_counts_len = want("rate_counts")?;
    let history_len = want("history")?;
    let improvements_len = want("improvements")?;
    let prev_batch_len = want("prev_batch")?;
    if k == 0 {
        bail!("train checkpoint has k=0 (a fit must have at least one center)");
    }
    if tau == 0 {
        bail!("train checkpoint has tau=0 (truncation windows need tau >= 1)");
    }
    if n == 0 {
        bail!("train checkpoint has n=0 (a fit needs a dataset)");
    }
    if rate_counts_len != k {
        bail!(
            "train checkpoint has {rate_counts_len} learning-rate counters \
             for k={k} centers"
        );
    }
    // history records one pre-update objective per completed iteration.
    if history_len != next_iter {
        bail!(
            "train checkpoint claims {next_iter} completed iterations but \
             records {history_len} history entries"
        );
    }
    let rate_kind = match header
        .get("rate")
        .as_str()
        .context("train checkpoint header missing rate")?
    {
        "beta" => LearningRate::Beta,
        "sklearn" => LearningRate::Sklearn,
        other => bail!("unknown learning-rate schedule {other:?} in train checkpoint"),
    };
    let has_gauss = header
        .get("has_gauss")
        .as_bool()
        .context("train checkpoint header missing has_gauss")?;
    let windows_json = header
        .get("windows")
        .as_arr()
        .context("train checkpoint header missing windows")?;
    if windows_json.len() != k {
        bail!(
            "train checkpoint header has {} windows for k={k} centers",
            windows_json.len()
        );
    }
    let mut metas = Vec::with_capacity(k);
    for w in windows_json {
        let entry_lens: Vec<usize> = w
            .get("entries")
            .as_arr()
            .context("window header missing entries")?
            .iter()
            .map(|e| e.as_usize().context("window header has a non-integer entry length"))
            .collect::<Result<_>>()?;
        let has_init = w
            .get("has_init")
            .as_bool()
            .context("window header missing has_init")?;
        let init_idx = if has_init {
            let idx = w
                .get("init_idx")
                .as_usize()
                .context("window header missing init_idx")?;
            u32::try_from(idx).ok().context("window init_idx exceeds u32")?
        } else {
            0
        };
        let updates = w
            .get("updates_since_exact")
            .as_usize()
            .context("window header missing updates_since_exact")?;
        metas.push(WinMeta {
            entry_lens,
            has_init,
            init_idx,
            has_cc: w
                .get("has_cc")
                .as_bool()
                .context("window header missing has_cc")?,
            updates_since_exact: u32::try_from(updates)
                .ok()
                .context("window updates_since_exact exceeds u32")?,
        });
    }
    // Exact payload-size pre-check (u128; see model_from_bytes).
    let mut expect: u128 = 32 // four RNG words
        + 8 * u128::from(has_gauss)
        + (rate_counts_len as u128) * 8
        + (history_len as u128) * 8
        + (improvements_len as u128) * 12 // u32 iteration + f64 value
        + (prev_batch_len as u128) * 4;
    for m in &metas {
        for &len in &m.entry_lens {
            expect += (len as u128) * 12; // u32 points + f64 raws
        }
        expect += 8; // scale
        expect += 8 * u128::from(m.has_init) + 8 * u128::from(m.has_cc);
    }
    if expect != payload.len() as u128 {
        bail!(
            "train checkpoint payload truncated or corrupt: header describes \
             {expect} bytes, found {}",
            payload.len()
        );
    }
    let mut r = Reader::new(payload);
    let words = r.u64s(4)?;
    let rng_words = [words[0], words[1], words[2], words[3]];
    let gauss_cache = if has_gauss { Some(r.f64()?) } else { None };
    let rate_counts = r.f64s(rate_counts_len)?;
    let history = r.f64s(history_len)?;
    let improvement_iters = r.u32s(improvements_len)?;
    let improvement_vals = r.f64s(improvements_len)?;
    for &it in &improvement_iters {
        if it as usize >= next_iter {
            bail!(
                "train checkpoint records a stopper decision at iteration \
                 {it} but only {next_iter} iterations completed"
            );
        }
    }
    let improvements: Vec<(u32, f64)> = improvement_iters
        .into_iter()
        .zip(improvement_vals)
        .collect();
    let prev_raw = r.u32s(prev_batch_len)?;
    if let Some(&bad) = prev_raw.iter().find(|&&p| p as usize >= n) {
        bail!(
            "train checkpoint carry batch references dataset row {bad} but \
             the dataset has only {n} rows"
        );
    }
    let prev_batch: Vec<usize> = prev_raw.into_iter().map(|p| p as usize).collect();
    let mut windows = Vec::with_capacity(metas.len());
    for m in &metas {
        let mut entries = Vec::with_capacity(m.entry_lens.len());
        for &len in &m.entry_lens {
            let points = r.u32s(len)?;
            if let Some(&bad) = points.iter().find(|&&p| p as usize >= n) {
                bail!(
                    "train checkpoint window references dataset row {bad} \
                     but the dataset has only {n} rows"
                );
            }
            let raws = r.f64s(len)?;
            entries.push((points, raws));
        }
        let scale = r.f64()?;
        let init_point = if m.has_init {
            if m.init_idx as usize >= n {
                bail!(
                    "train checkpoint window init point {} is outside the \
                     {n}-row dataset",
                    m.init_idx
                );
            }
            Some((m.init_idx, r.f64()?))
        } else {
            None
        };
        let cc_cache = if m.has_cc { Some(r.f64()?) } else { None };
        windows.push(WindowState {
            entries,
            scale,
            init_point,
            tau,
            cc_cache,
            updates_since_exact: m.updates_since_exact,
        });
    }
    r.done()?;
    let snap = TrainSnapshot {
        next_iter,
        rng: Rng::from_state(rng_words, gauss_cache),
        windows,
        rate_kind,
        rate_counts,
        history,
        improvements,
        prev_batch,
    };
    Ok((snap, TrainMeta { fingerprint, n }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::util::rng::Rng;

    fn tiny_model(kernel: KernelFunction) -> KernelKMeansModel {
        let mut rng = Rng::seeded(41);
        let ds = blobs(&SyntheticSpec::new(30, 3, 2), &mut rng);
        let mut windows: Vec<CenterWindow> =
            (0..2).map(|j| CenterWindow::new(j * 5, 9)).collect();
        for step in 0..6 {
            for w in windows.iter_mut() {
                let pts: Vec<usize> =
                    (0..1 + step % 3).map(|_| rng.below(ds.n)).collect();
                w.apply_update(0.5, &pts, None);
            }
        }
        KernelKMeansModel::freeze(&ds, kernel, &mut windows)
    }

    #[test]
    fn model_roundtrip_is_bit_identical_for_every_kernel() {
        for kernel in [
            KernelFunction::Gaussian { kappa: 3.5 },
            KernelFunction::Laplacian { sigma: 1.25 },
            KernelFunction::Polynomial { gamma: 0.5, coef0: 1.0, degree: 3 },
            KernelFunction::Linear,
        ] {
            let model = tiny_model(kernel);
            let bytes = model_to_bytes(&model);
            let back = model_from_bytes(&bytes).expect("roundtrip");
            assert_eq!(back.kernel, model.kernel);
            assert_eq!(model_to_bytes(&back), bytes, "{kernel:?}");
        }
    }

    #[test]
    fn loader_rejects_bad_magic_version_and_kind() {
        let model = tiny_model(KernelFunction::Linear);
        let good = model_to_bytes(&model);

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        let err = model_from_bytes(&bad_magic).unwrap_err();
        assert!(format!("{err}").contains("magic"), "{err}");

        // Patch the version inside the JSON header (CRCs recomputed, so
        // the version check is what fires, not the checksum).
        let v99 = patch_header(&good, "\"format_version\":2", "\"format_version\":99");
        let err = model_from_bytes(&v99).unwrap_err();
        assert!(format!("{err}").contains("version 99"), "{err}");

        // A model artifact must not open as a stream checkpoint.
        let err = stream_from_bytes(&good).unwrap_err();
        assert!(format!("{err}").contains("kind"), "{err}");
    }

    #[test]
    fn loader_errors_on_every_truncation_point() {
        let model = tiny_model(KernelFunction::Gaussian { kappa: 2.0 });
        let good = model_to_bytes(&model);
        for len in 0..good.len() {
            assert!(
                model_from_bytes(&good[..len]).is_err(),
                "prefix of {len}/{} bytes must fail to parse",
                good.len()
            );
        }
        let mut long = good.clone();
        long.push(0);
        assert!(model_from_bytes(&long).is_err(), "trailing bytes must fail");
    }

    #[test]
    fn stream_roundtrip_preserves_every_byte() {
        let mut rng = Rng::seeded(5);
        let ds = blobs(&SyntheticSpec::new(400, 4, 3), &mut rng);
        let mut s = StreamingKernelKMeans::new(
            KernelFunction::Gaussian { kappa: 5.0 },
            ds.d,
            3,
            32,
            20,
            LearningRate::Sklearn,
        );
        for _ in 0..8 {
            let idx = rng.sample_with_replacement(ds.n, 32);
            let mut rows = Vec::with_capacity(32 * ds.d);
            for &i in &idx {
                rows.extend_from_slice(ds.row(i));
            }
            s.partial_fit(&rows, &mut rng);
        }
        let bytes = stream_to_bytes(&s);
        let back = stream_from_bytes(&bytes).expect("roundtrip");
        assert_eq!(back.iterations, s.iterations);
        assert_eq!(stream_to_bytes(&back), bytes);
    }

    #[test]
    fn fresh_stream_snapshot_roundtrips() {
        // Before the first batch there are no windows; the checkpoint must
        // still round-trip (has_windows=false).
        let s = StreamingKernelKMeans::new(
            KernelFunction::Linear,
            2,
            4,
            16,
            10,
            LearningRate::Beta,
        );
        let bytes = stream_to_bytes(&s);
        let back = stream_from_bytes(&bytes).expect("roundtrip");
        assert_eq!(back.iterations, 0);
        assert_eq!(stream_to_bytes(&back), bytes);
    }

    /// Header length of a serialized artifact.
    fn hlen_of(bytes: &[u8]) -> usize {
        u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize
    }

    /// The JSON header text of a v2 artifact.
    fn header_of(bytes: &[u8]) -> &str {
        std::str::from_utf8(&bytes[12..12 + hlen_of(bytes)]).unwrap()
    }

    /// The payload section of a v2 artifact (between the two CRC words).
    fn payload_of(bytes: &[u8]) -> &[u8] {
        &bytes[12 + hlen_of(bytes) + 4..bytes.len() - 4]
    }

    /// Assemble a well-formed v2 artifact from raw header text + payload,
    /// recomputing both CRCs — the header/payload may be deliberately
    /// inconsistent, but the checksums are valid so the *structural*
    /// validation under test is what fires.
    fn rebuild_v2(header: &str, payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        let hcrc = crc32(&out);
        out.extend_from_slice(&hcrc.to_le_bytes());
        out.extend_from_slice(payload);
        out.extend_from_slice(&crc32(payload).to_le_bytes());
        out
    }

    /// Rebuild an artifact with one header substring replaced (length
    /// prefix and checksums recomputed), leaving the payload untouched.
    fn patch_header(bytes: &[u8], from: &str, to: &str) -> Vec<u8> {
        let header = header_of(bytes);
        let patched = header.replace(from, to);
        assert_ne!(patched, header, "patch {from:?} must hit the header");
        rebuild_v2(&patched, payload_of(bytes))
    }

    #[test]
    fn stream_loader_enforces_writer_invariants() {
        // A checkpoint whose header is internally consistent for the size
        // pre-check but violates writer invariants (k vs rate counters vs
        // window count) must fail at load, not panic inside partial_fit.
        let mut rng = Rng::seeded(13);
        let ds = blobs(&SyntheticSpec::new(100, 3, 2), &mut rng);
        let mut s = StreamingKernelKMeans::new(
            KernelFunction::Gaussian { kappa: 4.0 },
            ds.d,
            3,
            16,
            10,
            LearningRate::Sklearn,
        );
        let idx = rng.sample_with_replacement(ds.n, 16);
        let mut rows = Vec::new();
        for &i in &idx {
            rows.extend_from_slice(ds.row(i));
        }
        s.partial_fit(&rows, &mut rng);
        let good = stream_to_bytes(&s);
        // k inflated: the 3 rate counters no longer cover 99 centers.
        let err = stream_from_bytes(&patch_header(&good, "\"k\":3", "\"k\":99")).unwrap_err();
        assert!(format!("{err}").contains("learning-rate counters"), "{err}");
        // More advertised counters than centers.
        let err = stream_from_bytes(&patch_header(
            &good,
            "\"rate_counts\":3",
            "\"rate_counts\":4",
        ))
        .unwrap_err();
        assert!(format!("{err}").contains("learning-rate counters"), "{err}");
        // Initialized stream with an empty window list.
        let fresh = StreamingKernelKMeans::new(
            KernelFunction::Linear,
            2,
            2,
            8,
            5,
            LearningRate::Beta,
        );
        let err = stream_from_bytes(&patch_header(
            &stream_to_bytes(&fresh),
            "\"has_windows\":false",
            "\"has_windows\":true",
        ))
        .unwrap_err();
        assert!(format!("{err}").contains("windows"), "{err}");
    }

    #[test]
    fn stream_loader_rejects_out_of_range_indices() {
        let mut rng = Rng::seeded(6);
        let ds = blobs(&SyntheticSpec::new(100, 3, 2), &mut rng);
        let mut s = StreamingKernelKMeans::new(
            KernelFunction::Gaussian { kappa: 4.0 },
            ds.d,
            2,
            16,
            10,
            LearningRate::Beta,
        );
        let idx = rng.sample_with_replacement(ds.n, 16);
        let mut rows = Vec::new();
        for &i in &idx {
            rows.extend_from_slice(ds.row(i));
        }
        s.partial_fit(&rows, &mut rng);
        let good = stream_to_bytes(&s);
        // Shrink the advertised reservoir without touching the windows:
        // the header is rebuilt with store_n=0 and an empty feature block
        // (checksums recomputed so index validation is what fires).
        let header = header_of(&good);
        let store_n = s.stored_rows();
        let patched = header.replace(&format!("\"store_n\":{store_n}"), "\"store_n\":0");
        assert_ne!(patched, header, "test patch must hit the header");
        let tampered = rebuild_v2(&patched, &payload_of(&good)[store_n * ds.d * 4..]);
        let err = stream_from_bytes(&tampered).unwrap_err();
        assert!(
            format!("{err}").contains("reservoir") || format!("{err}").contains("init point"),
            "{err}"
        );
    }

    /// The six sections of a v2 artifact as `(name, start, end)` byte
    /// ranges.
    fn section_bounds(bytes: &[u8]) -> Vec<(&'static str, usize, usize)> {
        let h = hlen_of(bytes);
        vec![
            ("magic", 0, 8),
            ("hlen", 8, 12),
            ("header", 12, 12 + h),
            ("header_crc", 12 + h, 16 + h),
            ("payload", 16 + h, bytes.len() - 4),
            ("payload_crc", bytes.len() - 4, bytes.len()),
        ]
    }

    #[test]
    fn corruption_matrix_detects_torn_and_flipped_artifacts() {
        // Truncate at every section boundary and bit-flip bytes in every
        // section, for both artifact kinds: the loader must return an
        // error each time — never panic, never a silently wrong model.
        let model = tiny_model(KernelFunction::Gaussian { kappa: 2.0 });
        let model_bytes = model_to_bytes(&model);
        let mut rng = Rng::seeded(23);
        let ds = blobs(&SyntheticSpec::new(120, 3, 2), &mut rng);
        let mut s = StreamingKernelKMeans::new(
            KernelFunction::Gaussian { kappa: 4.0 },
            ds.d,
            2,
            16,
            12,
            LearningRate::Beta,
        );
        let idx = rng.sample_with_replacement(ds.n, 16);
        let mut rows = Vec::new();
        for &i in &idx {
            rows.extend_from_slice(ds.row(i));
        }
        s.partial_fit(&rows, &mut rng);
        let stream_bytes = stream_to_bytes(&s);

        let cases: Vec<(&str, &[u8], Box<dyn Fn(&[u8]) -> bool>)> = vec![
            ("model", &model_bytes, Box::new(|b| model_from_bytes(b).is_err())),
            ("stream", &stream_bytes, Box::new(|b| stream_from_bytes(b).is_err())),
        ];
        for (kind, good, fails) in cases {
            for (name, start, end) in section_bounds(good) {
                for cut in [start, end] {
                    if cut < good.len() {
                        assert!(
                            fails(&good[..cut]),
                            "{kind}: truncation at {name} boundary {cut} must fail"
                        );
                    }
                }
                // One byte per section, first and middle, every bit edge.
                for byte in [start, (start + end) / 2] {
                    for bit in [0u8, 7] {
                        let mut bad = good.to_vec();
                        bad[byte] ^= 1 << bit;
                        assert!(
                            fails(&bad),
                            "{kind}: bit {bit} flip in {name} at byte {byte} \
                             must be detected"
                        );
                    }
                }
            }
        }
    }

    /// Build the v1 (unchecksummed) layout from a v2 artifact's parts.
    fn downgrade_to_v1(bytes: &[u8]) -> Vec<u8> {
        let header =
            header_of(bytes).replace("\"format_version\":2", "\"format_version\":1");
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(payload_of(bytes));
        out
    }

    #[test]
    fn v1_artifacts_still_load() {
        // Back-compat: artifacts written by the PR 4-7 builds (version 1,
        // no CRC sections) load, and re-serializing upgrades them to v2
        // bit-identically to a native v2 write.
        let model = tiny_model(KernelFunction::Laplacian { sigma: 1.5 });
        let v2 = model_to_bytes(&model);
        let back = model_from_bytes(&downgrade_to_v1(&v2)).expect("v1 model must load");
        assert_eq!(model_to_bytes(&back), v2);

        let mut rng = Rng::seeded(31);
        let ds = blobs(&SyntheticSpec::new(150, 3, 2), &mut rng);
        let mut s = StreamingKernelKMeans::new(
            KernelFunction::Gaussian { kappa: 3.0 },
            ds.d,
            2,
            16,
            10,
            LearningRate::Sklearn,
        );
        let idx = rng.sample_with_replacement(ds.n, 16);
        let mut rows = Vec::new();
        for &i in &idx {
            rows.extend_from_slice(ds.row(i));
        }
        s.partial_fit(&rows, &mut rng);
        let v2 = stream_to_bytes(&s);
        let back = stream_from_bytes(&downgrade_to_v1(&v2)).expect("v1 stream must load");
        assert_eq!(stream_to_bytes(&back), v2);
    }

    /// A real mid-fit snapshot (nested schedule + ε-stopper engaged so
    /// every optional field is populated), plus the dataset size.
    fn training_snapshot() -> (TrainSnapshot, usize) {
        use crate::kkmeans::{
            NativeBackend, ScheduleSpec, TruncatedConfig, TruncatedMiniBatchKernelKMeans,
        };
        let mut rng = Rng::seeded(77);
        let ds = blobs(&SyntheticSpec::new(200, 3, 2), &mut rng);
        let gram = crate::kernels::Gram::on_the_fly(
            &ds,
            KernelFunction::Gaussian { kappa: 10.0 },
        );
        let cfg = TruncatedConfig {
            k: 2,
            batch_size: 24,
            schedule: ScheduleSpec::Nested { growth: 1.5 },
            tau: 40,
            max_iters: 12,
            epsilon: Some(1e-12),
            ..Default::default()
        };
        let mut snaps = Vec::new();
        let mut fit_rng = Rng::seeded(3);
        TruncatedMiniBatchKernelKMeans::new(cfg)
            .fit_with_backend_resumable(
                &gram,
                &mut NativeBackend,
                &mut fit_rng,
                None,
                4,
                &mut |s| {
                    snaps.push(s.clone());
                    Ok(())
                },
            )
            .unwrap();
        (snaps.pop().expect("cadence must snapshot"), ds.n)
    }

    #[test]
    fn train_roundtrip_is_bit_identical() {
        let (snap, n) = training_snapshot();
        let bytes = train_to_bytes(&snap, "spec:test-fingerprint", n);
        let (back, meta) = train_from_bytes(&bytes).expect("roundtrip");
        assert_eq!(meta.fingerprint, "spec:test-fingerprint");
        assert_eq!(meta.n, n);
        assert_eq!(back.next_iter, snap.next_iter);
        assert_eq!(train_to_bytes(&back, &meta.fingerprint, meta.n), bytes);
        // Kind cross-check: a train checkpoint is not a model.
        assert!(model_from_bytes(&bytes).is_err());
    }

    #[test]
    fn train_loader_enforces_writer_invariants() {
        let (snap, n) = training_snapshot();
        let good = train_to_bytes(&snap, "fp", n);
        let err =
            train_from_bytes(&patch_header(&good, "\"k\":2", "\"k\":0")).unwrap_err();
        assert!(format!("{err}").contains("k=0"), "{err}");
        let err = train_from_bytes(&patch_header(
            &good,
            &format!("\"next_iter\":{}", snap.next_iter),
            "\"next_iter\":1",
        ))
        .unwrap_err();
        assert!(format!("{err}").contains("history"), "{err}");
        let err = train_from_bytes(&patch_header(&good, "\"rate_counts\":2", "\"rate_counts\":3"))
            .unwrap_err();
        assert!(format!("{err}").contains("learning-rate"), "{err}");
        // Every truncation of a train checkpoint fails too.
        for len in 0..good.len() {
            assert!(train_from_bytes(&good[..len]).is_err(), "prefix {len} must fail");
        }
    }

    #[test]
    fn model_shard_plan_roundtrips_and_is_ignored_by_the_loader() {
        let model = tiny_model(KernelFunction::Gaussian { kappa: 2.0 });
        let plain = model_to_bytes(&model);
        assert_eq!(model_shard_plan(&plain).unwrap(), None);
        let sharded = model_to_bytes_with_plan(&model, Some(&[0, 1, 2]));
        assert_eq!(model_shard_plan(&sharded).unwrap(), Some(vec![0, 1, 2]));
        // The plan is header-only serving metadata: the model loader reads
        // a planned artifact to the identical model.
        let back = model_from_bytes(&sharded).expect("planned artifact must load");
        assert_eq!(model_to_bytes(&back), plain);
        // Malformed plans are loader errors, not panics.
        let bad = patch_header(&sharded, "\"shards\":[0,1,2]", "\"shards\":[0,\"x\",2]");
        assert!(model_shard_plan(&bad).is_err());
    }

    /// A streaming fit advanced past a captured base: the primary, a
    /// full snapshot taken at the base generation (the stale replica),
    /// and the delta between them — non-trivial on every axis (appended
    /// rows, trimmed windows, live scalars).
    fn delta_fixture() -> (StreamingKernelKMeans, Vec<u8>, LogDelta) {
        use crate::serve::replicate::{capture_base, delta_from};
        let mut rng = Rng::seeded(91);
        let ds = blobs(&SyntheticSpec::new(300, 4, 3), &mut rng);
        let mut s = StreamingKernelKMeans::new(
            KernelFunction::Gaussian { kappa: 5.0 },
            ds.d,
            3,
            24,
            10,
            LearningRate::Sklearn,
        );
        let mut feed = |s: &mut StreamingKernelKMeans, rng: &mut Rng| {
            let idx = rng.sample_with_replacement(ds.n, 24);
            let mut rows = Vec::with_capacity(24 * ds.d);
            for &i in &idx {
                rows.extend_from_slice(ds.row(i));
            }
            s.partial_fit(&rows, rng);
        };
        for _ in 0..4 {
            feed(&mut s, &mut rng);
        }
        let base_snapshot = stream_to_bytes(&s);
        let base = capture_base(&s);
        for _ in 0..3 {
            feed(&mut s, &mut rng);
        }
        let delta = delta_from(&s, &base).expect("append-only history must delta");
        (s, base_snapshot, delta)
    }

    #[test]
    fn delta_roundtrip_is_bit_identical_and_replays() {
        use crate::serve::replicate::apply_delta;
        let (primary, base_snapshot, delta) = delta_fixture();
        let bytes = delta_to_bytes(&delta);
        let back = delta_from_bytes(&bytes).expect("roundtrip");
        assert_eq!(back, delta);
        assert_eq!(delta_to_bytes(&back), bytes);
        // Kind cross-check: a delta is not a stream checkpoint.
        let err = stream_from_bytes(&bytes).unwrap_err();
        assert!(format!("{err}").contains("kind"), "{err}");
        // End-to-end through the container: a replica resumed from the
        // base-generation snapshot, caught up via the *decoded* delta, is
        // byte-identical to the primary.
        let mut replica = stream_from_bytes(&base_snapshot).unwrap();
        apply_delta(&mut replica, &back).expect("replay");
        assert_eq!(stream_to_bytes(&replica), stream_to_bytes(&primary));
    }

    #[test]
    fn delta_loader_rejects_corruption_and_bad_structure() {
        let (_primary, _base_snapshot, delta) = delta_fixture();
        let good = delta_to_bytes(&delta);
        for len in 0..good.len() {
            assert!(
                delta_from_bytes(&good[..len]).is_err(),
                "prefix of {len}/{} bytes must fail",
                good.len()
            );
        }
        for byte in [0, good.len() / 2, good.len() - 1] {
            let mut bad = good.clone();
            bad[byte] ^= 0x10;
            assert!(delta_from_bytes(&bad).is_err(), "flip at {byte} must be detected");
        }
        // Structural invariants fire with valid checksums.
        let err = delta_from_bytes(&patch_header(&good, "\"k\":3", "\"k\":0")).unwrap_err();
        assert!(format!("{err}").contains("k=0"), "{err}");
        let err = delta_from_bytes(&patch_header(
            &good,
            &format!("\"base_iterations\":{}", delta.base_generation()),
            &format!("\"base_iterations\":{}", delta.generation() + 1),
        ))
        .unwrap_err();
        assert!(format!("{err}").contains("backwards"), "{err}");
    }

    #[test]
    fn atomic_write_replaces_and_leaves_no_litter() {
        let dir = std::env::temp_dir().join(format!("mbkk-aw-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("artifact.mbkk");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second, longer payload").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second, longer payload");
        let litter: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|f| f.contains(".tmp."))
            .collect();
        assert!(litter.is_empty(), "temp files left behind: {litter:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn atomic_write_failure_preserves_previous_file() {
        use crate::util::failpoint;
        let _guard = failpoint::exclusive_test_lock();
        let dir = std::env::temp_dir().join(format!("mbkk-awf-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("artifact.mbkk");
        atomic_write(&path, b"durable").unwrap();
        for point in ["artifact.write.tmp", "artifact.write.fsync"] {
            failpoint::configure(&format!("{point}=1*err(injected write fault)")).unwrap();
            let err = atomic_write(&path, b"torn").unwrap_err();
            assert!(format!("{err}").contains("injected write fault"), "{err}");
            failpoint::clear(point);
            assert_eq!(
                std::fs::read(&path).unwrap(),
                b"durable",
                "{point}: target must be untouched after a failed write"
            );
            let litter: Vec<String> = std::fs::read_dir(&dir)
                .unwrap()
                .filter_map(|e| e.ok())
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .filter(|f| f.contains(".tmp."))
                .collect();
            assert!(litter.is_empty(), "{point}: temp litter {litter:?}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
