//! The zero-dependency HTTP/1.1 prediction service (DESIGN.md §11/§14,
//! docs/API.md).
//!
//! ```text
//! TcpListener (nonblocking accept loop, polls shutdown + artifact watches)
//!    └─ per-connection thread (keep-alive loop)
//!         ├─ wire::read_head / read_body   bounded framing, 100-continue
//!         ├─ json::lazy                    offset-based "points" extraction
//!         ├─ ModelRegistry                 `?model=` routing + hot-swap
//!         ├─ Coalescer                     deadline-batched admission queue
//!         │     └─ Scorer                  PredictEngine, or a ShardSet
//!         │                                fanning out to shard replicas
//!         └─ wire::Response                single-write JSON response
//! ```
//!
//! Endpoints: `POST /v1/predict`, `GET /v1/models`, `GET /healthz` — the
//! request/response schemas, error envelope, and coalescing semantics are
//! documented in docs/API.md and pinned by `rust/tests/conformance_http.rs`
//! and `rust/tests/conformance_shard.rs`.
//!
//! Guarantees:
//!
//! * **Never panics on client bytes.** Framing and JSON errors map to 4xx
//!   envelopes; routing runs under `catch_unwind` so even an internal bug
//!   answers 500 and closes that one connection.
//! * **Bit-identity.** A row scored over HTTP gets exactly the assignment
//!   the CLI's `predict --scalar` computes for the same text: the lazy
//!   parser converts number tokens with the CSV loader's single-rounding
//!   `parse::<f32>` and the coalescer inherits the engine's batch-shape
//!   invariance. Sharded serving preserves this: the fixed-shard-order
//!   merge reproduces the single-node distance matrix bitwise
//!   (`serve::shard` docs), so a fully-covered sharded answer is
//!   byte-equal to an unsharded one.
//! * **Bounded resources.** Head and body caps, a connection ceiling
//!   (503 above it), and read timeouts on every accepted socket.
//!
//! Connection handling is thread-per-connection on `std::thread` — *not*
//! the compute worker pool, which stays dedicated to `PredictEngine`
//! batches and must never block on client sockets (ADR-003).
//!
//! **Degrade, don't die** (ADR-004, ADR-006): the server carries an
//! explicit health state machine — `starting → serving → draining`, with
//! a time-windowed `degraded` overlay entered whenever an internal fault
//! is contained. Each fault records a **structured cause code**
//! (`internal_panic`, `connection_fault`, `prediction_failed`,
//! `shard_unavailable`, `partial_results`) held for a configurable window
//! (`--degraded-window-s`); a currently-ejected shard replica contributes
//! the live cause `replica_ejected` for as long as it stays ejected.
//! `/healthz` reports status truthfully with the cause list and per-shard
//! replica detail; 503 while starting or draining (with `Retry-After`).
//! Load is shed with 503 + `Retry-After` at the connection ceiling and
//! when a request blows its deadline budget before admission.
//! Fault-injection hooks (`http.accept`, `http.read`, `http.write`,
//! `shard.dispatch`, `shard.merge`, `replica.probe` — see
//! `util::failpoint`) prove the blast radius — pinned by the CI chaos
//! sweep.

use std::collections::BTreeMap;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::coalesce::{CoalesceConfig, Coalescer, ScoreError, StatsSnapshot};
use super::engine::PredictEngine;
use super::format;
use super::replicate::{ArtifactWatch, ModelRegistry};
use super::shard::{HttpShardWorker, LocalShardWorker, ShardPlan, ShardSet, ShardSetConfig, ShardWorker};
use super::wire::{self, RequestHead, Response, WireError};
use crate::kkmeans::KernelKMeansModel;
use crate::util::error::{Context, Result};
use crate::util::failpoint;
use crate::util::json::{lazy, Json};
use crate::util::simd::NumericsMode;

/// How often the accept loop re-checks the shutdown flag when idle.
const ACCEPT_POLL: Duration = Duration::from_millis(2);
/// How long shutdown waits for in-flight connections to finish.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(5);
/// How often the accept loop polls artifact watches for hot-swaps.
const REFRESH_INTERVAL: Duration = Duration::from_secs(1);

/// Health phases (the `degraded` overlay is a cause map, not a phase —
/// a fault must not mask a concurrent drain).
const PHASE_STARTING: u8 = 0;
const PHASE_SERVING: u8 = 1;
const PHASE_DRAINING: u8 = 2;

/// Server configuration (`mbkk serve` flags map onto these fields).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:8605` (port 0 picks a free port).
    pub addr: String,
    /// Coalescing deadline: how long a batch leader waits for company.
    pub max_wait: Duration,
    /// Flush threshold / bypass size, in rows.
    pub max_batch_rows: usize,
    /// Request body cap in bytes (413 above it).
    pub max_body_bytes: usize,
    /// Per-socket read/write timeout.
    pub read_timeout: Duration,
    /// Concurrent-connection ceiling (503 above it).
    pub max_connections: usize,
    /// Per-request deadline budget: a predict request that spends longer
    /// than this between arrival and admission (slow body upload, parse)
    /// is shed with 503 + `Retry-After` instead of queueing stale work.
    pub request_deadline: Duration,
    /// Numerics mode the prediction engine serves under (`--numerics`).
    /// Fast is safe for serving: distances move within the exp ulp
    /// budget, assignments effectively never (DESIGN.md §13).
    pub numerics: NumericsMode,
    /// How long `/healthz` keeps reporting a contained fault's cause
    /// code (`--degraded-window-s`).
    pub degraded_window: Duration,
    /// Shard the support set into this many contiguous center ranges
    /// (0 = unsharded single-engine serving). `shard_plan` overrides the
    /// even split; `shard_workers` implies one shard per worker address.
    pub shards: usize,
    /// Explicit shard bounds (`0, …, k`), e.g. recorded in the model
    /// artifact header — overrides the even `shards` split.
    pub shard_plan: Option<Vec<usize>>,
    /// In-process replicas per shard. With remote `shard_workers` these
    /// are appended after the remote replica as local failover targets;
    /// 0 then means remote-only (no local fallback).
    pub shard_replicas: usize,
    /// Remote `mbkk shard-worker` addresses, one per shard in shard
    /// order. Empty = all-in-process shards.
    pub shard_workers: Vec<String>,
    /// Merge policy when a shard stays unavailable through every retry:
    /// `false` answers 503 `shard_unavailable`; `true` answers from the
    /// covered centers with `"partial": true` and a coverage fraction.
    pub partial_results: bool,
    /// Dispatch rounds per shard per batch (retry with backoff between).
    pub shard_attempts: u32,
    /// Base backoff between dispatch rounds (exponential, jittered).
    pub shard_backoff: Duration,
    /// Connect/read/write deadline for one remote shard dispatch.
    pub shard_deadline: Duration,
    /// How often the background prober re-checks ejected replicas.
    pub probe_interval: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:8605".to_string(),
            max_wait: CoalesceConfig::default().max_wait,
            max_batch_rows: CoalesceConfig::default().max_batch_rows,
            max_body_bytes: 8 * 1024 * 1024,
            read_timeout: Duration::from_secs(10),
            max_connections: 128,
            request_deadline: Duration::from_secs(5),
            numerics: NumericsMode::Deterministic,
            degraded_window: Duration::from_secs(30),
            shards: 0,
            shard_plan: None,
            shard_replicas: 1,
            shard_workers: Vec::new(),
            partial_results: false,
            shard_attempts: ShardSetConfig::default().attempts,
            shard_backoff: ShardSetConfig::default().backoff,
            shard_deadline: Duration::from_secs(2),
            probe_interval: Duration::from_millis(250),
        }
    }
}

/// One model the server will serve: registry name, the model itself, and
/// optionally the artifact watch that hot-swaps it on version bumps.
pub struct ModelSpec {
    /// Registry name — the `?model=` routing key and `/v1/models` label.
    pub name: String,
    /// The frozen model.
    pub model: KernelKMeansModel,
    /// Watch this artifact; on a content change the serving unit is
    /// rebuilt from the new bytes and swapped in without dropping
    /// in-flight requests.
    pub watch: Option<ArtifactWatch>,
}

/// Everything one served model needs to answer queries: the admission
/// queue over its scorer, the shard fleet behind it (if sharded), and
/// prebuilt JSON fragments. Hot-swap replaces the whole unit atomically;
/// in-flight requests finish on the old one (they hold its `Arc`).
struct ServingUnit {
    coalescer: Coalescer,
    shard_set: Option<Arc<ShardSet>>,
    /// Static `/v1/models` entry fields (dynamic fields are merged in per
    /// request).
    meta: Json,
    /// Prebuilt model summary embedded in `/healthz`.
    summary: Json,
}

/// Build a serving unit: a plain engine, or a shard fleet when the config
/// asks for one.
fn build_unit(model: &KernelKMeansModel, name: &str, cfg: &ServeConfig) -> Result<ServingUnit> {
    let ccfg = CoalesceConfig { max_wait: cfg.max_wait, max_batch_rows: cfg.max_batch_rows };
    let sharded = cfg.shards > 0 || cfg.shard_plan.is_some() || !cfg.shard_workers.is_empty();
    let (coalescer, shard_set) = if sharded {
        let plan = match &cfg.shard_plan {
            Some(bounds) => ShardPlan::from_bounds(bounds.clone(), model.k())?,
            None => ShardPlan::contiguous(
                model.k(),
                cfg.shards.max(cfg.shard_workers.len()).max(1),
            ),
        };
        if !cfg.shard_workers.is_empty() && cfg.shard_workers.len() != plan.shards() {
            crate::bail!(
                "{} shard-worker addresses for {} shards (need exactly one per shard)",
                cfg.shard_workers.len(),
                plan.shards()
            );
        }
        let scfg = ShardSetConfig {
            partial_results: cfg.partial_results,
            attempts: cfg.shard_attempts,
            backoff: cfg.shard_backoff,
            ..ShardSetConfig::default()
        };
        let set = if cfg.shard_workers.is_empty() {
            ShardSet::local(model, plan, cfg.shard_replicas.max(1), cfg.numerics, scfg)?
        } else {
            // Remote replica first (it owns the shard), locals after it as
            // failover targets: a dead worker ejects, dispatch falls over
            // to the local copy, and answers stay bit-identical.
            let mut workers: Vec<Vec<Box<dyn ShardWorker>>> = Vec::new();
            for i in 0..plan.shards() {
                let mut reps: Vec<Box<dyn ShardWorker>> = vec![Box::new(HttpShardWorker::new(
                    &cfg.shard_workers[i],
                    &plan,
                    i,
                    cfg.shard_deadline,
                ))];
                for j in 0..cfg.shard_replicas {
                    reps.push(Box::new(LocalShardWorker::new(
                        model,
                        &plan,
                        i,
                        cfg.numerics,
                        &format!("local:{i}.{j}"),
                    )));
                }
                workers.push(reps);
            }
            ShardSet::from_workers(model.d, plan, workers, scfg)?
        };
        let set = Arc::new(set);
        (Coalescer::new(Arc::clone(&set), ccfg), Some(set))
    } else {
        let engine = PredictEngine::with_mode(model, cfg.numerics);
        (Coalescer::new(engine, ccfg), None)
    };
    let mut meta_fields = vec![
        ("name", Json::Str(name.to_string())),
        ("kind", Json::Str("model".to_string())),
        ("format_version", Json::Num(format::FORMAT_VERSION as f64)),
        ("kernel", format::kernel_to_json(model.kernel)),
        ("k", Json::Num(model.k() as f64)),
        ("d", Json::Num(model.d as f64)),
        ("support_points", Json::Num(model.support_points() as f64)),
    ];
    if let Some(set) = &shard_set {
        meta_fields.push((
            "shards",
            Json::arr_num(set.plan().bounds().iter().map(|&b| b as f64)),
        ));
    }
    let meta = Json::obj(meta_fields);
    let summary = Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("k", Json::Num(model.k() as f64)),
        ("d", Json::Num(model.d as f64)),
    ]);
    Ok(ServingUnit { coalescer, shard_set, meta, summary })
}

struct ServerState {
    registry: ModelRegistry<ServingUnit>,
    /// The serving configuration, kept for hot-swap rebuilds.
    cfg: ServeConfig,
    shutdown: Arc<AtomicBool>,
    active: AtomicUsize,
    /// Health phase: starting / serving / draining.
    phase: AtomicU8,
    /// Instant the state was built — the zero point for the cause map.
    started: Instant,
    /// Contained-fault cause codes → millis-since-`started` until which
    /// each keeps `/healthz` degraded. Written by [`note_degraded`].
    degraded: Mutex<BTreeMap<&'static str, u64>>,
    /// Requests shed before admission (deadline blown, draining).
    shed: AtomicU64,
}

impl ServerState {
    fn now_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    /// Cause codes currently holding the server degraded: every windowed
    /// fault cause still fresh, plus the live `replica_ejected` condition
    /// while any shard replica is out of dispatch.
    fn live_causes(&self) -> Vec<&'static str> {
        let now = self.now_ms();
        let mut causes: Vec<&'static str> = self
            .degraded
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .iter()
            .filter(|&(_, &until)| now < until)
            .map(|(&cause, _)| cause)
            .collect();
        let ejected = self.registry.entries().iter().any(|e| {
            e.unit().shard_set.as_ref().is_some_and(|s| s.any_ejected())
        });
        if ejected && !causes.contains(&"replica_ejected") {
            causes.push("replica_ejected");
        }
        causes
    }

    /// `"starting" | "ok" | "degraded" | "draining"` — the serving phase
    /// with the fault causes overlaid (a drain outranks them).
    fn health_status(&self) -> &'static str {
        match self.phase.load(Ordering::SeqCst) {
            PHASE_STARTING => "starting",
            PHASE_DRAINING => "draining",
            _ if !self.live_causes().is_empty() => "degraded",
            _ => "ok",
        }
    }
}

/// Open (or extend) the degraded window for one structured cause code.
fn note_degraded(state: &ServerState, cause: &'static str) {
    let until = state.now_ms() + state.cfg.degraded_window.as_millis() as u64;
    let mut map = state.degraded.lock().unwrap_or_else(|p| p.into_inner());
    let entry = map.entry(cause).or_insert(0);
    *entry = (*entry).max(until);
}

/// A bound, not-yet-running prediction server.
pub struct Server {
    listener: TcpListener,
    read_timeout: Duration,
    state: Arc<ServerState>,
}

/// Decrements the active-connection counter even if a handler unwinds.
struct ActiveGuard(Arc<ServerState>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.active.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Server {
    /// Build the engine + admission queue for one model and bind the
    /// listen socket. `source` labels the model in `/v1/models` and
    /// `/healthz` (the artifact path, or a synthetic label for
    /// fit-on-the-fly models).
    pub fn bind(model: &KernelKMeansModel, source: &str, cfg: &ServeConfig) -> Result<Server> {
        Server::bind_registry(
            vec![ModelSpec { name: source.to_string(), model: model.clone(), watch: None }],
            cfg,
        )
    }

    /// Bind a multi-model server. The first spec is the default model
    /// (requests without `?model=` route to it); watched specs hot-swap
    /// when their artifact changes on disk.
    pub fn bind_registry(specs: Vec<ModelSpec>, cfg: &ServeConfig) -> Result<Server> {
        if specs.is_empty() {
            crate::bail!("the server needs at least one model to serve");
        }
        let mut registry = ModelRegistry::new();
        for spec in specs {
            let unit = build_unit(&spec.model, &spec.name, cfg)?;
            let version = spec.watch.as_ref().map(|w| w.version() as u64).unwrap_or(0);
            registry.register(&spec.name, unit, version, spec.watch)?;
        }
        let listener = TcpListener::bind(cfg.addr.as_str())
            .with_context(|| format!("binding http listener on {}", cfg.addr))?;
        Ok(Server {
            listener,
            read_timeout: cfg.read_timeout,
            state: Arc::new(ServerState {
                registry,
                cfg: cfg.clone(),
                shutdown: Arc::new(AtomicBool::new(false)),
                active: AtomicUsize::new(0),
                phase: AtomicU8::new(PHASE_STARTING),
                started: Instant::now(),
                degraded: Mutex::new(BTreeMap::new()),
                shed: AtomicU64::new(0),
            }),
        })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading the bound address")
    }

    /// Handle to the shutdown flag: store `true` (e.g. from a SIGTERM
    /// handler or a test) and `run` drains connections and returns.
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.state.shutdown)
    }

    /// Accept loop. Returns the default model's final service counters
    /// once the shutdown flag is set and in-flight connections have
    /// drained (or the drain timeout passes).
    pub fn run(self) -> Result<StatsSnapshot> {
        let state = self.state;
        self.listener
            .set_nonblocking(true)
            .context("setting the listener nonblocking")?;
        // Background prober: re-checks ejected shard replicas so a
        // recovered worker re-enters dispatch without waiting for live
        // traffic to find it.
        let prober = if state.registry.entries().iter().any(|e| e.unit().shard_set.is_some()) {
            let st = Arc::clone(&state);
            Some(
                std::thread::Builder::new()
                    .name("mbkk-probe".to_string())
                    .spawn(move || {
                        let step = Duration::from_millis(50);
                        while !st.shutdown.load(Ordering::SeqCst) {
                            let mut waited = Duration::ZERO;
                            while waited < st.cfg.probe_interval
                                && !st.shutdown.load(Ordering::SeqCst)
                            {
                                std::thread::sleep(step);
                                waited += step;
                            }
                            for entry in st.registry.entries() {
                                if let Some(set) = &entry.unit().shard_set {
                                    set.probe_ejected();
                                }
                            }
                        }
                    })
                    .context("spawning the shard probe thread")?,
            )
        } else {
            None
        };
        state.phase.store(PHASE_SERVING, Ordering::SeqCst);
        let mut last_refresh = Instant::now();
        while !state.shutdown.load(Ordering::SeqCst) {
            if last_refresh.elapsed() >= REFRESH_INTERVAL {
                last_refresh = Instant::now();
                refresh_models(&state);
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    // Accept-boundary fault injection: whatever the armed
                    // action, the blast radius is THIS connection — the
                    // accept loop itself must never exit on a fault
                    // (chaos CI pins the process staying alive).
                    if failpoint::armed() {
                        if let Some(fault) = failpoint::eval("http.accept") {
                            let msg = match fault {
                                failpoint::Fault::Panic => "injected panic".to_string(),
                                failpoint::Fault::Err(m) => m,
                            };
                            eprintln!("mbkk-serve: dropped a connection (failpoint http.accept: {msg})");
                            note_degraded(&state, "connection_fault");
                            continue;
                        }
                    }
                    if state.active.load(Ordering::SeqCst) >= state.cfg.max_connections {
                        let mut s = stream;
                        let _ = s.set_nonblocking(false);
                        let _ = Response::error(
                            503,
                            "server_overloaded",
                            "connection limit reached; retry shortly",
                        )
                        .retry_after(1)
                        .closing()
                        .write_to(&mut s);
                        continue;
                    }
                    if stream.set_nonblocking(false).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let _ = stream.set_read_timeout(Some(self.read_timeout));
                    let _ = stream.set_write_timeout(Some(self.read_timeout));
                    state.active.fetch_add(1, Ordering::SeqCst);
                    let guard = ActiveGuard(Arc::clone(&state));
                    let st = Arc::clone(&state);
                    let spawned = std::thread::Builder::new()
                        .name("mbkk-http".to_string())
                        .spawn(move || {
                            let _guard = guard;
                            handle_connection(&st, stream);
                        });
                    if spawned.is_err() {
                        // ActiveGuard moved into the dead closure was
                        // dropped by the failed spawn, decrementing for us.
                        continue;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::ConnectionAborted | std::io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => return Err(e).context("accepting a connection"),
            }
        }
        // Drain: stop accepting (loop exited), flush the in-flight
        // coalesced accumulation immediately instead of letting it wait
        // out `max_wait`, and give connection threads the drain window to
        // finish. Only if the window closes with tickets still queued do
        // we abort them — counted, so the e2e drain test can assert a
        // graceful shutdown aborts nothing.
        state.phase.store(PHASE_DRAINING, Ordering::SeqCst);
        for entry in state.registry.entries() {
            entry.unit().coalescer.begin_drain();
        }
        let deadline = Instant::now() + DRAIN_TIMEOUT;
        while state.active.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(ACCEPT_POLL);
        }
        let mut aborted = 0;
        for entry in state.registry.entries() {
            aborted += entry.unit().coalescer.abort_pending("server draining; request aborted");
        }
        if aborted > 0 {
            eprintln!("mbkk-serve: aborted {aborted} queued requests at the drain deadline");
        }
        if let Some(handle) = prober {
            let _ = handle.join();
        }
        Ok(state.registry.default_model().unit().coalescer.stats())
    }
}

/// Poll artifact watches; hot-swap any model whose artifact changed. A
/// corrupt or mid-rewrite artifact keeps the previous version serving —
/// logged, never fatal.
fn refresh_models(state: &ServerState) {
    let cfg = &state.cfg;
    let (swapped, errors) = state.registry.refresh(|name, bytes| {
        let model = format::model_from_bytes(bytes).map_err(|e| e.to_string())?;
        let mut ucfg = cfg.clone();
        // A shard plan recorded in the new artifact wins over the CLI's.
        if let Ok(Some(bounds)) = format::model_shard_plan(bytes) {
            ucfg.shard_plan = Some(bounds);
        }
        build_unit(&model, name, &ucfg).map_err(|e| e.to_string())
    });
    for e in errors {
        eprintln!("mbkk-serve: artifact refresh: {e}");
    }
    if swapped > 0 {
        eprintln!("mbkk-serve: hot-swapped {swapped} model(s) on artifact version bump");
    }
}

/// Keep-alive loop for one accepted connection.
fn handle_connection(state: &ServerState, stream: TcpStream) {
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        let head = match wire::read_head(&mut reader) {
            Ok(head) => head,
            Err(WireError::Closed) | Err(WireError::Io(_)) => return,
            Err(WireError::Idle) => {
                if state.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(WireError::Malformed(m)) => {
                let _ = Response::error(400, "bad_request", &m).closing().write_to(&mut writer);
                return;
            }
            // read_head never produces these two; framing is unknown, close.
            Err(WireError::LengthRequired) | Err(WireError::TooLarge(_)) => return,
        };
        // The deadline budget starts once a request head exists; body
        // upload and parsing spend from it.
        let arrived = Instant::now();
        // Read-boundary fault injection: a panic here kills exactly this
        // connection thread (the accept loop and every other connection
        // keep going); an err closes the connection quietly.
        if failpoint::armed() {
            if let Some(fault) = failpoint::eval("http.read") {
                match fault {
                    failpoint::Fault::Panic => panic!("failpoint http.read: injected panic"),
                    failpoint::Fault::Err(_) => return,
                }
            }
        }
        let Ok(body) = read_framed_body(state, &head, &mut reader, &mut writer) else {
            return;
        };
        let mut resp = dispatch(state, &head, &body, arrived);
        if state.shutdown.load(Ordering::SeqCst) {
            resp = resp.closing();
        }
        if failpoint::armed() {
            if let Some(fault) = failpoint::eval("http.write") {
                match fault {
                    failpoint::Fault::Panic => panic!("failpoint http.write: injected panic"),
                    failpoint::Fault::Err(_) => return,
                }
            }
        }
        if resp.write_to(&mut writer).is_err() || resp.close || !head.keep_alive {
            return;
        }
    }
}

/// Read the request body under the framing rules, emitting 411/413/400
/// and `100 Continue` as needed. `Err(())` means the connection must
/// close (the error response, if owed, was already written).
fn read_framed_body(
    state: &ServerState,
    head: &RequestHead,
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
) -> std::result::Result<Vec<u8>, ()> {
    let len = match head.content_length {
        Some(len) => len,
        None if head.method == "POST" => {
            let _ = Response::error(
                411,
                "length_required",
                "POST requires a Content-Length header (chunked bodies are not supported)",
            )
            .closing()
            .write_to(writer);
            return Err(());
        }
        None => return Ok(Vec::new()),
    };
    if len > state.cfg.max_body_bytes {
        let _ = Response::error(
            413,
            "payload_too_large",
            &format!(
                "request body of {len} bytes exceeds the {} byte limit",
                state.cfg.max_body_bytes
            ),
        )
        .closing()
        .write_to(writer);
        return Err(());
    }
    if head.expect_continue && len > 0 {
        // curl sends Expect for bodies over ~1 KiB and stalls ~1 s if the
        // interim response never comes — that stall would swamp p99.
        if writer.write_all(wire::CONTINUE_LINE).is_err() {
            return Err(());
        }
    }
    match wire::read_body(reader, len, state.cfg.max_body_bytes) {
        Ok(body) => Ok(body),
        Err(WireError::Malformed(m)) => {
            let _ = Response::error(400, "bad_request", &m).closing().write_to(writer);
            Err(())
        }
        Err(_) => Err(()),
    }
}

/// Route under `catch_unwind`: a bug in a handler answers 500 on this
/// connection instead of tearing the whole service down — and opens the
/// degraded health window with the `internal_panic` cause, so `/healthz`
/// tells the truth about it.
fn dispatch(state: &ServerState, head: &RequestHead, body: &[u8], arrived: Instant) -> Response {
    let routed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        route(state, head, body, arrived)
    }));
    match routed {
        Ok(resp) => resp,
        Err(_) => {
            note_degraded(state, "internal_panic");
            Response::error(500, "internal", "internal error; closing this connection").closing()
        }
    }
}

/// The value of one query-string parameter in the request target, if
/// present. No percent-decoding — model names are registry labels, not
/// arbitrary URLs.
fn query_param<'a>(target: &'a str, key: &str) -> Option<&'a str> {
    let (_, query) = target.split_once('?')?;
    query.split('&').find_map(|pair| {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        (k == key).then_some(v)
    })
}

fn route(state: &ServerState, head: &RequestHead, body: &[u8], arrived: Instant) -> Response {
    match (head.method.as_str(), head.path()) {
        ("GET", "/healthz") => healthz_response(state),
        ("GET", "/v1/models") => Response::json(&models_json(state)),
        ("POST", "/v1/predict") => predict(state, head, body, arrived),
        (_, "/healthz") | (_, "/v1/models") => method_not_allowed("GET"),
        (_, "/v1/predict") => method_not_allowed("POST"),
        (method, path) => {
            Response::error(404, "not_found", &format!("no route for {method} {path}"))
        }
    }
}

fn method_not_allowed(allow: &'static str) -> Response {
    let mut resp =
        Response::error(405, "method_not_allowed", &format!("this endpoint accepts {allow}"));
    resp.allow = Some(allow);
    resp
}

/// `GET /v1/models`: every registered model's static metadata merged with
/// its live registry stats (artifact version, routed requests, hot-swaps).
fn models_json(state: &ServerState) -> Json {
    let models: Vec<Json> = state
        .registry
        .entries()
        .iter()
        .map(|entry| {
            let unit = entry.unit();
            let mut fields = unit.meta.as_obj().cloned().unwrap_or_default();
            fields.insert("version".to_string(), Json::Num(entry.version() as f64));
            fields.insert("requests".to_string(), Json::Num(entry.requests() as f64));
            fields.insert("swaps".to_string(), Json::Num(entry.swaps() as f64));
            Json::Obj(fields)
        })
        .collect();
    Json::obj(vec![("models", Json::Arr(models))])
}

/// `POST /v1/predict`: resolve the model (`?model=`, default first),
/// lazy-extract `points`, validate shape, submit through the model's
/// coalescer, answer the assignments.
///
/// Sheds the request (503 + `Retry-After`) if the deadline budget was
/// spent before admission. Failure mapping: a scorer dependency outage
/// (required shard down through every retry) answers 503
/// `shard_unavailable`; a request that failed even retried alone answers
/// 500 `prediction_failed`; a partial sharded answer (opt-in) carries
/// `"partial": true` and the coverage fraction. Each failure records its
/// structured cause in the health state.
fn predict(state: &ServerState, head: &RequestHead, body: &[u8], arrived: Instant) -> Response {
    if arrived.elapsed() >= state.cfg.request_deadline {
        state.shed.fetch_add(1, Ordering::SeqCst);
        return Response::error(
            503,
            "deadline_exceeded",
            &format!(
                "request spent its {} ms deadline budget before admission",
                state.cfg.request_deadline.as_millis()
            ),
        )
        .retry_after(1);
    }
    let wanted = query_param(&head.target, "model");
    let Some(entry) = state.registry.lookup(wanted) else {
        return Response::error(
            404,
            "model_not_found",
            &format!("no model named {:?} is registered (see /v1/models)", wanted.unwrap_or("")),
        );
    };
    entry.note_request();
    let unit = entry.unit();
    let raw = match lazy::fields(body, &["points"]) {
        Ok(fields) => fields.into_iter().next().flatten(),
        Err(e) => return Response::error(400, "invalid_json", &e.to_string()),
    };
    let Some(raw) = raw else {
        return Response::error(
            400,
            "missing_field",
            "request body must contain a \"points\" field",
        );
    };
    let points = match raw.parse_points() {
        Ok(points) => points,
        Err(e) => return Response::error(400, "invalid_points", &e.to_string()),
    };
    let d = unit.coalescer.d();
    if points.rows > 0 && points.d != d {
        return Response::error(
            400,
            "shape_mismatch",
            &format!("points have {} features per row but the served model expects {d}", points.d),
        );
    }
    let scored = match unit.coalescer.submit(points.features) {
        Ok(scored) => scored,
        Err(ScoreError::Unavailable(msg)) => {
            // A required shard stayed down through every retry. The
            // request is answerable again the moment the shard recovers —
            // 503 + Retry-After, not 500.
            note_degraded(state, "shard_unavailable");
            return Response::error(503, "shard_unavailable", &msg).retry_after(1);
        }
        Err(ScoreError::Failed(msg)) => {
            // The scorer panicked on this request even retried alone (or
            // it was aborted at shutdown). The fault is contained to this
            // request, but it IS an internal fault — surface it in health.
            note_degraded(state, "prediction_failed");
            return Response::error(500, "prediction_failed", &msg);
        }
    };
    let mut fields = vec![
        ("assignments", Json::arr_num(scored.assignments.iter().map(|&a| a as f64))),
        ("rows", Json::Num(points.rows as f64)),
    ];
    if let Some(coverage) = scored.coverage {
        // Partial-policy answer: correct argmin over the covered centers,
        // marked so the client can decide whether that is good enough.
        note_degraded(state, "partial_results");
        fields.push(("partial", Json::Bool(true)));
        fields.push(("coverage", Json::Num(coverage)));
    }
    Response::json(&Json::obj(fields))
}

/// `GET /healthz`: the health state machine, truthfully.
///
/// | state     | code | notes                                   |
/// |-----------|------|-----------------------------------------|
/// | starting  | 503  | bound but not yet accepting             |
/// | ok        | 200  |                                         |
/// | degraded  | 200  | still serving; `degraded_causes` says why |
/// | draining  | 503  | `Retry-After` set; shutting down        |
fn healthz_response(state: &ServerState) -> Response {
    let status = state.health_status();
    let mut resp = Response::json(&healthz_json(state, status));
    match status {
        "starting" => resp.status = 503,
        "draining" => {
            resp.status = 503;
            resp = resp.retry_after(1);
        }
        _ => {}
    }
    resp
}

/// Per-shard replica detail for `/healthz` (sharded units only).
fn shards_json(unit: &ServingUnit) -> Option<Json> {
    let set = unit.shard_set.as_ref()?;
    let shards: Vec<Json> = set
        .status()
        .into_iter()
        .map(|s| {
            Json::obj(vec![
                ("shard", Json::Num(s.shard as f64)),
                (
                    "centers",
                    Json::arr_num([s.centers.0 as f64, s.centers.1 as f64]),
                ),
                (
                    "replicas",
                    Json::Arr(
                        s.replicas
                            .into_iter()
                            .map(|r| {
                                Json::obj(vec![
                                    ("label", Json::Str(r.label)),
                                    ("ejected", Json::Bool(r.ejected)),
                                    (
                                        "consecutive_failures",
                                        Json::Num(r.consecutive_failures as f64),
                                    ),
                                    ("dispatches", Json::Num(r.dispatches as f64)),
                                    ("failures", Json::Num(r.failures as f64)),
                                    ("probes", Json::Num(r.probes as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    Some(Json::obj(vec![
        ("plan", Json::arr_num(set.plan().bounds().iter().map(|&b| b as f64))),
        ("ejection_events", Json::Num(set.ejection_events() as f64)),
        ("readmissions", Json::Num(set.readmissions() as f64)),
        ("detail", Json::Arr(shards)),
    ]))
}

fn healthz_json(state: &ServerState, status: &str) -> Json {
    let unit = state.registry.default_model().unit();
    let s = unit.coalescer.stats();
    let causes = state.live_causes();
    let mut fields = vec![
        ("status", Json::Str(status.to_string())),
        ("model", unit.summary.clone()),
        (
            "degraded_causes",
            Json::Arr(causes.into_iter().map(|c| Json::Str(c.to_string())).collect()),
        ),
        (
            "stats",
            Json::obj(vec![
                ("requests", Json::Num(s.requests as f64)),
                ("batches", Json::Num(s.batches as f64)),
                ("rows", Json::Num(s.rows as f64)),
                ("coalesced_batches", Json::Num(s.coalesced_batches as f64)),
                ("max_batch_rows", Json::Num(s.max_batch_rows as f64)),
                ("aborted_requests", Json::Num(s.aborted_requests as f64)),
                ("shed_requests", Json::Num(state.shed.load(Ordering::SeqCst) as f64)),
                (
                    "active_connections",
                    Json::Num(state.active.load(Ordering::SeqCst) as f64),
                ),
            ]),
        ),
    ];
    if let Some(shards) = shards_json(&unit) {
        fields.push(("shards", shards));
    }
    Json::obj(fields)
}
