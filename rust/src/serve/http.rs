//! The zero-dependency HTTP/1.1 prediction service (DESIGN.md §11,
//! docs/API.md).
//!
//! ```text
//! TcpListener (nonblocking accept loop, polls the shutdown flag)
//!    └─ per-connection thread (keep-alive loop)
//!         ├─ wire::read_head / read_body   bounded framing, 100-continue
//!         ├─ json::lazy                    offset-based "points" extraction
//!         ├─ Coalescer                     deadline-batched admission queue
//!         │     └─ PredictEngine           persistent worker pool
//!         └─ wire::Response                single-write JSON response
//! ```
//!
//! Endpoints: `POST /v1/predict`, `GET /v1/models`, `GET /healthz` — the
//! request/response schemas, error envelope, and coalescing semantics are
//! documented in docs/API.md and pinned by `rust/tests/conformance_http.rs`.
//!
//! Guarantees:
//!
//! * **Never panics on client bytes.** Framing and JSON errors map to 4xx
//!   envelopes; routing runs under `catch_unwind` so even an internal bug
//!   answers 500 and closes that one connection.
//! * **Bit-identity.** A row scored over HTTP gets exactly the assignment
//!   the CLI's `predict --scalar` computes for the same text: the lazy
//!   parser converts number tokens with the CSV loader's single-rounding
//!   `parse::<f32>` and the coalescer inherits the engine's batch-shape
//!   invariance.
//! * **Bounded resources.** Head and body caps, a connection ceiling
//!   (503 above it), and read timeouts on every accepted socket.
//!
//! Connection handling is thread-per-connection on `std::thread` — *not*
//! the compute worker pool, which stays dedicated to `PredictEngine`
//! batches and must never block on client sockets (ADR-003).
//!
//! **Degrade, don't die** (ADR-004): the server carries an explicit health
//! state machine — `starting → serving → draining`, with a time-windowed
//! `degraded` overlay entered whenever an internal fault is contained
//! (a routed panic, a failed coalescer flush). `/healthz` reports it
//! truthfully: 503 while starting or draining (with `Retry-After`), 200
//! with `"status": "degraded"` inside the fault window. Load is shed with
//! 503 + `Retry-After` at the connection ceiling and when a request blows
//! its deadline budget before admission. Fault-injection hooks
//! (`http.accept`, `http.read`, `http.write` — see `util::failpoint`)
//! prove the blast radius: an injected accept fault drops one connection,
//! a read/write fault kills one connection thread, and the process keeps
//! serving — pinned by the CI chaos sweep.

use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::coalesce::{CoalesceConfig, Coalescer, StatsSnapshot};
use super::engine::PredictEngine;
use super::format;
use super::wire::{self, RequestHead, Response, WireError};
use crate::kkmeans::KernelKMeansModel;
use crate::util::error::{Context, Result};
use crate::util::failpoint;
use crate::util::json::{lazy, Json};
use crate::util::simd::NumericsMode;

/// How often the accept loop re-checks the shutdown flag when idle.
const ACCEPT_POLL: Duration = Duration::from_millis(2);
/// How long shutdown waits for in-flight connections to finish.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(5);
/// How long `/healthz` reports `degraded` after a contained internal
/// fault. Long enough for an external prober on a coarse interval to see
/// it; the server keeps serving throughout.
const DEGRADED_WINDOW: Duration = Duration::from_secs(30);

/// Health phases (the `Degraded` overlay is a timestamp, not a phase —
/// a fault must not mask a concurrent drain).
const PHASE_STARTING: u8 = 0;
const PHASE_SERVING: u8 = 1;
const PHASE_DRAINING: u8 = 2;

/// Server configuration (`mbkk serve` flags map onto these fields).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:8605` (port 0 picks a free port).
    pub addr: String,
    /// Coalescing deadline: how long a batch leader waits for company.
    pub max_wait: Duration,
    /// Flush threshold / bypass size, in rows.
    pub max_batch_rows: usize,
    /// Request body cap in bytes (413 above it).
    pub max_body_bytes: usize,
    /// Per-socket read/write timeout.
    pub read_timeout: Duration,
    /// Concurrent-connection ceiling (503 above it).
    pub max_connections: usize,
    /// Per-request deadline budget: a predict request that spends longer
    /// than this between arrival and admission (slow body upload, parse)
    /// is shed with 503 + `Retry-After` instead of queueing stale work.
    pub request_deadline: Duration,
    /// Numerics mode the prediction engine serves under (`--numerics`).
    /// Fast is safe for serving: distances move within the exp ulp
    /// budget, assignments effectively never (DESIGN.md §13).
    pub numerics: NumericsMode,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:8605".to_string(),
            max_wait: CoalesceConfig::default().max_wait,
            max_batch_rows: CoalesceConfig::default().max_batch_rows,
            max_body_bytes: 8 * 1024 * 1024,
            read_timeout: Duration::from_secs(10),
            max_connections: 128,
            request_deadline: Duration::from_secs(5),
            numerics: NumericsMode::Deterministic,
        }
    }
}

struct ServerState {
    coalescer: Coalescer,
    /// Prebuilt `GET /v1/models` response value.
    models_json: Json,
    /// Prebuilt model summary embedded in `/healthz`.
    model_summary: Json,
    shutdown: Arc<AtomicBool>,
    active: AtomicUsize,
    max_body_bytes: usize,
    max_connections: usize,
    request_deadline: Duration,
    /// Health phase: starting / serving / draining.
    phase: AtomicU8,
    /// Instant the state was built — the zero point for `degraded_until`.
    started: Instant,
    /// Millis-since-`started` until which `/healthz` reports `degraded`
    /// (0 = never degraded). Written by [`note_degraded`].
    degraded_until: AtomicU64,
    /// Requests shed before admission (deadline blown, draining).
    shed: AtomicU64,
}

impl ServerState {
    fn now_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    /// `"starting" | "ok" | "degraded" | "draining"` — the serving phase
    /// with the fault window overlaid (a drain outranks it).
    fn health_status(&self) -> &'static str {
        match self.phase.load(Ordering::SeqCst) {
            PHASE_STARTING => "starting",
            PHASE_DRAINING => "draining",
            _ if self.now_ms() < self.degraded_until.load(Ordering::SeqCst) => "degraded",
            _ => "ok",
        }
    }
}

/// Open (or extend) the degraded window after a contained internal fault.
fn note_degraded(state: &ServerState) {
    let until = state.now_ms() + DEGRADED_WINDOW.as_millis() as u64;
    state.degraded_until.fetch_max(until, Ordering::SeqCst);
}

/// A bound, not-yet-running prediction server.
pub struct Server {
    listener: TcpListener,
    read_timeout: Duration,
    state: Arc<ServerState>,
}

/// Decrements the active-connection counter even if a handler unwinds.
struct ActiveGuard(Arc<ServerState>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.active.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Server {
    /// Build the engine + admission queue and bind the listen socket.
    /// `source` labels the model in `/v1/models` and `/healthz` (the
    /// artifact path, or a synthetic label for fit-on-the-fly models).
    pub fn bind(model: &KernelKMeansModel, source: &str, cfg: &ServeConfig) -> Result<Server> {
        let engine = PredictEngine::with_mode(model, cfg.numerics);
        let coalescer = Coalescer::new(
            engine,
            CoalesceConfig { max_wait: cfg.max_wait, max_batch_rows: cfg.max_batch_rows },
        );
        let meta = Json::obj(vec![
            ("name", Json::Str(source.to_string())),
            ("kind", Json::Str("model".to_string())),
            ("format_version", Json::Num(format::FORMAT_VERSION as f64)),
            ("kernel", format::kernel_to_json(model.kernel)),
            ("k", Json::Num(model.k() as f64)),
            ("d", Json::Num(model.d as f64)),
            ("support_points", Json::Num(model.support_points() as f64)),
        ]);
        let model_summary = Json::obj(vec![
            ("name", Json::Str(source.to_string())),
            ("k", Json::Num(model.k() as f64)),
            ("d", Json::Num(model.d as f64)),
        ]);
        let listener = TcpListener::bind(cfg.addr.as_str())
            .with_context(|| format!("binding http listener on {}", cfg.addr))?;
        Ok(Server {
            listener,
            read_timeout: cfg.read_timeout,
            state: Arc::new(ServerState {
                coalescer,
                models_json: Json::obj(vec![("models", Json::Arr(vec![meta]))]),
                model_summary,
                shutdown: Arc::new(AtomicBool::new(false)),
                active: AtomicUsize::new(0),
                max_body_bytes: cfg.max_body_bytes,
                max_connections: cfg.max_connections,
                request_deadline: cfg.request_deadline,
                phase: AtomicU8::new(PHASE_STARTING),
                started: Instant::now(),
                degraded_until: AtomicU64::new(0),
                shed: AtomicU64::new(0),
            }),
        })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading the bound address")
    }

    /// Handle to the shutdown flag: store `true` (e.g. from a SIGTERM
    /// handler or a test) and `run` drains connections and returns.
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.state.shutdown)
    }

    /// Accept loop. Returns the final service counters once the shutdown
    /// flag is set and in-flight connections have drained (or the drain
    /// timeout passes).
    pub fn run(self) -> Result<StatsSnapshot> {
        let state = self.state;
        self.listener
            .set_nonblocking(true)
            .context("setting the listener nonblocking")?;
        state.phase.store(PHASE_SERVING, Ordering::SeqCst);
        while !state.shutdown.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    // Accept-boundary fault injection: whatever the armed
                    // action, the blast radius is THIS connection — the
                    // accept loop itself must never exit on a fault
                    // (chaos CI pins the process staying alive).
                    if failpoint::armed() {
                        if let Some(fault) = failpoint::eval("http.accept") {
                            let msg = match fault {
                                failpoint::Fault::Panic => "injected panic".to_string(),
                                failpoint::Fault::Err(m) => m,
                            };
                            eprintln!("mbkk-serve: dropped a connection (failpoint http.accept: {msg})");
                            note_degraded(&state);
                            continue;
                        }
                    }
                    if state.active.load(Ordering::SeqCst) >= state.max_connections {
                        let mut s = stream;
                        let _ = s.set_nonblocking(false);
                        let _ = Response::error(
                            503,
                            "server_overloaded",
                            "connection limit reached; retry shortly",
                        )
                        .retry_after(1)
                        .closing()
                        .write_to(&mut s);
                        continue;
                    }
                    if stream.set_nonblocking(false).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let _ = stream.set_read_timeout(Some(self.read_timeout));
                    let _ = stream.set_write_timeout(Some(self.read_timeout));
                    state.active.fetch_add(1, Ordering::SeqCst);
                    let guard = ActiveGuard(Arc::clone(&state));
                    let st = Arc::clone(&state);
                    let spawned = std::thread::Builder::new()
                        .name("mbkk-http".to_string())
                        .spawn(move || {
                            let _guard = guard;
                            handle_connection(&st, stream);
                        });
                    if spawned.is_err() {
                        // ActiveGuard moved into the dead closure was
                        // dropped by the failed spawn, decrementing for us.
                        continue;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::ConnectionAborted | std::io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => return Err(e).context("accepting a connection"),
            }
        }
        // Drain: stop accepting (loop exited), flush the in-flight
        // coalesced accumulation immediately instead of letting it wait
        // out `max_wait`, and give connection threads the drain window to
        // finish. Only if the window closes with tickets still queued do
        // we abort them — counted, so the e2e drain test can assert a
        // graceful shutdown aborts nothing.
        state.phase.store(PHASE_DRAINING, Ordering::SeqCst);
        state.coalescer.begin_drain();
        let deadline = Instant::now() + DRAIN_TIMEOUT;
        while state.active.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(ACCEPT_POLL);
        }
        let aborted = state.coalescer.abort_pending("server draining; request aborted");
        if aborted > 0 {
            eprintln!("mbkk-serve: aborted {aborted} queued requests at the drain deadline");
        }
        Ok(state.coalescer.stats())
    }
}

/// Keep-alive loop for one accepted connection.
fn handle_connection(state: &ServerState, stream: TcpStream) {
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        let head = match wire::read_head(&mut reader) {
            Ok(head) => head,
            Err(WireError::Closed) | Err(WireError::Io(_)) => return,
            Err(WireError::Idle) => {
                if state.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(WireError::Malformed(m)) => {
                let _ = Response::error(400, "bad_request", &m).closing().write_to(&mut writer);
                return;
            }
            // read_head never produces these two; framing is unknown, close.
            Err(WireError::LengthRequired) | Err(WireError::TooLarge(_)) => return,
        };
        // The deadline budget starts once a request head exists; body
        // upload and parsing spend from it.
        let arrived = Instant::now();
        // Read-boundary fault injection: a panic here kills exactly this
        // connection thread (the accept loop and every other connection
        // keep going); an err closes the connection quietly.
        if failpoint::armed() {
            if let Some(fault) = failpoint::eval("http.read") {
                match fault {
                    failpoint::Fault::Panic => panic!("failpoint http.read: injected panic"),
                    failpoint::Fault::Err(_) => return,
                }
            }
        }
        let Ok(body) = read_framed_body(state, &head, &mut reader, &mut writer) else {
            return;
        };
        let mut resp = dispatch(state, &head, &body, arrived);
        if state.shutdown.load(Ordering::SeqCst) {
            resp = resp.closing();
        }
        if failpoint::armed() {
            if let Some(fault) = failpoint::eval("http.write") {
                match fault {
                    failpoint::Fault::Panic => panic!("failpoint http.write: injected panic"),
                    failpoint::Fault::Err(_) => return,
                }
            }
        }
        if resp.write_to(&mut writer).is_err() || resp.close || !head.keep_alive {
            return;
        }
    }
}

/// Read the request body under the framing rules, emitting 411/413/400
/// and `100 Continue` as needed. `Err(())` means the connection must
/// close (the error response, if owed, was already written).
fn read_framed_body(
    state: &ServerState,
    head: &RequestHead,
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
) -> std::result::Result<Vec<u8>, ()> {
    let len = match head.content_length {
        Some(len) => len,
        None if head.method == "POST" => {
            let _ = Response::error(
                411,
                "length_required",
                "POST requires a Content-Length header (chunked bodies are not supported)",
            )
            .closing()
            .write_to(writer);
            return Err(());
        }
        None => return Ok(Vec::new()),
    };
    if len > state.max_body_bytes {
        let _ = Response::error(
            413,
            "payload_too_large",
            &format!(
                "request body of {len} bytes exceeds the {} byte limit",
                state.max_body_bytes
            ),
        )
        .closing()
        .write_to(writer);
        return Err(());
    }
    if head.expect_continue && len > 0 {
        // curl sends Expect for bodies over ~1 KiB and stalls ~1 s if the
        // interim response never comes — that stall would swamp p99.
        if writer.write_all(wire::CONTINUE_LINE).is_err() {
            return Err(());
        }
    }
    match wire::read_body(reader, len, state.max_body_bytes) {
        Ok(body) => Ok(body),
        Err(WireError::Malformed(m)) => {
            let _ = Response::error(400, "bad_request", &m).closing().write_to(writer);
            Err(())
        }
        Err(_) => Err(()),
    }
}

/// Route under `catch_unwind`: a bug in a handler answers 500 on this
/// connection instead of tearing the whole service down — and opens the
/// degraded health window, so `/healthz` tells the truth about it.
fn dispatch(state: &ServerState, head: &RequestHead, body: &[u8], arrived: Instant) -> Response {
    let routed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        route(state, head, body, arrived)
    }));
    match routed {
        Ok(resp) => resp,
        Err(_) => {
            note_degraded(state);
            Response::error(500, "internal", "internal error; closing this connection").closing()
        }
    }
}

fn route(state: &ServerState, head: &RequestHead, body: &[u8], arrived: Instant) -> Response {
    match (head.method.as_str(), head.path()) {
        ("GET", "/healthz") => healthz_response(state),
        ("GET", "/v1/models") => Response::json(&state.models_json),
        ("POST", "/v1/predict") => predict(state, body, arrived),
        (_, "/healthz") | (_, "/v1/models") => method_not_allowed("GET"),
        (_, "/v1/predict") => method_not_allowed("POST"),
        (method, path) => {
            Response::error(404, "not_found", &format!("no route for {method} {path}"))
        }
    }
}

fn method_not_allowed(allow: &'static str) -> Response {
    let mut resp =
        Response::error(405, "method_not_allowed", &format!("this endpoint accepts {allow}"));
    resp.allow = Some(allow);
    resp
}

/// `POST /v1/predict`: lazy-extract `points`, validate shape against the
/// served model, submit through the coalescer, answer the assignments.
/// Sheds the request (503 + `Retry-After`) if the deadline budget was
/// spent before admission; answers 500 if the request failed even when
/// retried alone after poisoning a batch.
fn predict(state: &ServerState, body: &[u8], arrived: Instant) -> Response {
    if arrived.elapsed() >= state.request_deadline {
        state.shed.fetch_add(1, Ordering::SeqCst);
        return Response::error(
            503,
            "deadline_exceeded",
            &format!(
                "request spent its {} ms deadline budget before admission",
                state.request_deadline.as_millis()
            ),
        )
        .retry_after(1);
    }
    let raw = match lazy::fields(body, &["points"]) {
        Ok(fields) => fields.into_iter().next().flatten(),
        Err(e) => return Response::error(400, "invalid_json", &e.to_string()),
    };
    let Some(raw) = raw else {
        return Response::error(
            400,
            "missing_field",
            "request body must contain a \"points\" field",
        );
    };
    let points = match raw.parse_points() {
        Ok(points) => points,
        Err(e) => return Response::error(400, "invalid_points", &e.to_string()),
    };
    let d = state.coalescer.engine().d();
    if points.rows > 0 && points.d != d {
        return Response::error(
            400,
            "shape_mismatch",
            &format!("points have {} features per row but the served model expects {d}", points.d),
        );
    }
    let assignments = match state.coalescer.submit(points.features) {
        Ok(assignments) => assignments,
        Err(msg) => {
            // The engine panicked on this request even retried alone (or
            // it was aborted at shutdown). The fault is contained to this
            // request, but it IS an internal fault — surface it in health.
            note_degraded(state);
            return Response::error(500, "prediction_failed", &msg);
        }
    };
    Response::json(&Json::obj(vec![
        ("assignments", Json::arr_num(assignments.iter().map(|&a| a as f64))),
        ("rows", Json::Num(points.rows as f64)),
    ]))
}

/// `GET /healthz`: the health state machine, truthfully.
///
/// | state     | code | notes                                   |
/// |-----------|------|-----------------------------------------|
/// | starting  | 503  | bound but not yet accepting             |
/// | ok        | 200  |                                         |
/// | degraded  | 200  | still serving; fault window open        |
/// | draining  | 503  | `Retry-After` set; shutting down        |
fn healthz_response(state: &ServerState) -> Response {
    let status = state.health_status();
    let mut resp = Response::json(&healthz_json(state, status));
    match status {
        "starting" => resp.status = 503,
        "draining" => {
            resp.status = 503;
            resp = resp.retry_after(1);
        }
        _ => {}
    }
    resp
}

fn healthz_json(state: &ServerState, status: &str) -> Json {
    let s = state.coalescer.stats();
    Json::obj(vec![
        ("status", Json::Str(status.to_string())),
        ("model", state.model_summary.clone()),
        (
            "stats",
            Json::obj(vec![
                ("requests", Json::Num(s.requests as f64)),
                ("batches", Json::Num(s.batches as f64)),
                ("rows", Json::Num(s.rows as f64)),
                ("coalesced_batches", Json::Num(s.coalesced_batches as f64)),
                ("max_batch_rows", Json::Num(s.max_batch_rows as f64)),
                ("aborted_requests", Json::Num(s.aborted_requests as f64)),
                ("shed_requests", Json::Num(state.shed.load(Ordering::SeqCst) as f64)),
                (
                    "active_connections",
                    Json::Num(state.active.load(Ordering::SeqCst) as f64),
                ),
            ]),
        ),
    ])
}
