//! Sharded scoring: support-set partitions, replica failover, and the
//! coordinator-side merge (DESIGN.md §14, ADR-006).
//!
//! The frozen model's per-center support sets bound single-node serving:
//! every query pays O(k·(τ+b)) kernel evaluations against memory one
//! machine must hold. This module splits the centers into S contiguous
//! shards ([`ShardPlan`]), runs each shard behind one or more replicas
//! ([`ShardWorker`]: in-process [`LocalShardWorker`] over a sub-model
//! engine, or [`HttpShardWorker`] speaking a CRC-framed binary protocol
//! to an `mbkk shard-worker` process), and merges the per-shard distance
//! panels back into full k-wide rows in **fixed shard order**.
//!
//! **Bit-identity.** The split is by whole centers, so a shard's
//! sub-engine runs exactly the same per-center contraction chains the
//! full engine would (each support row's dot product is an independent
//! sequential chain; panel packing never changes a value). The merge is
//! pure column placement — no floating-point arithmetic crosses shards —
//! and the final argmin replays the engine's first-minimum `total_cmp`
//! scan. Merged assignments are therefore byte-equal to single-node
//! [`PredictEngine::predict_batch`] for any S; `conformance_shard.rs`
//! pins it for S ∈ {1, 2, 3, 8}.
//!
//! **Robustness.** Dispatch fans out one thread per shard; each shard
//! tries its replicas in order with per-round exponential backoff and
//! deterministic jitter. A replica that fails [`ShardSetConfig::eject_after`]
//! consecutive attempts is ejected (skipped by dispatch) until a
//! background probe re-admits it; a fully-ejected shard still gets a
//! hail-mary pass, because answering beats bookkeeping purity. Missing
//! shards follow the strict-vs-partial policy: by default the batch
//! fails `Unavailable` (the HTTP layer answers 503 `shard_unavailable`);
//! with `partial_results` the merge fills missing columns with `+∞`,
//! answers from the surviving centers, and reports the coverage
//! fraction so clients see exactly how degraded the answer is.
//! Failpoints `shard.dispatch`, `shard.merge`, and `replica.probe`
//! inject faults at each boundary (`util::failpoint`).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::coalesce::{ScoreError, Scored, Scorer};
use super::engine::PredictEngine;
use super::wire::{self, Response, WireError};
use crate::kkmeans::KernelKMeansModel;
use crate::util::crc32::crc32;
use crate::util::error::{Context, Result};
use crate::util::failpoint;
use crate::util::simd::NumericsMode;

/// Magic prefixes of the binary shard protocol bodies.
const QUERY_MAGIC: &[u8; 4] = b"MBKQ";
const PARTIAL_MAGIC: &[u8; 4] = b"MBKR";
/// Body cap for the shard-worker server (a query batch of
/// `max_batch_rows`·d f32s sits far below this).
const WORKER_MAX_BODY: usize = 64 * 1024 * 1024;

/// Deterministic contiguous partition of `k` centers into `S` shards.
///
/// Shard `i` owns centers `[i·k/S, (i+1)·k/S)` — the same split for the
/// same `(k, S)` on every node, so a plan recorded in a model artifact's
/// header reproduces bit-identically at load time. Shards may be empty
/// when S > k; empty shards own no centers and never affect coverage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// `S + 1` boundaries: `bounds[i]..bounds[i+1]` is shard i's range.
    bounds: Vec<usize>,
}

impl ShardPlan {
    /// The canonical plan: `S` near-equal contiguous ranges over `k`
    /// centers (`bounds[i] = ⌊i·k/S⌋`).
    pub fn contiguous(k: usize, shards: usize) -> ShardPlan {
        let s = shards.max(1);
        ShardPlan { bounds: (0..=s).map(|i| i * k / s).collect() }
    }

    /// Rebuild a plan from recorded boundaries, validating shape.
    pub fn from_bounds(bounds: Vec<usize>, k: usize) -> Result<ShardPlan> {
        if bounds.len() < 2 || bounds[0] != 0 || *bounds.last().unwrap() != k {
            bail!("shard plan bounds must run from 0 to k={k}: {bounds:?}");
        }
        if bounds.windows(2).any(|w| w[0] > w[1]) {
            bail!("shard plan bounds must be non-decreasing: {bounds:?}");
        }
        Ok(ShardPlan { bounds })
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Total number of centers the plan covers.
    pub fn k(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// Shard `i`'s center range `[lo, hi)`.
    pub fn range(&self, i: usize) -> (usize, usize) {
        (self.bounds[i], self.bounds[i + 1])
    }

    /// The raw boundaries (recorded into artifact headers by
    /// `serve::format`).
    pub fn bounds(&self) -> &[usize] {
        &self.bounds
    }
}

/// One shard's answer for a query batch: the distance panel of its
/// centers, `nq` rows by `k_local` columns.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardPartial {
    /// First center index this shard owns.
    pub center_lo: usize,
    /// Number of centers in the panel.
    pub k_local: usize,
    /// Row-major `nq × k_local` squared distances.
    pub dist: Vec<f64>,
}

/// One replica of one shard: anything that can turn a query batch into
/// its shard's distance panel. Implementations must be safe to call from
/// concurrent dispatch threads.
pub trait ShardWorker: Send + Sync {
    /// Human-readable replica label (`/healthz` per-shard detail).
    fn label(&self) -> String;
    /// The center range `[lo, hi)` this worker serves.
    fn center_range(&self) -> (usize, usize);
    /// Compute the shard's distance panel for `nq` rows of `d` features.
    /// An `Err` is a *replica* failure (timeout, transport, shape) — the
    /// coordinator retries, fails over, and tracks replica health on it.
    fn distances(&self, rows: &[f32], nq: usize) -> std::result::Result<ShardPartial, String>;
    /// Cheap liveness check used by the background prober to re-admit an
    /// ejected replica.
    fn probe(&self) -> std::result::Result<(), String>;
}

/// In-process replica: a [`PredictEngine`] over the sub-model holding
/// only this shard's centers (`None` for an empty shard).
pub struct LocalShardWorker {
    engine: Option<PredictEngine>,
    lo: usize,
    hi: usize,
    label: String,
}

impl LocalShardWorker {
    /// Slice `model` down to shard `i` of `plan` and build its engine.
    pub fn new(
        model: &KernelKMeansModel,
        plan: &ShardPlan,
        shard: usize,
        mode: NumericsMode,
        label: &str,
    ) -> LocalShardWorker {
        let (lo, hi) = plan.range(shard);
        // Whole-center slicing: the sub-engine runs the exact per-center
        // contraction chains of the full engine (bit-identity argument in
        // the module docs).
        let engine = (hi > lo).then(|| {
            let sub = KernelKMeansModel {
                kernel: model.kernel,
                d: model.d,
                centers: model.centers[lo..hi].to_vec(),
                cc: model.cc[lo..hi].to_vec(),
            };
            PredictEngine::with_mode(&sub, mode)
        });
        LocalShardWorker { engine, lo, hi, label: label.to_string() }
    }
}

impl ShardWorker for LocalShardWorker {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn center_range(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    fn distances(&self, rows: &[f32], nq: usize) -> std::result::Result<ShardPartial, String> {
        let k_local = self.hi - self.lo;
        let dist = match &self.engine {
            Some(engine) => engine.distances_batch(rows),
            None => Vec::new(),
        };
        debug_assert_eq!(dist.len(), nq * k_local);
        Ok(ShardPartial { center_lo: self.lo, k_local, dist })
    }

    fn probe(&self) -> std::result::Result<(), String> {
        Ok(())
    }
}

/// Knobs for dispatch robustness and the merge policy.
#[derive(Debug, Clone)]
pub struct ShardSetConfig {
    /// Merge policy for missing shards: `false` fails the batch
    /// (`Unavailable` → 503 `shard_unavailable`); `true` answers from the
    /// covered centers with a coverage fraction.
    pub partial_results: bool,
    /// Dispatch rounds per shard (each round tries every live replica).
    pub attempts: u32,
    /// Base backoff between rounds; round r waits `backoff · 2^(r−1)`
    /// plus deterministic jitter, capped at `max_backoff`.
    pub backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
    /// Consecutive failures after which a replica is ejected from
    /// dispatch until a probe re-admits it.
    pub eject_after: u32,
}

impl Default for ShardSetConfig {
    fn default() -> Self {
        ShardSetConfig {
            partial_results: false,
            attempts: 2,
            backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(200),
            eject_after: 3,
        }
    }
}

/// One replica plus its health bookkeeping.
struct Replica {
    worker: Box<dyn ShardWorker>,
    ejected: AtomicBool,
    consecutive: AtomicU32,
    dispatches: AtomicU64,
    failures: AtomicU64,
    probes: AtomicU64,
}

impl Replica {
    fn new(worker: Box<dyn ShardWorker>) -> Replica {
        Replica {
            worker,
            ejected: AtomicBool::new(false),
            consecutive: AtomicU32::new(0),
            dispatches: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            probes: AtomicU64::new(0),
        }
    }
}

/// Health snapshot of one replica (`/healthz` per-shard detail).
#[derive(Debug, Clone)]
pub struct ReplicaStatus {
    /// Replica label.
    pub label: String,
    /// Currently ejected from dispatch.
    pub ejected: bool,
    /// Consecutive failures so far (resets on success).
    pub consecutive_failures: u32,
    /// Total dispatch attempts routed to this replica.
    pub dispatches: u64,
    /// Total failed attempts.
    pub failures: u64,
    /// Total probe attempts while ejected.
    pub probes: u64,
}

/// Health snapshot of one shard.
#[derive(Debug, Clone)]
pub struct ShardStatus {
    /// Shard index.
    pub shard: usize,
    /// Center range `[lo, hi)`.
    pub centers: (usize, usize),
    /// Every replica's state, in dispatch order.
    pub replicas: Vec<ReplicaStatus>,
}

/// A merged, possibly partial, batch answer.
#[derive(Debug, Clone)]
pub struct ShardScore {
    /// One assignment per query row.
    pub assignments: Vec<usize>,
    /// Fraction of centers that answered (1.0 = complete, bit-identical
    /// to single-node).
    pub coverage: f64,
    /// Indices of shards that failed this batch (empty when complete).
    pub missing: Vec<usize>,
}

/// Batch-level failure of the shard set.
#[derive(Debug, Clone)]
pub enum ShardError {
    /// One or more required shards did not answer (strict mode), or no
    /// shard answered at all (any mode).
    Unavailable(String),
}

/// Best-effort text of a caught panic payload.
fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "opaque panic payload".to_string())
}

/// The coordinator-side replica fleet: S shards, each with one or more
/// replicas, dispatched in parallel and merged in fixed shard order.
pub struct ShardSet {
    d: usize,
    k: usize,
    plan: ShardPlan,
    shards: Vec<Vec<Replica>>,
    cfg: ShardSetConfig,
    /// Monotone dispatch sequence feeding the deterministic jitter hash.
    jitter_seq: AtomicU64,
    ejection_events: AtomicU64,
    readmissions: AtomicU64,
}

impl ShardSet {
    /// Build from explicit per-shard replica lists (`workers[i]` serves
    /// shard i). Every non-empty shard needs at least one replica.
    pub fn from_workers(
        d: usize,
        plan: ShardPlan,
        workers: Vec<Vec<Box<dyn ShardWorker>>>,
        cfg: ShardSetConfig,
    ) -> Result<ShardSet> {
        if workers.len() != plan.shards() {
            bail!(
                "shard set needs one replica list per shard: got {} lists for {} shards",
                workers.len(),
                plan.shards()
            );
        }
        for (i, reps) in workers.iter().enumerate() {
            let (lo, hi) = plan.range(i);
            if hi > lo && reps.is_empty() {
                bail!("shard {i} owns centers {lo}..{hi} but has no replicas");
            }
            for rep in reps {
                if rep.center_range() != (lo, hi) {
                    bail!(
                        "replica {} serves centers {:?} but shard {i} owns {lo}..{hi}",
                        rep.label(),
                        rep.center_range()
                    );
                }
            }
        }
        let k = plan.k();
        let shards = workers.into_iter().map(|reps| reps.into_iter().map(Replica::new).collect()).collect();
        Ok(ShardSet {
            d,
            k,
            plan,
            shards,
            cfg: ShardSetConfig { attempts: cfg.attempts.max(1), eject_after: cfg.eject_after.max(1), ..cfg },
            jitter_seq: AtomicU64::new(0),
            ejection_events: AtomicU64::new(0),
            readmissions: AtomicU64::new(0),
        })
    }

    /// All-in-process fleet: `replicas` [`LocalShardWorker`]s per shard.
    pub fn local(
        model: &KernelKMeansModel,
        plan: ShardPlan,
        replicas: usize,
        mode: NumericsMode,
        cfg: ShardSetConfig,
    ) -> Result<ShardSet> {
        let r = replicas.max(1);
        let workers = (0..plan.shards())
            .map(|i| {
                (0..r)
                    .map(|j| {
                        Box::new(LocalShardWorker::new(
                            model,
                            &plan,
                            i,
                            mode,
                            &format!("local:{i}.{j}"),
                        )) as Box<dyn ShardWorker>
                    })
                    .collect()
            })
            .collect();
        ShardSet::from_workers(model.d, plan, workers, cfg)
    }

    /// Feature dimension of the served model.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of centers across all shards.
    pub fn k(&self) -> usize {
        self.k
    }

    /// The partition.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Total replica-ejection events so far.
    pub fn ejection_events(&self) -> u64 {
        self.ejection_events.load(Ordering::Relaxed)
    }

    /// Total re-admissions (probe- or dispatch-recovered).
    pub fn readmissions(&self) -> u64 {
        self.readmissions.load(Ordering::Relaxed)
    }

    /// Whether any replica is currently ejected (feeds the degraded
    /// health overlay).
    pub fn any_ejected(&self) -> bool {
        self.shards.iter().flatten().any(|r| r.ejected.load(Ordering::Relaxed))
    }

    /// Per-shard, per-replica health snapshot.
    pub fn status(&self) -> Vec<ShardStatus> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, reps)| ShardStatus {
                shard: i,
                centers: self.plan.range(i),
                replicas: reps
                    .iter()
                    .map(|r| ReplicaStatus {
                        label: r.worker.label(),
                        ejected: r.ejected.load(Ordering::Relaxed),
                        consecutive_failures: r.consecutive.load(Ordering::Relaxed),
                        dispatches: r.dispatches.load(Ordering::Relaxed),
                        failures: r.failures.load(Ordering::Relaxed),
                        probes: r.probes.load(Ordering::Relaxed),
                    })
                    .collect(),
            })
            .collect()
    }

    /// Probe every ejected replica once (the `replica.probe` failpoint
    /// can fail or panic the probe; a panic is contained here). Returns
    /// how many replicas were re-admitted.
    pub fn probe_ejected(&self) -> usize {
        let mut readmitted = 0;
        for (si, reps) in self.shards.iter().enumerate() {
            for rep in reps {
                if !rep.ejected.load(Ordering::Relaxed) {
                    continue;
                }
                rep.probes.fetch_add(1, Ordering::Relaxed);
                let outcome = catch_unwind(AssertUnwindSafe(|| -> std::result::Result<(), String> {
                    if failpoint::armed() {
                        if let Some(fault) = failpoint::eval("replica.probe") {
                            match fault {
                                failpoint::Fault::Panic => {
                                    panic!("failpoint replica.probe: injected panic")
                                }
                                failpoint::Fault::Err(m) => {
                                    return Err(format!("failpoint replica.probe: {m}"))
                                }
                            }
                        }
                    }
                    rep.worker.probe()
                }));
                if matches!(outcome, Ok(Ok(()))) {
                    rep.ejected.store(false, Ordering::Relaxed);
                    rep.consecutive.store(0, Ordering::Relaxed);
                    self.readmissions.fetch_add(1, Ordering::Relaxed);
                    readmitted += 1;
                    eprintln!(
                        "mbkk-serve: shard {si} replica {} re-admitted by probe",
                        rep.worker.label()
                    );
                }
            }
        }
        readmitted
    }

    /// Deterministic backoff + jitter before dispatch round `round` (≥1).
    /// Jitter hashes a monotone sequence number — reproducible across
    /// runs, uncorrelated across shards, no wall-clock entropy.
    fn backoff_delay(&self, round: u32) -> Duration {
        let base = self.cfg.backoff.saturating_mul(1u32 << (round - 1).min(16));
        let base = base.min(self.cfg.max_backoff);
        let seq = self.jitter_seq.fetch_add(1, Ordering::Relaxed);
        let hash = seq.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 33;
        let span_us = (base.as_micros() as u64 / 2).max(1);
        base + Duration::from_micros(hash % span_us)
    }

    /// One guarded attempt against one replica, with health bookkeeping.
    /// The `shard.dispatch` failpoint fires per attempt; a panic (organic
    /// or injected) is contained and counts as a replica failure.
    fn attempt(
        &self,
        si: usize,
        rep: &Replica,
        rows: &[f32],
        nq: usize,
    ) -> std::result::Result<ShardPartial, String> {
        rep.dispatches.fetch_add(1, Ordering::Relaxed);
        let caught = catch_unwind(AssertUnwindSafe(|| -> std::result::Result<ShardPartial, String> {
            if failpoint::armed() {
                if let Some(fault) = failpoint::eval("shard.dispatch") {
                    match fault {
                        failpoint::Fault::Panic => {
                            panic!("failpoint shard.dispatch: injected panic")
                        }
                        failpoint::Fault::Err(m) => {
                            return Err(format!("failpoint shard.dispatch: {m}"))
                        }
                    }
                }
            }
            rep.worker.distances(rows, nq)
        }));
        let res = match caught {
            Ok(res) => res,
            Err(p) => Err(format!("replica panicked: {}", panic_message(p))),
        };
        let (lo, hi) = self.plan.range(si);
        let res = res.and_then(|p| {
            if p.center_lo != lo || p.k_local != hi - lo || p.dist.len() != nq * p.k_local {
                Err(format!(
                    "replica answered the wrong shape: centers {}+{} ({} values) for shard {si} \
                     owning {lo}..{hi} over {nq} rows",
                    p.center_lo,
                    p.k_local,
                    p.dist.len()
                ))
            } else {
                Ok(p)
            }
        });
        match res {
            Ok(p) => {
                rep.consecutive.store(0, Ordering::Relaxed);
                if rep.ejected.swap(false, Ordering::Relaxed) {
                    self.readmissions.fetch_add(1, Ordering::Relaxed);
                }
                Ok(p)
            }
            Err(msg) => {
                rep.failures.fetch_add(1, Ordering::Relaxed);
                let streak = rep.consecutive.fetch_add(1, Ordering::Relaxed) + 1;
                if streak >= self.cfg.eject_after && !rep.ejected.swap(true, Ordering::Relaxed) {
                    self.ejection_events.fetch_add(1, Ordering::Relaxed);
                    eprintln!(
                        "mbkk-serve: shard {si} replica {} ejected after {streak} consecutive \
                         failures ({msg})",
                        rep.worker.label()
                    );
                }
                Err(msg)
            }
        }
    }

    /// Fetch one shard's panel: try live replicas in order, back off and
    /// retry across rounds, and fall back to ejected replicas only when
    /// nothing else is left.
    fn shard_distances(
        &self,
        si: usize,
        rows: &[f32],
        nq: usize,
    ) -> std::result::Result<ShardPartial, String> {
        let (lo, hi) = self.plan.range(si);
        if hi == lo {
            return Ok(ShardPartial { center_lo: lo, k_local: 0, dist: Vec::new() });
        }
        let reps = &self.shards[si];
        let mut last_err = format!("shard {si} has no replicas");
        for round in 1..=self.cfg.attempts {
            if round > 1 {
                std::thread::sleep(self.backoff_delay(round - 1));
            }
            let mut tried = 0usize;
            for rep in reps {
                if rep.ejected.load(Ordering::Relaxed) {
                    continue;
                }
                tried += 1;
                match self.attempt(si, rep, rows, nq) {
                    Ok(p) => return Ok(p),
                    Err(e) => last_err = e,
                }
            }
            if tried == 0 {
                // Every replica is ejected: hail-mary the full list once
                // this round — a probe may simply not have run yet, and a
                // success re-admits the replica on the spot.
                for rep in reps {
                    match self.attempt(si, rep, rows, nq) {
                        Ok(p) => return Ok(p),
                        Err(e) => last_err = e,
                    }
                }
            }
        }
        Err(format!("shard {si} (centers {lo}..{hi}): {last_err}"))
    }

    /// Score a batch: fan out to every shard in parallel, merge the
    /// panels in fixed shard order, and argmin exactly as the single-node
    /// engine does.
    pub fn score_batch(&self, rows: &[f32]) -> std::result::Result<ShardScore, ShardError> {
        let d = self.d.max(1);
        assert_eq!(rows.len() % d, 0, "score_batch() requires validated row shapes");
        let nq = rows.len() / d;
        if nq == 0 {
            return Ok(ShardScore { assignments: Vec::new(), coverage: 1.0, missing: Vec::new() });
        }
        let results: Vec<std::result::Result<ShardPartial, String>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..self.plan.shards())
                    .map(|si| scope.spawn(move || self.shard_distances(si, rows, nq)))
                    .collect();
                handles
                    .into_iter()
                    .map(|h| {
                        h.join()
                            .unwrap_or_else(|p| Err(format!("dispatch thread died: {}", panic_message(p))))
                    })
                    .collect()
            });
        self.merge(nq, &results)
    }

    /// Merge per-shard panels into k-wide rows (fixed shard order, pure
    /// column placement) and apply the strict-vs-partial policy. The
    /// `shard.merge` failpoint can fail (→ `Unavailable`) or panic (the
    /// coalescer's guard contains it) the merge itself.
    fn merge(
        &self,
        nq: usize,
        results: &[std::result::Result<ShardPartial, String>],
    ) -> std::result::Result<ShardScore, ShardError> {
        if failpoint::armed() {
            if let Some(fault) = failpoint::eval("shard.merge") {
                match fault {
                    failpoint::Fault::Panic => panic!("failpoint shard.merge: injected panic"),
                    failpoint::Fault::Err(m) => {
                        return Err(ShardError::Unavailable(format!("failpoint shard.merge: {m}")))
                    }
                }
            }
        }
        let k = self.k.max(1);
        let mut dist = vec![f64::INFINITY; nq * k];
        let mut covered = 0usize;
        let mut missing = Vec::new();
        let mut first_err = String::new();
        for (si, res) in results.iter().enumerate() {
            let (lo, hi) = self.plan.range(si);
            match res {
                Ok(p) => {
                    for q in 0..nq {
                        dist[q * k + lo..q * k + hi]
                            .copy_from_slice(&p.dist[q * p.k_local..(q + 1) * p.k_local]);
                    }
                    covered += hi - lo;
                }
                Err(e) if hi > lo => {
                    missing.push(si);
                    if first_err.is_empty() {
                        first_err = e.clone();
                    }
                }
                // An empty shard owns no centers; its failure costs nothing.
                Err(_) => {}
            }
        }
        if !missing.is_empty() && !self.cfg.partial_results {
            return Err(ShardError::Unavailable(format!(
                "shards {missing:?} did not answer ({first_err})"
            )));
        }
        if covered == 0 {
            return Err(ShardError::Unavailable(format!(
                "no shard answered ({first_err})"
            )));
        }
        // The engine's argmin, verbatim: first minimum under total_cmp.
        // Missing columns hold +∞ and can never win against a real value.
        let mut assignments = vec![0usize; nq];
        for q in 0..nq {
            let drow = &dist[q * k..(q + 1) * k];
            let mut best = 0usize;
            for (j, v) in drow.iter().enumerate().skip(1) {
                if v.total_cmp(&drow[best]) == std::cmp::Ordering::Less {
                    best = j;
                }
            }
            assignments[q] = best;
        }
        Ok(ShardScore {
            assignments,
            coverage: covered as f64 / self.k.max(1) as f64,
            missing,
        })
    }
}

impl Scorer for Arc<ShardSet> {
    fn d(&self) -> usize {
        ShardSet::d(self)
    }

    fn k(&self) -> usize {
        ShardSet::k(self)
    }

    fn score(&self, rows: &[f32]) -> std::result::Result<Scored, ScoreError> {
        match self.score_batch(rows) {
            Ok(s) => Ok(Scored {
                assignments: s.assignments,
                coverage: (s.coverage < 1.0).then_some(s.coverage),
            }),
            Err(ShardError::Unavailable(m)) => Err(ScoreError::Unavailable(m)),
        }
    }
}

// ---------------------------------------------------------------------------
// Binary wire codec (CRC-framed, little-endian — the artifact format's
// conventions at request scale).

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn read_u32(bytes: &[u8], at: usize) -> std::result::Result<u32, String> {
    bytes
        .get(at..at + 4)
        .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .ok_or_else(|| "truncated shard protocol body".to_string())
}

/// Frame a query batch: magic, d, nq, f32 rows, trailing CRC.
pub fn encode_query(d: usize, rows: &[f32]) -> Vec<u8> {
    let nq = if d == 0 { 0 } else { rows.len() / d };
    let mut out = Vec::with_capacity(16 + rows.len() * 4);
    out.extend_from_slice(QUERY_MAGIC);
    push_u32(&mut out, d as u32);
    push_u32(&mut out, nq as u32);
    for v in rows {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let crc = crc32(&out);
    push_u32(&mut out, crc);
    out
}

/// Decode + validate a query frame into `(d, nq, rows)`.
pub fn decode_query(body: &[u8]) -> std::result::Result<(usize, usize, Vec<f32>), String> {
    if body.len() < 16 || &body[..4] != QUERY_MAGIC {
        return Err("not a shard query frame".to_string());
    }
    let crc_at = body.len() - 4;
    if crc32(&body[..crc_at]) != read_u32(body, crc_at)? {
        return Err("shard query frame failed its CRC check".to_string());
    }
    let d = read_u32(body, 4)? as usize;
    let nq = read_u32(body, 8)? as usize;
    let want = (nq as u128) * (d as u128) * 4;
    if want != (crc_at - 12) as u128 {
        return Err(format!("shard query frame claims {nq}×{d} rows but carries {} payload bytes", crc_at - 12));
    }
    let mut rows = Vec::with_capacity(nq * d);
    for c in body[12..crc_at].chunks_exact(4) {
        rows.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    Ok((d, nq, rows))
}

/// Frame a shard's distance panel.
pub fn encode_partial(p: &ShardPartial, nq: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(20 + p.dist.len() * 8);
    out.extend_from_slice(PARTIAL_MAGIC);
    push_u32(&mut out, p.center_lo as u32);
    push_u32(&mut out, p.k_local as u32);
    push_u32(&mut out, nq as u32);
    for v in &p.dist {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let crc = crc32(&out);
    push_u32(&mut out, crc);
    out
}

/// Decode + validate a distance-panel frame for an expected `nq`.
pub fn decode_partial(body: &[u8], nq: usize) -> std::result::Result<ShardPartial, String> {
    if body.len() < 20 || &body[..4] != PARTIAL_MAGIC {
        return Err("not a shard distance frame".to_string());
    }
    let crc_at = body.len() - 4;
    if crc32(&body[..crc_at]) != read_u32(body, crc_at)? {
        return Err("shard distance frame failed its CRC check".to_string());
    }
    let center_lo = read_u32(body, 4)? as usize;
    let k_local = read_u32(body, 8)? as usize;
    let got_nq = read_u32(body, 12)? as usize;
    if got_nq != nq {
        return Err(format!("shard answered {got_nq} rows for a {nq}-row query"));
    }
    let want = (nq as u128) * (k_local as u128) * 8;
    if want != (crc_at - 16) as u128 {
        return Err(format!("shard distance frame claims {nq}×{k_local} values but carries {} payload bytes", crc_at - 16));
    }
    let mut dist = Vec::with_capacity(nq * k_local);
    for c in body[16..crc_at].chunks_exact(8) {
        dist.push(f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]));
    }
    Ok(ShardPartial { center_lo, k_local, dist })
}

// ---------------------------------------------------------------------------
// HTTP replica client + the `mbkk shard-worker` server.

/// Remote replica: speaks the binary protocol to an `mbkk shard-worker`
/// process. One fresh connection per call keeps failure containment
/// trivial (a dead worker costs exactly one connect timeout).
pub struct HttpShardWorker {
    addr: String,
    lo: usize,
    hi: usize,
    /// Per-call deadline, enforced as connect + read + write timeouts —
    /// a replica that misses it surfaces as an `Err` and dispatch fails
    /// over to the next replica.
    deadline: Duration,
}

impl HttpShardWorker {
    /// A client for shard `i` of `plan` served at `addr` (`host:port`).
    pub fn new(addr: &str, plan: &ShardPlan, shard: usize, deadline: Duration) -> HttpShardWorker {
        let (lo, hi) = plan.range(shard);
        HttpShardWorker { addr: addr.to_string(), lo, hi, deadline }
    }

    fn connect(&self) -> std::result::Result<TcpStream, String> {
        let addr = self
            .addr
            .to_socket_addrs()
            .map_err(|e| format!("resolving {}: {e}", self.addr))?
            .next()
            .ok_or_else(|| format!("{} resolves to no address", self.addr))?;
        let stream = TcpStream::connect_timeout(&addr, self.deadline)
            .map_err(|e| format!("connecting {}: {e}", self.addr))?;
        let _ = stream.set_nodelay(true);
        stream
            .set_read_timeout(Some(self.deadline))
            .and_then(|_| stream.set_write_timeout(Some(self.deadline)))
            .map_err(|e| format!("setting timeouts on {}: {e}", self.addr))?;
        Ok(stream)
    }

    fn roundtrip(
        &self,
        method: &str,
        path: &str,
        content_type: &str,
        body: &[u8],
    ) -> std::result::Result<(u16, Vec<u8>), String> {
        let mut stream = self.connect()?;
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: {content_type}\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            self.addr,
            body.len()
        );
        let mut req = head.into_bytes();
        req.extend_from_slice(body);
        stream.write_all(&req).map_err(|e| format!("writing to {}: {e}", self.addr))?;
        read_response(&mut stream, WORKER_MAX_BODY)
            .map_err(|e| format!("reading from {}: {e}", self.addr))
    }
}

impl ShardWorker for HttpShardWorker {
    fn label(&self) -> String {
        format!("http:{}", self.addr)
    }

    fn center_range(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    fn distances(&self, rows: &[f32], nq: usize) -> std::result::Result<ShardPartial, String> {
        let d = if nq == 0 { 0 } else { rows.len() / nq };
        let body = encode_query(d, rows);
        let (status, resp) =
            self.roundtrip("POST", "/v1/shard-distances", "application/octet-stream", &body)?;
        if status != 200 {
            return Err(format!(
                "{} answered HTTP {status}: {}",
                self.addr,
                String::from_utf8_lossy(&resp[..resp.len().min(200)])
            ));
        }
        decode_partial(&resp, nq)
    }

    fn probe(&self) -> std::result::Result<(), String> {
        let (status, _) = self.roundtrip("GET", "/healthz", "application/json", &[])?;
        if status == 200 {
            Ok(())
        } else {
            Err(format!("{} probe answered HTTP {status}", self.addr))
        }
    }
}

/// Minimal HTTP-response reader for the replica client: status line,
/// headers (only `Content-Length` matters), then an exact-length body.
fn read_response(
    stream: &mut TcpStream,
    max_body: usize,
) -> std::result::Result<(u16, Vec<u8>), String> {
    let mut head = Vec::with_capacity(256);
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        if head.len() > 16 * 1024 {
            return Err("response head too large".to_string());
        }
        match stream.read(&mut byte) {
            Ok(1) => head.push(byte[0]),
            Ok(_) => return Err("connection closed mid-head".to_string()),
            Err(e) => return Err(format!("reading response head: {e}")),
        }
    }
    let text = String::from_utf8_lossy(&head);
    let mut lines = text.split("\r\n");
    let status_line = lines.next().unwrap_or_default();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| format!("malformed status line {status_line:?}"))?;
    let mut len = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                len = value
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad Content-Length {value:?}"))?;
            }
        }
    }
    if len > max_body {
        return Err(format!("response body of {len} bytes exceeds the {max_body} byte cap"));
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body).map_err(|e| format!("reading response body: {e}"))?;
    Ok((status, body))
}

/// A bound, not-yet-running shard worker (`mbkk shard-worker`): serves
/// `POST /v1/shard-distances` (binary protocol) and `GET /healthz` for
/// one shard of one model.
pub struct ShardWorkerServer {
    listener: TcpListener,
    state: Arc<WorkerState>,
}

struct WorkerState {
    engine: Option<PredictEngine>,
    shard: usize,
    lo: usize,
    hi: usize,
    d: usize,
    shutdown: Arc<AtomicBool>,
    requests: AtomicU64,
}

impl ShardWorkerServer {
    /// Slice the model to shard `shard` of `plan` and bind `addr`.
    pub fn bind(
        model: &KernelKMeansModel,
        plan: &ShardPlan,
        shard: usize,
        addr: &str,
        mode: NumericsMode,
    ) -> Result<ShardWorkerServer> {
        if shard >= plan.shards() {
            bail!("shard index {shard} out of range for a {}-shard plan", plan.shards());
        }
        let worker = LocalShardWorker::new(model, plan, shard, mode, "worker");
        let (lo, hi) = (worker.lo, worker.hi);
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding shard-worker listener on {addr}"))?;
        Ok(ShardWorkerServer {
            listener,
            state: Arc::new(WorkerState {
                engine: worker.engine,
                shard,
                lo,
                hi,
                d: model.d,
                shutdown: Arc::new(AtomicBool::new(false)),
                requests: AtomicU64::new(0),
            }),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        self.listener.local_addr().context("reading the bound address")
    }

    /// Shutdown flag: store `true` and `run` returns after the current
    /// accept poll.
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.state.shutdown)
    }

    /// Accept loop; returns the request count once the shutdown flag is
    /// set.
    pub fn run(self) -> Result<u64> {
        self.listener
            .set_nonblocking(true)
            .context("setting the shard-worker listener nonblocking")?;
        let state = self.state;
        while !state.shutdown.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(false).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
                    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
                    let st = Arc::clone(&state);
                    let _ = std::thread::Builder::new()
                        .name("mbkk-shard".to_string())
                        .spawn(move || worker_connection(&st, stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::ConnectionAborted | std::io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => return Err(e).context("accepting a shard-worker connection"),
            }
        }
        Ok(state.requests.load(Ordering::Relaxed))
    }
}

/// Keep-alive loop for one shard-worker connection. Routing runs under
/// `catch_unwind`: a bug answers 500 on this connection and the worker
/// keeps serving.
fn worker_connection(state: &WorkerState, stream: TcpStream) {
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = std::io::BufReader::new(read_half);
    let mut writer = stream;
    loop {
        let head = match wire::read_head(&mut reader) {
            Ok(head) => head,
            Err(WireError::Idle) => {
                if state.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(WireError::Malformed(m)) => {
                let _ = Response::error(400, "bad_request", &m).closing().write_to(&mut writer);
                return;
            }
            Err(_) => return,
        };
        let body = match head.content_length {
            Some(len) if len > WORKER_MAX_BODY => {
                let _ = Response::error(413, "payload_too_large", "query batch too large")
                    .closing()
                    .write_to(&mut writer);
                return;
            }
            Some(len) => {
                if head.expect_continue && len > 0 && writer.write_all(wire::CONTINUE_LINE).is_err()
                {
                    return;
                }
                match wire::read_body(&mut reader, len, WORKER_MAX_BODY) {
                    Ok(body) => body,
                    Err(_) => return,
                }
            }
            None if head.method == "POST" => {
                let _ = Response::error(411, "length_required", "POST requires Content-Length")
                    .closing()
                    .write_to(&mut writer);
                return;
            }
            None => Vec::new(),
        };
        let resp = catch_unwind(AssertUnwindSafe(|| worker_route(state, &head, &body)))
            .unwrap_or_else(|_| {
                Response::error(500, "internal", "internal error; closing this connection")
                    .closing()
            });
        let close = resp.close || !head.keep_alive;
        if resp.write_to(&mut writer).is_err() || close {
            return;
        }
    }
}

fn worker_route(state: &WorkerState, head: &wire::RequestHead, body: &[u8]) -> Response {
    use crate::util::json::Json;
    match (head.method.as_str(), head.path()) {
        ("GET", "/healthz") => Response::json(&Json::obj(vec![
            ("status", Json::Str("ok".to_string())),
            ("shard", Json::Num(state.shard as f64)),
            (
                "centers",
                Json::Arr(vec![Json::Num(state.lo as f64), Json::Num(state.hi as f64)]),
            ),
            ("d", Json::Num(state.d as f64)),
            ("requests", Json::Num(state.requests.load(Ordering::Relaxed) as f64)),
        ])),
        ("POST", "/v1/shard-distances") => {
            let (d, nq, rows) = match decode_query(body) {
                Ok(q) => q,
                Err(m) => return Response::error(400, "bad_frame", &m),
            };
            if nq > 0 && d != state.d {
                return Response::error(
                    400,
                    "shape_mismatch",
                    &format!("query rows have {d} features but this shard serves d={}", state.d),
                );
            }
            state.requests.fetch_add(1, Ordering::Relaxed);
            let k_local = state.hi - state.lo;
            let dist = match &state.engine {
                Some(engine) => engine.distances_batch(&rows),
                None => Vec::new(),
            };
            let partial = ShardPartial { center_lo: state.lo, k_local, dist };
            Response::binary(encode_partial(&partial, nq))
        }
        (_, "/healthz") => {
            let mut resp = Response::error(405, "method_not_allowed", "this endpoint accepts GET");
            resp.allow = Some("GET");
            resp
        }
        (_, "/v1/shard-distances") => {
            let mut resp = Response::error(405, "method_not_allowed", "this endpoint accepts POST");
            resp.allow = Some("POST");
            resp
        }
        (method, path) => {
            Response::error(404, "not_found", &format!("no route for {method} {path}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::data::Dataset;
    use crate::kernels::KernelFunction;
    use crate::kkmeans::CenterWindow;
    use crate::util::rng::Rng;

    /// Servable model with irregular per-center support sizes (mirrors
    /// the coalescer fixture).
    fn model_for(d: usize, seed: u64) -> (Dataset, KernelKMeansModel) {
        let mut rng = Rng::seeded(seed);
        let ds = blobs(&SyntheticSpec::new(120, d, 3), &mut rng);
        let mut windows: Vec<CenterWindow> =
            (0..3).map(|j| CenterWindow::new(j * 7, 23)).collect();
        for step in 0..12 {
            for (j, w) in windows.iter_mut().enumerate() {
                let pts: Vec<usize> =
                    (0..1 + (step + j) % 5).map(|_| rng.below(ds.n)).collect();
                w.apply_update(0.4, &pts, None);
            }
        }
        let kernel = KernelFunction::Gaussian { kappa: 2.0 };
        let model = KernelKMeansModel::freeze(&ds, kernel, &mut windows);
        (ds, model)
    }

    fn rows_from(ds: &Dataset, idx: &[usize]) -> Vec<f32> {
        idx.iter().flat_map(|&i| ds.row(i).to_vec()).collect()
    }

    #[test]
    fn contiguous_plan_properties() {
        for (k, s) in [(1, 1), (3, 2), (8, 3), (3, 8), (16, 4), (5, 5)] {
            let plan = ShardPlan::contiguous(k, s);
            assert_eq!(plan.shards(), s);
            assert_eq!(plan.k(), k);
            assert_eq!(plan.range(0).0, 0);
            assert_eq!(plan.range(s - 1).1, k);
            let total: usize = (0..s).map(|i| plan.range(i).1 - plan.range(i).0).sum();
            assert_eq!(total, k, "ranges must tile 0..k for k={k} s={s}");
            // Round-trip through the recorded-bounds path.
            let again = ShardPlan::from_bounds(plan.bounds().to_vec(), k).unwrap();
            assert_eq!(again, plan);
        }
        assert!(ShardPlan::from_bounds(vec![0, 2, 1, 3], 3).is_err());
        assert!(ShardPlan::from_bounds(vec![0, 2], 3).is_err());
        assert!(ShardPlan::from_bounds(vec![1, 3], 3).is_err());
    }

    #[test]
    fn sharded_scoring_is_bit_identical_to_single_node() {
        let (ds, model) = model_for(6, 77);
        let engine = PredictEngine::new(&model);
        let rows = rows_from(&ds, &(0..48).collect::<Vec<_>>());
        let want = engine.predict_batch(&rows);
        for s in [1, 2, 3, 8] {
            let set = ShardSet::local(
                &model,
                ShardPlan::contiguous(model.k(), s),
                1,
                NumericsMode::Deterministic,
                ShardSetConfig::default(),
            )
            .unwrap();
            let got = set.score_batch(&rows).unwrap();
            assert_eq!(got.assignments, want, "S={s} diverged from single-node");
            assert_eq!(got.coverage, 1.0);
            assert!(got.missing.is_empty());
        }
    }

    /// A replica that fails its first `fail_first` calls, then serves via
    /// a local worker.
    struct FlakyWorker {
        inner: LocalShardWorker,
        remaining_failures: AtomicU32,
        healthy: AtomicBool,
    }

    impl ShardWorker for FlakyWorker {
        fn label(&self) -> String {
            format!("flaky:{}", self.inner.label())
        }
        fn center_range(&self) -> (usize, usize) {
            self.inner.center_range()
        }
        fn distances(&self, rows: &[f32], nq: usize) -> std::result::Result<ShardPartial, String> {
            if self
                .remaining_failures
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
                .is_ok()
            {
                return Err("injected replica failure".to_string());
            }
            self.inner.distances(rows, nq)
        }
        fn probe(&self) -> std::result::Result<(), String> {
            if self.healthy.load(Ordering::Relaxed) {
                Ok(())
            } else {
                Err("still down".to_string())
            }
        }
    }

    #[test]
    fn failover_to_second_replica_is_bit_identical() {
        let (ds, model) = model_for(5, 31);
        let engine = PredictEngine::new(&model);
        let rows = rows_from(&ds, &(0..20).collect::<Vec<_>>());
        let plan = ShardPlan::contiguous(model.k(), 2);
        let workers: Vec<Vec<Box<dyn ShardWorker>>> = (0..2)
            .map(|i| {
                vec![
                    Box::new(FlakyWorker {
                        inner: LocalShardWorker::new(
                            &model,
                            &plan,
                            i,
                            NumericsMode::Deterministic,
                            "a",
                        ),
                        remaining_failures: AtomicU32::new(u32::MAX / 2),
                        healthy: AtomicBool::new(false),
                    }) as Box<dyn ShardWorker>,
                    Box::new(LocalShardWorker::new(
                        &model,
                        &plan,
                        i,
                        NumericsMode::Deterministic,
                        "b",
                    )),
                ]
            })
            .collect();
        let set = ShardSet::from_workers(
            model.d,
            plan,
            workers,
            ShardSetConfig { backoff: Duration::from_micros(100), ..Default::default() },
        )
        .unwrap();
        for _ in 0..4 {
            let got = set.score_batch(&rows).unwrap();
            assert_eq!(got.assignments, engine.predict_batch(&rows));
            assert_eq!(got.coverage, 1.0);
        }
        // The dead first replicas crossed the ejection threshold.
        let status = set.status();
        assert!(status.iter().all(|s| s.replicas[0].ejected), "{status:?}");
        assert!(status.iter().all(|s| !s.replicas[1].ejected));
        assert!(set.any_ejected());
        assert!(set.ejection_events() >= 2);
        // Probing while the replicas are still down re-admits nothing.
        assert_eq!(set.probe_ejected(), 0);
    }

    #[test]
    fn probe_readmits_recovered_replica() {
        let (ds, model) = model_for(4, 13);
        let rows = rows_from(&ds, &[0, 1, 2]);
        let plan = ShardPlan::contiguous(model.k(), 1);
        let flaky = Arc::new(FlakyWorker {
            inner: LocalShardWorker::new(&model, &plan, 0, NumericsMode::Deterministic, "only"),
            remaining_failures: AtomicU32::new(6),
            healthy: AtomicBool::new(false),
        });

        /// Shares one flaky replica between the set and the test.
        struct Shared(Arc<FlakyWorker>);
        impl ShardWorker for Shared {
            fn label(&self) -> String {
                self.0.label()
            }
            fn center_range(&self) -> (usize, usize) {
                self.0.center_range()
            }
            fn distances(
                &self,
                rows: &[f32],
                nq: usize,
            ) -> std::result::Result<ShardPartial, String> {
                self.0.distances(rows, nq)
            }
            fn probe(&self) -> std::result::Result<(), String> {
                self.0.probe()
            }
        }

        let set = ShardSet::from_workers(
            model.d,
            plan,
            vec![vec![Box::new(Shared(flaky.clone())) as Box<dyn ShardWorker>]],
            ShardSetConfig {
                attempts: 1,
                backoff: Duration::from_micros(100),
                ..Default::default()
            },
        )
        .unwrap();
        // Three failing batches cross the default threshold of 3 and eject
        // the only replica; strict mode surfaces Unavailable, never panics.
        for _ in 0..3 {
            assert!(matches!(set.score_batch(&rows), Err(ShardError::Unavailable(_))));
        }
        assert!(set.any_ejected());
        assert_eq!(set.probe_ejected(), 0, "an unhealthy replica must not be re-admitted");
        flaky.healthy.store(true, Ordering::Relaxed);
        flaky.remaining_failures.store(0, Ordering::Relaxed);
        assert_eq!(set.probe_ejected(), 1);
        assert!(!set.any_ejected());
        let engine = PredictEngine::new(&model);
        assert_eq!(set.score_batch(&rows).unwrap().assignments, engine.predict_batch(&rows));
        assert!(set.readmissions() >= 1);
    }

    #[test]
    fn strict_mode_fails_partial_mode_answers_with_coverage() {
        let (ds, model) = model_for(6, 19);
        let engine = PredictEngine::new(&model);
        let rows = rows_from(&ds, &(0..10).collect::<Vec<_>>());
        let plan = ShardPlan::contiguous(model.k(), 3);
        let make_workers = |dead_shard: usize| -> Vec<Vec<Box<dyn ShardWorker>>> {
            (0..3)
                .map(|i| {
                    if i == dead_shard {
                        vec![Box::new(FlakyWorker {
                            inner: LocalShardWorker::new(
                                &model,
                                &plan,
                                i,
                                NumericsMode::Deterministic,
                                "dead",
                            ),
                            remaining_failures: AtomicU32::new(u32::MAX / 2),
                            healthy: AtomicBool::new(false),
                        }) as Box<dyn ShardWorker>]
                    } else {
                        vec![Box::new(LocalShardWorker::new(
                            &model,
                            &plan,
                            i,
                            NumericsMode::Deterministic,
                            "ok",
                        )) as Box<dyn ShardWorker>]
                    }
                })
                .collect()
        };
        // Strict (default): the batch fails with Unavailable.
        let strict = ShardSet::from_workers(
            model.d,
            plan.clone(),
            make_workers(1),
            ShardSetConfig { backoff: Duration::from_micros(100), ..Default::default() },
        )
        .unwrap();
        match strict.score_batch(&rows) {
            Err(ShardError::Unavailable(m)) => assert!(m.contains("shard"), "{m}"),
            other => panic!("strict mode must fail: {other:?}"),
        }
        // Partial: answers from covered centers with honest coverage.
        let partial = ShardSet::from_workers(
            model.d,
            plan.clone(),
            make_workers(1),
            ShardSetConfig {
                partial_results: true,
                backoff: Duration::from_micros(100),
                ..Default::default()
            },
        )
        .unwrap();
        let got = partial.score_batch(&rows).unwrap();
        let (lo, hi) = plan.range(1);
        assert_eq!(got.missing, vec![1]);
        let want_cov = (model.k() - (hi - lo)) as f64 / model.k() as f64;
        assert_eq!(got.coverage, want_cov);
        // Expected assignments: argmin over the full distance matrix with
        // the dead shard's columns forced to +∞.
        let k = model.k();
        let mut dist = engine.distances_batch(&rows);
        for q in 0..rows.len() / model.d {
            for j in lo..hi {
                dist[q * k + j] = f64::INFINITY;
            }
        }
        let want: Vec<usize> = dist
            .chunks_exact(k)
            .map(|drow| {
                let mut best = 0usize;
                for (j, v) in drow.iter().enumerate().skip(1) {
                    if v.total_cmp(&drow[best]) == std::cmp::Ordering::Less {
                        best = j;
                    }
                }
                best
            })
            .collect();
        assert_eq!(got.assignments, want);
        // All shards dead → Unavailable even in partial mode.
        let all_dead: Vec<Vec<Box<dyn ShardWorker>>> = (0..3)
            .map(|i| {
                vec![Box::new(FlakyWorker {
                    inner: LocalShardWorker::new(
                        &model,
                        &plan,
                        i,
                        NumericsMode::Deterministic,
                        "dead",
                    ),
                    remaining_failures: AtomicU32::new(u32::MAX / 2),
                    healthy: AtomicBool::new(false),
                }) as Box<dyn ShardWorker>]
            })
            .collect();
        let none = ShardSet::from_workers(
            model.d,
            plan,
            all_dead,
            ShardSetConfig {
                partial_results: true,
                backoff: Duration::from_micros(100),
                ..Default::default()
            },
        )
        .unwrap();
        assert!(matches!(none.score_batch(&rows), Err(ShardError::Unavailable(_))));
    }

    #[test]
    fn dispatch_failpoint_is_contained_and_retried() {
        let _x = failpoint::exclusive_test_lock();
        let (ds, model) = model_for(4, 23);
        let engine = PredictEngine::new(&model);
        let rows = rows_from(&ds, &(0..8).collect::<Vec<_>>());
        let set = ShardSet::local(
            &model,
            ShardPlan::contiguous(model.k(), 1),
            1,
            NumericsMode::Deterministic,
            ShardSetConfig { backoff: Duration::from_micros(100), ..Default::default() },
        )
        .unwrap();
        // First attempt panics; the retry round answers bit-identically.
        failpoint::configure("shard.dispatch=1*panic").unwrap();
        let got = set.score_batch(&rows).unwrap();
        failpoint::clear("shard.dispatch");
        assert_eq!(got.assignments, engine.predict_batch(&rows));
        assert!(failpoint::fired_count("shard.dispatch") >= 1);
        // A merge fault surfaces as Unavailable, not a panic.
        failpoint::configure("shard.merge=err(injected merge fault)").unwrap();
        assert!(matches!(set.score_batch(&rows), Err(ShardError::Unavailable(_))));
        failpoint::clear("shard.merge");
    }

    #[test]
    fn wire_codec_round_trips_and_rejects_corruption() {
        let rows: Vec<f32> = (0..12).map(|i| i as f32 * 0.5 - 2.0).collect();
        let q = encode_query(3, &rows);
        let (d, nq, back) = decode_query(&q).unwrap();
        assert_eq!((d, nq), (3, 4));
        assert_eq!(back, rows);
        let mut bad = q.clone();
        bad[8] ^= 0x40;
        assert!(decode_query(&bad).is_err(), "corrupt frame must fail its CRC");
        assert!(decode_query(b"nope").is_err());

        let p = ShardPartial {
            center_lo: 2,
            k_local: 3,
            dist: (0..12).map(|i| i as f64 * 1.25).collect(),
        };
        let f = encode_partial(&p, 4);
        assert_eq!(decode_partial(&f, 4).unwrap(), p);
        assert!(decode_partial(&f, 5).is_err(), "row-count mismatch must fail");
        let mut bad = f.clone();
        let at = bad.len() - 5;
        bad[at] ^= 1;
        assert!(decode_partial(&bad, 4).is_err());
    }

    #[test]
    fn empty_batch_short_circuits() {
        let (_ds, model) = model_for(4, 3);
        let set = ShardSet::local(
            &model,
            ShardPlan::contiguous(model.k(), 2),
            1,
            NumericsMode::Deterministic,
            ShardSetConfig::default(),
        )
        .unwrap();
        let got = set.score_batch(&[]).unwrap();
        assert!(got.assignments.is_empty());
        assert_eq!(got.coverage, 1.0);
    }
}
