//! Request-coalescing admission queue for the HTTP service (ADR-003).
//!
//! Under many concurrent 1-row requests, dispatching each straight into
//! [`PredictEngine`] wastes the engine's batch shape: the panel kernels
//! and the worker pool amortize over rows, so k single-row batches cost
//! nearly k times one k-row batch. The coalescer turns that load pattern
//! back into batches: concurrent submissions accumulate behind a small
//! deadline (`max_wait`, sized from the bench model's per-batch cost) and
//! flush as ONE engine batch; each caller gets back exactly its slice.
//!
//! The scheme is leader/follower. The first thread to enqueue into an
//! empty queue becomes the *leader*: it waits out the deadline (or an
//! early wake when `max_batch_rows` accumulates), takes the whole queue,
//! runs the engine once, and distributes results to the followers'
//! tickets. Followers just park on their ticket. A request arriving while
//! a flush is in progress starts a fresh accumulation — batches overlap
//! with waiting, so throughput does not gate on the slowest client.
//!
//! The compute side is abstracted behind the [`Scorer`] trait: the
//! single-node [`PredictEngine`] and the sharded `serve::shard::ShardSet`
//! both implement it, so coalescing and fault containment are identical
//! whether a batch is scored in-process or fanned out to shard replicas.
//! Scorer failures are two-sided ([`ScoreError`]): `Failed` poisons the
//! batch and triggers per-request retries; `Unavailable` (a down shard)
//! fails the cohort uniformly without retries.
//!
//! **Bit-identity:** the engine guarantees batched output equal to the
//! scalar path for *any* batch size and thread count, so concatenating
//! requests and slicing the result per ticket cannot change any caller's
//! answer. `conformance_http.rs` pins this end to end.
//!
//! **Fault containment** (ADR-003 leader-panic resolution, ADR-004): the
//! leader's engine call runs under `catch_unwind`. If a batch panics, the
//! batch is *poisoned* — some request in it takes the engine down — so the
//! leader retries each request **alone**, each retry itself guarded.
//! Exactly the poisoned request(s) get an `Err`; every co-traveller still
//! gets its answer, and no connection thread ever dies inside the
//! coalescer. As a backstop against a leader thread that disappears
//! *before* claiming the batch, followers park with a timeout
//! ([`PROMOTE_GRACE`] past the flush deadline): a follower that wakes
//! unfilled with its ticket still queued promotes itself to leader and
//! flushes the orphaned cohort. Shutdown uses [`Coalescer::begin_drain`]
//! (flush the in-flight accumulation now rather than waiting out
//! `max_wait`) and, after the drain deadline, [`Coalescer::abort_pending`]
//! (fail any still-queued tickets with an error instead of leaving their
//! threads parked forever); aborts are counted so the e2e drain test can
//! assert a graceful shutdown aborted nothing.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::engine::PredictEngine;
use crate::util::failpoint;

/// A scored batch: one assignment per row, plus the coverage fraction
/// when the backing scorer answered from less than the full center set
/// (`None` = complete — the common case, and the only case for a
/// single-node engine).
#[derive(Debug, Clone, PartialEq)]
pub struct Scored {
    /// One center index per query row.
    pub assignments: Vec<usize>,
    /// `Some(fraction < 1.0)` iff the answer is partial (sharded scoring
    /// under `--partial-results` with shards missing; docs/API.md).
    pub coverage: Option<f64>,
}

/// Why a batch (or one request) failed to score.
#[derive(Debug, Clone, PartialEq)]
pub enum ScoreError {
    /// A scoring dependency is down (e.g. a required shard did not
    /// answer). Affects every request in the batch identically, so the
    /// coalescer fails the cohort without retrying — retrying each
    /// request alone would multiply load on the failing dependency for
    /// the same outcome. Maps to 503 `shard_unavailable`.
    Unavailable(String),
    /// The scorer failed on this input (a contained panic, or an abort
    /// at shutdown). Batch-poisoning semantics apply: the coalescer
    /// retries each request alone so only the poisoned one(s) fail.
    /// Maps to 500 `prediction_failed`.
    Failed(String),
}

impl std::fmt::Display for ScoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScoreError::Unavailable(m) => write!(f, "{m}"),
            ScoreError::Failed(m) => write!(f, "{m}"),
        }
    }
}

impl ScoreError {
    /// The failure message, without the variant.
    pub fn message(&self) -> &str {
        match self {
            ScoreError::Unavailable(m) | ScoreError::Failed(m) => m,
        }
    }
}

/// Anything the coalescer can score a batch against: the single-node
/// [`PredictEngine`], or a `serve::shard::ShardSet` fanning the batch
/// out to shard replicas. Implementations must keep the engine's
/// batch-shape invariance (a row's assignment is independent of its
/// co-travellers) — the coalescer concatenates requests and slices
/// results on that guarantee.
pub trait Scorer: Send + Sync {
    /// Feature dimension (the HTTP layer validates shape against this).
    fn d(&self) -> usize;
    /// Number of centers.
    fn k(&self) -> usize;
    /// Score a validated batch. Panics are allowed — the coalescer runs
    /// this under `catch_unwind` and converts them to
    /// [`ScoreError::Failed`].
    fn score(&self, rows: &[f32]) -> Result<Scored, ScoreError>;
}

impl Scorer for PredictEngine {
    fn d(&self) -> usize {
        PredictEngine::d(self)
    }

    fn k(&self) -> usize {
        PredictEngine::k(self)
    }

    fn score(&self, rows: &[f32]) -> Result<Scored, ScoreError> {
        Ok(Scored { assignments: self.predict_batch(rows), coverage: None })
    }
}

/// How long past the leader's flush deadline a follower waits before
/// concluding the leader is gone and promoting itself. Generous relative
/// to `max_wait` so a merely-slow leader is never raced; promotion is
/// idempotent anyway (whoever locks the queue first claims the cohort).
const PROMOTE_GRACE: Duration = Duration::from_millis(100);

/// Tuning knobs for the admission queue.
#[derive(Debug, Clone)]
pub struct CoalesceConfig {
    /// How long the batch leader waits for co-travellers before flushing.
    pub max_wait: Duration,
    /// Flush early once this many rows are queued. Also the bypass
    /// threshold: a single request at or above it skips the queue and is
    /// dispatched directly (it is already a full batch).
    pub max_batch_rows: usize,
}

impl Default for CoalesceConfig {
    /// Defaults sized from the committed "prediction service" bench
    /// entries: a d=16 engine batch costs ~6 ms at bench scale, so a 2 ms
    /// wait adds less than one batch-time of latency while letting tens
    /// of 1-row requests share a flush; 512 rows is comfortably past the
    /// point where the panel kernels saturate.
    fn default() -> Self {
        CoalesceConfig { max_wait: Duration::from_micros(2000), max_batch_rows: 512 }
    }
}

/// A consistent snapshot of the service counters (`/healthz` exposes it;
/// the CI e2e job asserts `batches < requests` under concurrent load).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// Predict requests admitted (including bypassed large requests).
    pub requests: u64,
    /// Engine batches actually dispatched.
    pub batches: u64,
    /// Total rows scored.
    pub rows: u64,
    /// Batches that carried more than one request.
    pub coalesced_batches: u64,
    /// Largest single batch dispatched, in rows.
    pub max_batch_rows: u64,
    /// Requests failed by [`Coalescer::abort_pending`] at shutdown — zero
    /// under a graceful drain (pinned by the e2e drain test).
    pub aborted_requests: u64,
}

#[derive(Default)]
struct Queue {
    rows: Vec<f32>,
    tickets: Vec<Arc<Ticket>>,
}

/// One waiting request: where its rows sit in the accumulating batch and
/// a slot for its slice of the results (or the error that befell it).
struct Ticket {
    first_row: usize,
    n_rows: usize,
    result: Mutex<Option<Result<Scored, ScoreError>>>,
    ready: Condvar,
}

/// The admission queue in front of a [`Scorer`] (single-node engine or
/// sharded fleet).
pub struct Coalescer {
    scorer: Box<dyn Scorer>,
    cfg: CoalesceConfig,
    queue: Mutex<Queue>,
    arrivals: Condvar,
    draining: AtomicBool,
    requests: AtomicU64,
    batches: AtomicU64,
    rows: AtomicU64,
    coalesced_batches: AtomicU64,
    max_batch_rows: AtomicU64,
    aborted: AtomicU64,
}

/// Lock, shrugging off poisoning: the engine cannot leave shared state
/// half-written (tickets are write-once), so a panicking peer thread must
/// not wedge every connection behind a poisoned mutex.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Best-effort text of a caught panic payload.
fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "opaque panic payload".to_string())
}

impl Coalescer {
    /// Wrap a scorer (engine or shard set) with an admission queue.
    pub fn new(scorer: impl Scorer + 'static, cfg: CoalesceConfig) -> Coalescer {
        Coalescer {
            scorer: Box::new(scorer),
            cfg: CoalesceConfig { max_batch_rows: cfg.max_batch_rows.max(1), ..cfg },
            queue: Mutex::new(Queue::default()),
            arrivals: Condvar::new(),
            draining: AtomicBool::new(false),
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            coalesced_batches: AtomicU64::new(0),
            max_batch_rows: AtomicU64::new(0),
            aborted: AtomicU64::new(0),
        }
    }

    /// Feature dimension of the wrapped scorer (the HTTP layer's shape
    /// checks happen against this).
    pub fn d(&self) -> usize {
        self.scorer.d()
    }

    /// Number of centers served.
    pub fn k(&self) -> usize {
        self.scorer.k()
    }

    /// Current counter values.
    pub fn stats(&self) -> StatsSnapshot {
        StatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            coalesced_batches: self.coalesced_batches.load(Ordering::Relaxed),
            max_batch_rows: self.max_batch_rows.load(Ordering::Relaxed),
            aborted_requests: self.aborted.load(Ordering::Relaxed),
        }
    }

    fn note_batch(&self, batch_rows: usize, batch_requests: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(batch_rows as u64, Ordering::Relaxed);
        if batch_requests > 1 {
            self.coalesced_batches.fetch_add(1, Ordering::Relaxed);
        }
        self.max_batch_rows.fetch_max(batch_rows as u64, Ordering::Relaxed);
    }

    /// Score `rows` (length must be a multiple of the scorer dimension —
    /// the HTTP layer validates shape *before* admission) and return one
    /// assignment per row. Blocks the calling thread until its batch is
    /// flushed; a successful complete result is bit-identical to calling
    /// the engine (or the scalar path) on these rows alone.
    /// `Err(Failed)` means *this* request failed — it panicked the scorer
    /// even when retried alone, or was aborted at shutdown;
    /// co-travellers are unaffected. `Err(Unavailable)` means a scoring
    /// dependency was down for the whole batch.
    pub fn submit(&self, rows: Vec<f32>) -> Result<Scored, ScoreError> {
        let d = self.scorer.d().max(1);
        assert_eq!(rows.len() % d, 0, "submit() requires validated row shapes");
        let n = rows.len() / d;
        self.requests.fetch_add(1, Ordering::Relaxed);
        if n == 0 {
            return Ok(Scored { assignments: Vec::new(), coverage: None });
        }
        // A full-batch-sized request gains nothing from waiting: dispatch
        // directly so it neither queues behind the deadline nor makes
        // smaller co-travellers wait behind its compute.
        if n >= self.cfg.max_batch_rows {
            let scored = self.score_guarded(&rows)?;
            self.note_batch(n, 1);
            return Ok(scored);
        }

        let mut q = lock(&self.queue);
        let first_row = q.rows.len() / d;
        q.rows.extend_from_slice(&rows);
        let ticket = Arc::new(Ticket {
            first_row,
            n_rows: n,
            result: Mutex::new(None),
            ready: Condvar::new(),
        });
        q.tickets.push(ticket.clone());
        let leader = q.tickets.len() == 1;

        if !leader {
            if q.rows.len() / d >= self.cfg.max_batch_rows {
                // Batch is full: wake the leader early.
                self.arrivals.notify_all();
            }
            drop(q);
            return self.await_ticket(&ticket);
        }

        // Leader: wait out the deadline (or an early full-batch wake, or a
        // drain — which flushes the in-flight accumulation immediately),
        // then take the whole queue and flush it as one engine call.
        let deadline = Instant::now() + self.cfg.max_wait;
        loop {
            if q.rows.len() / d >= self.cfg.max_batch_rows {
                break;
            }
            if self.draining.load(Ordering::Relaxed) {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            q = self
                .arrivals
                .wait_timeout(q, deadline - now)
                .unwrap_or_else(|p| p.into_inner())
                .0;
        }
        let batch = std::mem::take(&mut q.rows);
        let tickets = std::mem::take(&mut q.tickets);
        drop(q);

        if tickets.iter().any(|t| Arc::ptr_eq(t, &ticket)) {
            return self
                .flush(batch, tickets, Some(&ticket))
                .expect("own ticket was in the flushed cohort");
        }
        // Our cohort (our ticket included) was claimed while we slept — by
        // a promoted follower or a shutdown abort. Whatever we just took
        // belongs to a *newer* accumulation: flush it for its owners, then
        // collect our own result from whoever claimed our ticket.
        if !tickets.is_empty() {
            self.flush(batch, tickets, None);
        }
        self.await_ticket(&ticket)
    }

    /// Run the scorer on `rows` under `catch_unwind`, converting a panic
    /// (organic, or injected through the `coalesce.flush` failpoint) into
    /// a [`ScoreError::Failed`] instead of killing the calling connection
    /// thread. A scorer-level `Err` passes through with its variant.
    fn score_guarded(&self, rows: &[f32]) -> Result<Scored, ScoreError> {
        catch_unwind(AssertUnwindSafe(|| {
            if failpoint::armed() {
                if let Some(fault) = failpoint::eval("coalesce.flush") {
                    match fault {
                        failpoint::Fault::Panic => {
                            panic!("failpoint coalesce.flush: injected panic")
                        }
                        failpoint::Fault::Err(msg) => panic!("failpoint coalesce.flush: {msg}"),
                    }
                }
            }
            self.scorer.score(rows)
        }))
        .unwrap_or_else(|p| Err(ScoreError::Failed(panic_message(p))))
    }

    /// Flush a claimed cohort: one guarded scorer call; on a poisoned
    /// batch, retry every request alone so exactly the poisoned one(s)
    /// fail. An `Unavailable` batch fails the whole cohort *without*
    /// per-request retries — a down dependency answers every retry the
    /// same way, so retrying alone would only multiply load on it.
    /// Fills and wakes every ticket except `own`, whose result is
    /// returned (`None` iff `own` is `None`).
    fn flush(
        &self,
        batch: Vec<f32>,
        tickets: Vec<Arc<Ticket>>,
        own: Option<&Arc<Ticket>>,
    ) -> Option<Result<Scored, ScoreError>> {
        let d = self.scorer.d().max(1);
        let mut own_result = None;
        let mut deliver = |t: &Arc<Ticket>, res: Result<Scored, ScoreError>| {
            if own.is_some_and(|o| Arc::ptr_eq(t, o)) {
                own_result = Some(res);
            } else {
                *lock(&t.result) = Some(res);
                t.ready.notify_one();
            }
        };
        match self.score_guarded(&batch) {
            Ok(scored) => {
                self.note_batch(batch.len() / d, tickets.len());
                for t in &tickets {
                    deliver(
                        t,
                        Ok(Scored {
                            assignments: scored.assignments
                                [t.first_row..t.first_row + t.n_rows]
                                .to_vec(),
                            coverage: scored.coverage,
                        }),
                    );
                }
            }
            Err(ScoreError::Unavailable(msg)) => {
                for t in &tickets {
                    deliver(t, Err(ScoreError::Unavailable(msg.clone())));
                }
            }
            Err(ScoreError::Failed(batch_msg)) => {
                // The batch is poisoned: some request in it takes the
                // scorer down. Retry each alone so co-travellers of the
                // poisoned request still get their (bit-identical) answer.
                for t in &tickets {
                    let lo = t.first_row * d;
                    let hi = lo + t.n_rows * d;
                    let res = match self.score_guarded(&batch[lo..hi]) {
                        Ok(scored) => {
                            self.note_batch(t.n_rows, 1);
                            Ok(scored)
                        }
                        Err(ScoreError::Unavailable(m)) => Err(ScoreError::Unavailable(m)),
                        Err(ScoreError::Failed(m)) => Err(ScoreError::Failed(format!(
                            "prediction batch failed ({batch_msg}); \
                             this request also failed alone: {m}"
                        ))),
                    };
                    deliver(t, res);
                }
            }
        }
        own_result
    }

    /// Park on a ticket until a flusher fills it. If the wait times out
    /// with the ticket *still queued*, the leader died before claiming the
    /// batch — promote ourselves and flush the orphaned cohort. (Unqueued
    /// but unfilled just means the claimer is still computing: keep
    /// waiting.)
    fn await_ticket(&self, ticket: &Arc<Ticket>) -> Result<Scored, ScoreError> {
        let promote_after = self.cfg.max_wait + PROMOTE_GRACE;
        loop {
            let mut slot = lock(&ticket.result);
            loop {
                if let Some(res) = slot.take() {
                    return res;
                }
                let (g, timeout) = ticket
                    .ready
                    .wait_timeout(slot, promote_after)
                    .unwrap_or_else(|p| p.into_inner());
                slot = g;
                if timeout.timed_out() {
                    break;
                }
            }
            if let Some(res) = slot.take() {
                return res;
            }
            drop(slot);
            let mut q = lock(&self.queue);
            if q.tickets.iter().any(|t| Arc::ptr_eq(t, ticket)) {
                let batch = std::mem::take(&mut q.rows);
                let tickets = std::mem::take(&mut q.tickets);
                drop(q);
                return self
                    .flush(batch, tickets, Some(ticket))
                    .expect("own ticket was in the promoted cohort");
            }
        }
    }

    /// Enter drain mode: the current accumulation flushes immediately
    /// instead of waiting out `max_wait`, so a graceful shutdown completes
    /// in-flight coalesced batches quickly rather than aborting them.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::Relaxed);
        self.arrivals.notify_all();
    }

    /// Last-resort shutdown: fail every still-queued ticket with `reason`
    /// so no connection thread stays parked past the drain deadline.
    /// Returns the number of requests aborted (counted in
    /// [`StatsSnapshot::aborted_requests`]).
    pub fn abort_pending(&self, reason: &str) -> usize {
        let tickets = {
            let mut q = lock(&self.queue);
            q.rows.clear();
            std::mem::take(&mut q.tickets)
        };
        for t in &tickets {
            *lock(&t.result) = Some(Err(ScoreError::Failed(reason.to_string())));
            t.ready.notify_one();
        }
        self.aborted.fetch_add(tickets.len() as u64, Ordering::Relaxed);
        self.arrivals.notify_all();
        tickets.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::data::Dataset;
    use crate::kernels::KernelFunction;
    use crate::kkmeans::{CenterWindow, KernelKMeansModel};
    use crate::util::rng::Rng;
    use std::sync::Arc;

    /// A small servable model + dataset (mirrors conformance_serve's
    /// helper: irregular support sizes, no full fit).
    fn model_for(d: usize, seed: u64) -> (Dataset, KernelKMeansModel) {
        let mut rng = Rng::seeded(seed);
        let ds = blobs(&SyntheticSpec::new(120, d, 3), &mut rng);
        let mut windows: Vec<CenterWindow> =
            (0..3).map(|j| CenterWindow::new(j * 7, 23)).collect();
        for step in 0..12 {
            for (j, w) in windows.iter_mut().enumerate() {
                let pts: Vec<usize> =
                    (0..1 + (step + j) % 5).map(|_| rng.below(ds.n)).collect();
                w.apply_update(0.4, &pts, None);
            }
        }
        let kernel = KernelFunction::Gaussian { kappa: 2.0 };
        let model = KernelKMeansModel::freeze(&ds, kernel, &mut windows);
        (ds, model)
    }

    fn rows_from(ds: &Dataset, idx: &[usize]) -> Vec<f32> {
        idx.iter().flat_map(|&i| ds.row(i).to_vec()).collect()
    }

    #[test]
    fn single_submit_matches_engine() {
        let (ds, model) = model_for(6, 11);
        let rows = rows_from(&ds, &(0..32).collect::<Vec<_>>());
        let engine = PredictEngine::new(&model);
        let want = engine.predict_batch(&rows);
        let co = Coalescer::new(
            PredictEngine::new(&model),
            CoalesceConfig { max_wait: Duration::from_micros(200), max_batch_rows: 512 },
        );
        let scored = co.submit(rows).unwrap();
        assert_eq!(scored.assignments, want);
        assert_eq!(scored.coverage, None, "a single-node engine is always complete");
        let s = co.stats();
        assert_eq!((s.requests, s.batches, s.rows), (1, 1, 32));
        assert_eq!(s.coalesced_batches, 0);
    }

    #[test]
    fn empty_submit_returns_empty() {
        let (_ds, model) = model_for(4, 3);
        let co = Coalescer::new(PredictEngine::new(&model), CoalesceConfig::default());
        assert!(co.submit(Vec::new()).unwrap().assignments.is_empty());
        assert_eq!(co.stats().batches, 0);
    }

    #[test]
    fn oversized_request_bypasses_queue() {
        let (ds, model) = model_for(4, 5);
        let rows = rows_from(&ds, &(0..100).collect::<Vec<_>>());
        let co = Coalescer::new(
            PredictEngine::new(&model),
            CoalesceConfig { max_wait: Duration::from_millis(250), max_batch_rows: 8 },
        );
        let t0 = Instant::now();
        let preds = co.submit(rows.clone()).unwrap();
        // Bypass must not wait out the 250 ms deadline.
        assert!(t0.elapsed() < Duration::from_millis(200), "bypass waited on the deadline");
        assert_eq!(preds.assignments, PredictEngine::new(&model).predict_batch(&rows));
        assert_eq!(co.stats().max_batch_rows, 100);
    }

    #[test]
    fn concurrent_submits_coalesce_and_stay_bit_identical() {
        let (ds, model) = model_for(8, 21);
        let engine = PredictEngine::new(&model);
        let co = Arc::new(Coalescer::new(
            PredictEngine::new(&model),
            CoalesceConfig { max_wait: Duration::from_millis(30), max_batch_rows: 4096 },
        ));
        let mixes: Vec<Vec<usize>> = (0..12)
            .map(|t| (0..(1 + t % 5)).map(|j| (t * 19 + j * 3) % ds.n).collect())
            .collect();
        let mut handles = Vec::new();
        for idx in mixes.clone() {
            let co = co.clone();
            let rows = rows_from(&ds, &idx);
            handles.push(std::thread::spawn(move || co.submit(rows).unwrap()));
        }
        let got: Vec<Scored> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (idx, preds) in mixes.iter().zip(&got) {
            let want = engine.predict_batch(&rows_from(&ds, idx));
            assert_eq!(preds.assignments, want, "coalesced result diverged for mix {idx:?}");
        }
        let s = co.stats();
        assert_eq!(s.requests, 12);
        assert!(s.batches < s.requests, "no coalescing happened: {s:?}");
        assert!(s.coalesced_batches >= 1);
        assert_eq!(s.rows as usize, mixes.iter().map(Vec::len).sum::<usize>());
    }

    #[test]
    fn full_batch_trigger_flushes_before_deadline() {
        let (ds, model) = model_for(4, 9);
        let co = Arc::new(Coalescer::new(
            PredictEngine::new(&model),
            // Long deadline: only the max_batch_rows trigger can flush fast.
            CoalesceConfig { max_wait: Duration::from_secs(5), max_batch_rows: 4 },
        ));
        let t0 = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let co = co.clone();
                let rows = rows_from(&ds, &[t * 5, t * 5 + 1]);
                std::thread::spawn(move || co.submit(rows))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "flush waited for the deadline instead of the full-batch trigger"
        );
    }

    #[test]
    fn poisoned_batch_fails_alone_and_cohort_survives() {
        // ADR-003 resolution: a panic during the leader's flush must fail
        // only the poisoned request. `2*panic` makes the batch flush panic
        // (hit 1) and the first individual retry panic (hit 2); every
        // other retry succeeds — so exactly one submission errors no
        // matter how the twelve requests happened to batch.
        let _x = failpoint::exclusive_test_lock();
        failpoint::configure("coalesce.flush=2*panic").unwrap();
        let (ds, model) = model_for(6, 41);
        let engine = PredictEngine::new(&model);
        let co = Arc::new(Coalescer::new(
            PredictEngine::new(&model),
            CoalesceConfig { max_wait: Duration::from_millis(30), max_batch_rows: 4096 },
        ));
        let mixes: Vec<Vec<usize>> = (0..12)
            .map(|t| (0..(1 + t % 4)).map(|j| (t * 13 + j * 5) % ds.n).collect())
            .collect();
        let mut handles = Vec::new();
        for idx in mixes.clone() {
            let co = co.clone();
            let rows = rows_from(&ds, &idx);
            handles.push(std::thread::spawn(move || co.submit(rows)));
        }
        let got: Vec<Result<Scored, ScoreError>> =
            handles.into_iter().map(|h| h.join().expect("no thread may die")).collect();
        failpoint::clear("coalesce.flush");
        let errs = got.iter().filter(|r| r.is_err()).count();
        assert_eq!(errs, 1, "exactly the poisoned request fails: {got:?}");
        assert!(
            got.iter().all(|r| !matches!(r, Err(ScoreError::Unavailable(_)))),
            "a poisoned batch is Failed, never Unavailable: {got:?}"
        );
        for (idx, res) in mixes.iter().zip(&got) {
            if let Ok(preds) = res {
                let want = engine.predict_batch(&rows_from(&ds, idx));
                assert_eq!(preds.assignments, want, "survivor diverged for mix {idx:?}");
            }
        }
    }

    /// Plant a ticket + rows in the queue as if its leader thread died
    /// after enqueueing but before claiming the batch.
    fn plant_orphan(co: &Coalescer, rows: &[f32], n_rows: usize) -> Arc<Ticket> {
        let orphan = Arc::new(Ticket {
            first_row: 0,
            n_rows,
            result: Mutex::new(None),
            ready: Condvar::new(),
        });
        let mut q = lock(&co.queue);
        q.rows.extend_from_slice(rows);
        q.tickets.push(orphan.clone());
        orphan
    }

    #[test]
    fn dead_leader_cohort_is_rescued_by_promotion() {
        let (ds, model) = model_for(6, 31);
        let engine = PredictEngine::new(&model);
        let co = Coalescer::new(
            PredictEngine::new(&model),
            CoalesceConfig { max_wait: Duration::from_millis(2), max_batch_rows: 512 },
        );
        let rows_a = rows_from(&ds, &[1, 2]);
        let orphan = plant_orphan(&co, &rows_a, 2);
        // This submission is a follower (queue non-empty). No leader will
        // ever flush, so it must time out, promote itself, and flush the
        // whole cohort — including the dead leader's ticket.
        let rows_b = rows_from(&ds, &[5, 6, 7]);
        let got = co.submit(rows_b.clone()).unwrap();
        assert_eq!(got.assignments, engine.predict_batch(&rows_b));
        let rescued = lock(&orphan.result)
            .take()
            .expect("promoted follower fills the orphaned ticket")
            .unwrap();
        assert_eq!(rescued.assignments, engine.predict_batch(&rows_a));
    }

    #[test]
    fn abort_pending_fails_queued_tickets() {
        let (ds, model) = model_for(4, 17);
        let co = Coalescer::new(PredictEngine::new(&model), CoalesceConfig::default());
        let rows = rows_from(&ds, &[3]);
        let orphan = plant_orphan(&co, &rows, 1);
        co.begin_drain();
        assert_eq!(co.abort_pending("server shutting down"), 1);
        let res = lock(&orphan.result).take().expect("abort fills the ticket");
        assert!(res.is_err(), "aborted ticket must carry an error");
        assert_eq!(co.stats().aborted_requests, 1);
        // The queue is clean afterwards: a fresh submission works.
        assert_eq!(
            co.submit(rows.clone()).unwrap().assignments,
            PredictEngine::new(&model).predict_batch(&rows)
        );
    }

    /// A scorer whose dependency is down for the first `down_for` calls
    /// (then delegates to a real engine), and which reports coverage.
    struct FlakyScorer {
        engine: PredictEngine,
        down_for: AtomicU64,
        coverage: Option<f64>,
    }

    impl Scorer for FlakyScorer {
        fn d(&self) -> usize {
            self.engine.d()
        }
        fn k(&self) -> usize {
            self.engine.k()
        }
        fn score(&self, rows: &[f32]) -> Result<Scored, ScoreError> {
            if self
                .down_for
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
                .is_ok()
            {
                return Err(ScoreError::Unavailable("shard 1 did not answer".to_string()));
            }
            Ok(Scored { assignments: self.engine.predict_batch(rows), coverage: self.coverage })
        }
    }

    #[test]
    fn unavailable_scorer_fails_the_cohort_without_retries() {
        let (ds, model) = model_for(5, 57);
        let co = Arc::new(Coalescer::new(
            FlakyScorer {
                engine: PredictEngine::new(&model),
                // Down for exactly one batch: if the coalescer retried the
                // cohort per-request, later requests would succeed and the
                // failure count would drop below the cohort size.
                down_for: AtomicU64::new(1),
                coverage: None,
            },
            CoalesceConfig { max_wait: Duration::from_millis(30), max_batch_rows: 4096 },
        ));
        let mut handles = Vec::new();
        for t in 0..6 {
            let co = co.clone();
            let rows = rows_from(&ds, &[t * 7, t * 7 + 2]);
            handles.push(std::thread::spawn(move || co.submit(rows)));
        }
        let got: Vec<Result<Scored, ScoreError>> =
            handles.into_iter().map(|h| h.join().expect("no thread may die")).collect();
        let unavailable =
            got.iter().filter(|r| matches!(r, Err(ScoreError::Unavailable(_)))).count();
        let ok = got.iter().filter(|r| r.is_ok()).count();
        // Whatever the batching pattern, every member of the batch that
        // hit the outage fails Unavailable (≥1), nobody fails Failed, and
        // requests in later batches succeed.
        assert_eq!(unavailable + ok, 6, "no request may fail as Failed: {got:?}");
        assert!(unavailable >= 1, "the outage batch must surface: {got:?}");
    }

    #[test]
    fn coverage_propagates_to_every_cohort_member() {
        let (ds, model) = model_for(4, 71);
        let engine = PredictEngine::new(&model);
        let co = Arc::new(Coalescer::new(
            FlakyScorer {
                engine: PredictEngine::new(&model),
                down_for: AtomicU64::new(0),
                coverage: Some(2.0 / 3.0),
            },
            CoalesceConfig { max_wait: Duration::from_millis(30), max_batch_rows: 4096 },
        ));
        let mixes: Vec<Vec<usize>> = (0..5).map(|t| vec![t * 11, t * 11 + 3]).collect();
        let mut handles = Vec::new();
        for idx in mixes.clone() {
            let co = co.clone();
            let rows = rows_from(&ds, &idx);
            handles.push(std::thread::spawn(move || co.submit(rows).unwrap()));
        }
        for (idx, scored) in mixes.iter().zip(handles.into_iter().map(|h| h.join().unwrap())) {
            assert_eq!(scored.coverage, Some(2.0 / 3.0));
            assert_eq!(scored.assignments, engine.predict_batch(&rows_from(&ds, idx)));
        }
    }
}
