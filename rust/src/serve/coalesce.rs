//! Request-coalescing admission queue for the HTTP service (ADR-003).
//!
//! Under many concurrent 1-row requests, dispatching each straight into
//! [`PredictEngine`] wastes the engine's batch shape: the panel kernels
//! and the worker pool amortize over rows, so k single-row batches cost
//! nearly k times one k-row batch. The coalescer turns that load pattern
//! back into batches: concurrent submissions accumulate behind a small
//! deadline (`max_wait`, sized from the bench model's per-batch cost) and
//! flush as ONE engine batch; each caller gets back exactly its slice.
//!
//! The scheme is leader/follower. The first thread to enqueue into an
//! empty queue becomes the *leader*: it waits out the deadline (or an
//! early wake when `max_batch_rows` accumulates), takes the whole queue,
//! runs the engine once, and distributes results to the followers'
//! tickets. Followers just park on their ticket. A request arriving while
//! a flush is in progress starts a fresh accumulation — batches overlap
//! with waiting, so throughput does not gate on the slowest client.
//!
//! **Bit-identity:** the engine guarantees batched output equal to the
//! scalar path for *any* batch size and thread count, so concatenating
//! requests and slicing the result per ticket cannot change any caller's
//! answer. `conformance_http.rs` pins this end to end.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::engine::PredictEngine;

/// Tuning knobs for the admission queue.
#[derive(Debug, Clone)]
pub struct CoalesceConfig {
    /// How long the batch leader waits for co-travellers before flushing.
    pub max_wait: Duration,
    /// Flush early once this many rows are queued. Also the bypass
    /// threshold: a single request at or above it skips the queue and is
    /// dispatched directly (it is already a full batch).
    pub max_batch_rows: usize,
}

impl Default for CoalesceConfig {
    /// Defaults sized from the committed "prediction service" bench
    /// entries: a d=16 engine batch costs ~6 ms at bench scale, so a 2 ms
    /// wait adds less than one batch-time of latency while letting tens
    /// of 1-row requests share a flush; 512 rows is comfortably past the
    /// point where the panel kernels saturate.
    fn default() -> Self {
        CoalesceConfig { max_wait: Duration::from_micros(2000), max_batch_rows: 512 }
    }
}

/// A consistent snapshot of the service counters (`/healthz` exposes it;
/// the CI e2e job asserts `batches < requests` under concurrent load).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// Predict requests admitted (including bypassed large requests).
    pub requests: u64,
    /// Engine batches actually dispatched.
    pub batches: u64,
    /// Total rows scored.
    pub rows: u64,
    /// Batches that carried more than one request.
    pub coalesced_batches: u64,
    /// Largest single batch dispatched, in rows.
    pub max_batch_rows: u64,
}

#[derive(Default)]
struct Queue {
    rows: Vec<f32>,
    tickets: Vec<std::sync::Arc<Ticket>>,
}

/// One waiting request: where its rows sit in the accumulating batch and
/// a slot for its slice of the results.
struct Ticket {
    first_row: usize,
    n_rows: usize,
    result: Mutex<Option<Vec<usize>>>,
    ready: Condvar,
}

/// The admission queue in front of a [`PredictEngine`].
pub struct Coalescer {
    engine: PredictEngine,
    cfg: CoalesceConfig,
    queue: Mutex<Queue>,
    arrivals: Condvar,
    requests: AtomicU64,
    batches: AtomicU64,
    rows: AtomicU64,
    coalesced_batches: AtomicU64,
    max_batch_rows: AtomicU64,
}

/// Lock, shrugging off poisoning: the engine cannot leave shared state
/// half-written (tickets are write-once), so a panicking peer thread must
/// not wedge every connection behind a poisoned mutex.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl Coalescer {
    /// Wrap an engine with an admission queue.
    pub fn new(engine: PredictEngine, cfg: CoalesceConfig) -> Coalescer {
        Coalescer {
            engine,
            cfg: CoalesceConfig { max_batch_rows: cfg.max_batch_rows.max(1), ..cfg },
            queue: Mutex::new(Queue::default()),
            arrivals: Condvar::new(),
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            coalesced_batches: AtomicU64::new(0),
            max_batch_rows: AtomicU64::new(0),
        }
    }

    /// The wrapped engine (dimension checks happen against this).
    pub fn engine(&self) -> &PredictEngine {
        &self.engine
    }

    /// Current counter values.
    pub fn stats(&self) -> StatsSnapshot {
        StatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            coalesced_batches: self.coalesced_batches.load(Ordering::Relaxed),
            max_batch_rows: self.max_batch_rows.load(Ordering::Relaxed),
        }
    }

    fn note_batch(&self, batch_rows: usize, batch_requests: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(batch_rows as u64, Ordering::Relaxed);
        if batch_requests > 1 {
            self.coalesced_batches.fetch_add(1, Ordering::Relaxed);
        }
        self.max_batch_rows.fetch_max(batch_rows as u64, Ordering::Relaxed);
    }

    /// Score `rows` (length must be a multiple of the engine dimension —
    /// the HTTP layer validates shape *before* admission) and return one
    /// assignment per row. Blocks the calling thread until its batch is
    /// flushed; the result is bit-identical to calling the engine (or the
    /// scalar path) on these rows alone.
    pub fn submit(&self, rows: Vec<f32>) -> Vec<usize> {
        let d = self.engine.d();
        assert_eq!(rows.len() % d.max(1), 0, "submit() requires validated row shapes");
        let n = rows.len() / d.max(1);
        self.requests.fetch_add(1, Ordering::Relaxed);
        if n == 0 {
            return Vec::new();
        }
        // A full-batch-sized request gains nothing from waiting: dispatch
        // directly so it neither queues behind the deadline nor makes
        // smaller co-travellers wait behind its compute.
        if n >= self.cfg.max_batch_rows {
            let preds = self.engine.predict_batch(&rows);
            self.note_batch(n, 1);
            return preds;
        }

        let mut q = lock(&self.queue);
        let first_row = q.rows.len() / d.max(1);
        q.rows.extend_from_slice(&rows);
        let ticket = std::sync::Arc::new(Ticket {
            first_row,
            n_rows: n,
            result: Mutex::new(None),
            ready: Condvar::new(),
        });
        q.tickets.push(ticket.clone());
        let leader = q.tickets.len() == 1;

        if !leader {
            if q.rows.len() / d.max(1) >= self.cfg.max_batch_rows {
                // Batch is full: wake the leader early.
                self.arrivals.notify_all();
            }
            drop(q);
            let mut slot = lock(&ticket.result);
            while slot.is_none() {
                slot = ticket.ready.wait(slot).unwrap_or_else(|p| p.into_inner());
            }
            return slot.take().expect("ticket filled");
        }

        // Leader: wait out the deadline (or an early full-batch wake),
        // then take the whole queue and flush it as one engine call.
        let deadline = Instant::now() + self.cfg.max_wait;
        loop {
            if q.rows.len() / d.max(1) >= self.cfg.max_batch_rows {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            q = self
                .arrivals
                .wait_timeout(q, deadline - now)
                .unwrap_or_else(|p| p.into_inner())
                .0;
        }
        let batch = std::mem::take(&mut q.rows);
        let tickets = std::mem::take(&mut q.tickets);
        drop(q);

        let preds = self.engine.predict_batch(&batch);
        self.note_batch(batch.len() / d.max(1), tickets.len());

        let mut own = None;
        for t in tickets {
            let slice = preds[t.first_row..t.first_row + t.n_rows].to_vec();
            if std::sync::Arc::ptr_eq(&t, &ticket) {
                own = Some(slice);
                continue;
            }
            *lock(&t.result) = Some(slice);
            t.ready.notify_one();
        }
        own.expect("leader ticket present in its own batch")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::data::Dataset;
    use crate::kernels::KernelFunction;
    use crate::kkmeans::{CenterWindow, KernelKMeansModel};
    use crate::util::rng::Rng;
    use std::sync::Arc;

    /// A small servable model + dataset (mirrors conformance_serve's
    /// helper: irregular support sizes, no full fit).
    fn model_for(d: usize, seed: u64) -> (Dataset, KernelKMeansModel) {
        let mut rng = Rng::seeded(seed);
        let ds = blobs(&SyntheticSpec::new(120, d, 3), &mut rng);
        let mut windows: Vec<CenterWindow> =
            (0..3).map(|j| CenterWindow::new(j * 7, 23)).collect();
        for step in 0..12 {
            for (j, w) in windows.iter_mut().enumerate() {
                let pts: Vec<usize> =
                    (0..1 + (step + j) % 5).map(|_| rng.below(ds.n)).collect();
                w.apply_update(0.4, &pts, None);
            }
        }
        let kernel = KernelFunction::Gaussian { kappa: 2.0 };
        let model = KernelKMeansModel::freeze(&ds, kernel, &mut windows);
        (ds, model)
    }

    fn rows_from(ds: &Dataset, idx: &[usize]) -> Vec<f32> {
        idx.iter().flat_map(|&i| ds.row(i).to_vec()).collect()
    }

    #[test]
    fn single_submit_matches_engine() {
        let (ds, model) = model_for(6, 11);
        let rows = rows_from(&ds, &(0..32).collect::<Vec<_>>());
        let engine = PredictEngine::new(&model);
        let want = engine.predict_batch(&rows);
        let co = Coalescer::new(
            PredictEngine::new(&model),
            CoalesceConfig { max_wait: Duration::from_micros(200), max_batch_rows: 512 },
        );
        assert_eq!(co.submit(rows), want);
        let s = co.stats();
        assert_eq!((s.requests, s.batches, s.rows), (1, 1, 32));
        assert_eq!(s.coalesced_batches, 0);
    }

    #[test]
    fn empty_submit_returns_empty() {
        let (_ds, model) = model_for(4, 3);
        let co = Coalescer::new(PredictEngine::new(&model), CoalesceConfig::default());
        assert!(co.submit(Vec::new()).is_empty());
        assert_eq!(co.stats().batches, 0);
    }

    #[test]
    fn oversized_request_bypasses_queue() {
        let (ds, model) = model_for(4, 5);
        let rows = rows_from(&ds, &(0..100).collect::<Vec<_>>());
        let co = Coalescer::new(
            PredictEngine::new(&model),
            CoalesceConfig { max_wait: Duration::from_millis(250), max_batch_rows: 8 },
        );
        let t0 = Instant::now();
        let preds = co.submit(rows.clone());
        // Bypass must not wait out the 250 ms deadline.
        assert!(t0.elapsed() < Duration::from_millis(200), "bypass waited on the deadline");
        assert_eq!(preds, PredictEngine::new(&model).predict_batch(&rows));
        assert_eq!(co.stats().max_batch_rows, 100);
    }

    #[test]
    fn concurrent_submits_coalesce_and_stay_bit_identical() {
        let (ds, model) = model_for(8, 21);
        let engine = PredictEngine::new(&model);
        let co = Arc::new(Coalescer::new(
            PredictEngine::new(&model),
            CoalesceConfig { max_wait: Duration::from_millis(30), max_batch_rows: 4096 },
        ));
        let mixes: Vec<Vec<usize>> = (0..12)
            .map(|t| (0..(1 + t % 5)).map(|j| (t * 19 + j * 3) % ds.n).collect())
            .collect();
        let mut handles = Vec::new();
        for idx in mixes.clone() {
            let co = co.clone();
            let rows = rows_from(&ds, &idx);
            handles.push(std::thread::spawn(move || co.submit(rows)));
        }
        let got: Vec<Vec<usize>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (idx, preds) in mixes.iter().zip(&got) {
            let want = engine.predict_batch(&rows_from(&ds, idx));
            assert_eq!(preds, &want, "coalesced result diverged for mix {idx:?}");
        }
        let s = co.stats();
        assert_eq!(s.requests, 12);
        assert!(s.batches < s.requests, "no coalescing happened: {s:?}");
        assert!(s.coalesced_batches >= 1);
        assert_eq!(s.rows as usize, mixes.iter().map(Vec::len).sum::<usize>());
    }

    #[test]
    fn full_batch_trigger_flushes_before_deadline() {
        let (ds, model) = model_for(4, 9);
        let co = Arc::new(Coalescer::new(
            PredictEngine::new(&model),
            // Long deadline: only the max_batch_rows trigger can flush fast.
            CoalesceConfig { max_wait: Duration::from_secs(5), max_batch_rows: 4 },
        ));
        let t0 = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let co = co.clone();
                let rows = rows_from(&ds, &[t * 5, t * 5 + 1]);
                std::thread::spawn(move || co.submit(rows))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "flush waited for the deadline instead of the full-batch trigger"
        );
    }
}
