//! Model persistence and the batched prediction service (DESIGN.md §8).
//!
//! The truncated representation makes a fitted kernel k-means model
//! *servable* — assigning a new point costs O(k·(τ+b)) kernel evaluations
//! with no access to the training set — and this module gives that a
//! production shape:
//!
//! * [`format`] — the versioned on-disk artifact format behind
//!   `KernelKMeansModel::{save, load}` and
//!   `StreamingKernelKMeans::{snapshot, resume}` (zero-dep: a JSON header
//!   via `util::json` plus a little-endian binary payload).
//! * [`PredictEngine`] — batched query answering through packed support
//!   panels and the persistent worker pool, bit-identical to the scalar
//!   `KernelKMeansModel::predict`.
//!
//! The CLI's `fit` / `predict` / `serve-bench` subcommands are thin
//! drivers over these two pieces plus
//! `coordinator::experiment::fit_servable_model`.

pub mod engine;
pub mod format;

pub use engine::PredictEngine;
