//! Model persistence and the batched prediction service (DESIGN.md §8).
//!
//! The truncated representation makes a fitted kernel k-means model
//! *servable* — assigning a new point costs O(k·(τ+b)) kernel evaluations
//! with no access to the training set — and this module gives that a
//! production shape:
//!
//! * [`format`] — the versioned on-disk artifact format behind
//!   `KernelKMeansModel::{save, load}` and
//!   `StreamingKernelKMeans::{snapshot, resume}` (zero-dep: a JSON header
//!   via `util::json` plus a little-endian binary payload).
//! * [`PredictEngine`] — batched query answering through packed support
//!   panels and the persistent worker pool, bit-identical to the scalar
//!   `KernelKMeansModel::predict`.
//! * [`http`] — the zero-dependency HTTP/1.1 service over the engine
//!   (`POST /v1/predict`, `GET /v1/models`, `GET /healthz` — docs/API.md),
//!   with [`coalesce`]'s request-coalescing admission queue and
//!   [`wire`]'s bounded request framing (DESIGN.md §11, ADR-003).
//! * [`shard`] — fault-tolerant sharded scoring (DESIGN.md §14): the
//!   support set split into contiguous center ranges, each served by a
//!   replica set (in-process or remote `mbkk shard-worker`) with
//!   retry/backoff, ejection, probe re-admission, and a strict-vs-partial
//!   merge that is bit-identical to the single-node engine.
//! * [`replicate`] — log-suffix delta replication over the coefficient
//!   log (kind-`delta` artifacts) and the hot-swap multi-model registry
//!   behind `?model=` routing (DESIGN.md §14, ADR-006).
//!
//! The CLI's `fit` / `predict` / `serve-bench` / `serve` / `shard-worker`
//! subcommands are thin drivers over these pieces plus
//! `coordinator::experiment::fit_servable_model`.

pub mod coalesce;
pub mod engine;
pub mod format;
pub mod http;
pub mod replicate;
pub mod shard;
pub mod wire;

pub use engine::PredictEngine;
