//! The batched prediction hot path (DESIGN.md §8).
//!
//! [`KernelKMeansModel::distances`] serves one query at a time: every
//! (query, support) kernel value is a loop-carried f64 dot chain, so the
//! CPU retires roughly one fused-multiply-add per FP-add *latency* and
//! the SIMD units idle — the same pathology the panel engine (DESIGN.md
//! §7) removed from training. [`PredictEngine`] applies the identical
//! cure to serving:
//!
//! * the model's support rows are packed **once at construction** into
//!   dimension-major [`PANEL_COLS`]-wide f64 panels (they are frozen, so
//!   unlike training there is nothing to re-pack per call),
//! * each batch walks queries in [`PANEL_ROWS`]-tall blocks against those
//!   panels — `4 × 8 = 32` independent accumulator chains in flight,
//! * the support norms come from the model (frozen at `freeze` time,
//!   never recomputed), the per-value finish is the shared
//!   [`KernelPanel::finish`], the per-center contraction consumes kernel
//!   values in support order, and the argmin is fused into the same
//!   per-query sweep.
//!
//! **Bit-identity contract.** As everywhere else in the crate, speed
//! comes from parallelism *across* values only: each dot is the
//! sequential chain of [`fmath::dot_f64`], each distance the association
//! `(K(x,x) − 2·cross) + ⟨Ĉ,Ĉ⟩` clamped at 0, each tie broken
//! first-minimum under `total_cmp` — so batched output is bit-for-bit
//! the scalar [`KernelKMeansModel::predict`], for any batch size, any
//! remainder, and any thread count. The serving conformance suite
//! (`rust/tests/conformance_serve.rs`) pins this across
//! d ∈ {1, 3, 16, 128} and odd batch remainders.
//!
//! The contract above describes [`NumericsMode::Deterministic`], the
//! default. An engine built with [`PredictEngine::with_mode`] and
//! [`NumericsMode::Fast`] dispatches the dots and the exp finish to the
//! runtime-detected SIMD arm ([`crate::util::simd`]): dots and distances
//! of dot-product kernels stay bit-identical, Gaussian/Laplacian
//! distances move within the documented exp ulp budget — acceptable for
//! serving (DESIGN.md §13), never used by conformance or repro paths.

use crate::data::Dataset;
use crate::kernels::panel::{PANEL_COLS, PANEL_ROWS};
use crate::kernels::{KernelFunction, KernelPanel};
use crate::kkmeans::KernelKMeansModel;
use crate::util::fmath;
use crate::util::parallel::{par_chunks_mut, par_rows_mut};
use crate::util::simd::{self, NumericsMode};

/// A frozen model compiled for batched serving: support rows packed into
/// register-tile panels, norms and coefficients flattened center-major.
/// Construction is O(support · d); build one per loaded model and reuse
/// it across batches.
pub struct PredictEngine {
    kernel: KernelFunction,
    d: usize,
    k: usize,
    /// ⟨Ĉ_j, Ĉ_j⟩ per center.
    cc: Vec<f64>,
    /// Flattened support coefficients, center-major (center 0's support
    /// first, in freeze order — the scalar accumulation order).
    coefs: Vec<f64>,
    /// Frozen support squared norms, aligned with `coefs`.
    norms: Vec<f64>,
    /// Owning center per support row, aligned with `coefs`.
    center_of: Vec<u32>,
    /// Total support rows.
    n_sup: usize,
    /// Dimension-major packed support panels: panel `p` holds support
    /// rows `[p·8, p·8+8)` as `pack[p·d + t][c] = sup[p·8+c][t]`
    /// (f64-widened, zero-padded past `n_sup`) — the slab layout
    /// [`simd::dot_rows`] consumes.
    pack: Vec<[f64; PANEL_COLS]>,
    /// Numerics mode the block sweeps run under (DESIGN.md §13).
    mode: NumericsMode,
}

impl PredictEngine {
    /// Compile `model` for batched serving in
    /// [`NumericsMode::Deterministic`].
    pub fn new(model: &KernelKMeansModel) -> PredictEngine {
        Self::with_mode(model, NumericsMode::Deterministic)
    }

    /// [`PredictEngine::new`] with an explicit numerics mode.
    pub fn with_mode(model: &KernelKMeansModel, mode: NumericsMode) -> PredictEngine {
        assert!(model.d >= 1, "cannot serve a zero-dimensional model");
        assert!(model.k() >= 1, "cannot serve an empty model");
        let d = model.d;
        let mut coefs = Vec::new();
        let mut norms = Vec::new();
        let mut center_of = Vec::new();
        let mut sup_rows: Vec<&[f32]> = Vec::new();
        for (j, (feats, cfs, nms)) in model.centers.iter().enumerate() {
            for (row, (&c, &nm)) in
                feats.chunks_exact(d).zip(cfs.iter().zip(nms.iter()))
            {
                sup_rows.push(row);
                coefs.push(c);
                norms.push(nm);
                center_of.push(j as u32);
            }
        }
        let n_sup = sup_rows.len();
        let n_panels = n_sup.div_ceil(PANEL_COLS);
        let mut pack = vec![[0.0f64; PANEL_COLS]; n_panels * d];
        for (m, row) in sup_rows.iter().enumerate() {
            let (p, c) = (m / PANEL_COLS, m % PANEL_COLS);
            for (t, &v) in row.iter().enumerate() {
                pack[p * d + t][c] = v as f64;
            }
        }
        PredictEngine {
            kernel: model.kernel,
            d,
            k: model.k(),
            cc: model.cc.clone(),
            coefs,
            norms,
            center_of,
            n_sup,
            pack,
            mode,
        }
    }

    /// Number of centers.
    pub fn k(&self) -> usize {
        self.k
    }

    /// The numerics mode this engine serves under.
    pub fn mode(&self) -> NumericsMode {
        self.mode
    }

    /// Feature dimension the engine serves.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Total packed support rows.
    pub fn support_points(&self) -> usize {
        self.n_sup
    }

    fn batch_len(&self, rows: &[f32]) -> usize {
        assert_eq!(
            rows.len() % self.d,
            0,
            "feature dimension mismatch: query batch is not a multiple of d={}",
            self.d
        );
        rows.len() / self.d
    }

    /// Squared feature-space distances for a packed row-major query batch
    /// (`rows.len()` must be a multiple of `d`). Returns `nq × k`
    /// row-major values, bit-identical to per-query
    /// [`KernelKMeansModel::distances`].
    pub fn distances_batch(&self, rows: &[f32]) -> Vec<f64> {
        let nq = self.batch_len(rows);
        let mut out = vec![0.0f64; nq * self.k];
        self.distances_into(rows, &mut out);
        out
    }

    /// [`PredictEngine::distances_batch`] into a caller buffer
    /// (`out.len() == nq · k`), parallel over query blocks.
    pub fn distances_into(&self, rows: &[f32], out: &mut [f64]) {
        let nq = self.batch_len(rows);
        assert_eq!(out.len(), nq * self.k, "distances_into: bad output shape");
        if nq == 0 {
            return;
        }
        par_rows_mut(out, self.k, |q0, chunk| {
            let mut cross = vec![0.0f64; PANEL_ROWS * self.k];
            let nrows = chunk.len() / self.k;
            let mut r0 = 0;
            while r0 < nrows {
                let rw = PANEL_ROWS.min(nrows - r0);
                let mut qs: [&[f32]; PANEL_ROWS] = [&[]; PANEL_ROWS];
                for (r, q) in qs.iter_mut().enumerate().take(rw) {
                    let qi = q0 + r0 + r;
                    *q = &rows[qi * self.d..(qi + 1) * self.d];
                }
                self.block_distances(
                    &qs[..rw],
                    &mut cross,
                    &mut chunk[r0 * self.k..(r0 + rw) * self.k],
                );
                r0 += rw;
            }
        });
    }

    /// Hard assignments for a packed row-major query batch — bit-identical
    /// to per-query [`KernelKMeansModel::predict`], argmin fused into the
    /// block sweep.
    pub fn predict_batch(&self, rows: &[f32]) -> Vec<usize> {
        let nq = self.batch_len(rows);
        let mut out = vec![0usize; nq];
        self.predict_into(rows, &mut out);
        out
    }

    /// [`PredictEngine::predict_batch`] into a caller buffer
    /// (`out.len() == nq`).
    pub fn predict_into(&self, rows: &[f32], out: &mut [usize]) {
        let nq = self.batch_len(rows);
        assert_eq!(out.len(), nq, "predict_into: bad output shape");
        if nq == 0 {
            return;
        }
        par_chunks_mut(out, |q0, chunk| {
            let mut cross = vec![0.0f64; PANEL_ROWS * self.k];
            let mut dist = vec![0.0f64; PANEL_ROWS * self.k];
            let mut r0 = 0;
            while r0 < chunk.len() {
                let rw = PANEL_ROWS.min(chunk.len() - r0);
                let mut qs: [&[f32]; PANEL_ROWS] = [&[]; PANEL_ROWS];
                for (r, q) in qs.iter_mut().enumerate().take(rw) {
                    let qi = q0 + r0 + r;
                    *q = &rows[qi * self.d..(qi + 1) * self.d];
                }
                self.block_distances(&qs[..rw], &mut cross, &mut dist[..rw * self.k]);
                for r in 0..rw {
                    let drow = &dist[r * self.k..(r + 1) * self.k];
                    // First-minimum under the total order — the same tie
                    // rule as scalar predict's `min_by(total_cmp)`.
                    let mut best = 0usize;
                    for (j, v) in drow.iter().enumerate().skip(1) {
                        if v.total_cmp(&drow[best]) == std::cmp::Ordering::Less {
                            best = j;
                        }
                    }
                    chunk[r0 + r] = best;
                }
                r0 += rw;
            }
        });
    }

    /// Batch-predict a whole dataset (dimension-checked).
    pub fn predict_dataset(&self, ds: &Dataset) -> Vec<usize> {
        assert_eq!(ds.d, self.d, "feature dimension mismatch");
        self.predict_batch(&ds.features)
    }

    /// Distances for one block of ≤ [`PANEL_ROWS`] queries: micro-kernel
    /// dots against every support panel, finish + per-center contraction
    /// in support order, distance assembly. `cross` is reusable scratch of
    /// at least `PANEL_ROWS · k`; `out` receives `qs.len() · k` values.
    fn block_distances(&self, qs: &[&[f32]], cross: &mut [f64], out: &mut [f64]) {
        let qr = qs.len();
        let k = self.k;
        debug_assert!(qr >= 1 && qr <= PANEL_ROWS);
        debug_assert_eq!(out.len(), qr * k);
        cross[..qr * k].fill(0.0);
        let mut nq = [0.0f64; PANEL_ROWS];
        let mut kxx = [0.0f64; PANEL_ROWS];
        for (r, q) in qs.iter().enumerate() {
            nq[r] = fmath::sq_norm_f64(q);
            kxx[r] = self.kernel.eval_self(q);
        }
        let batched_exp = self.mode == NumericsMode::Fast
            && matches!(
                self.kernel,
                KernelFunction::Gaussian { .. } | KernelFunction::Laplacian { .. }
            );
        for p in 0..self.n_sup.div_ceil(PANEL_COLS) {
            // The shared training/serving micro-kernel (single definition
            // of the panel dot arithmetic — see kernels::panel and
            // util::simd; bit-identical across arms for f32-widened rows).
            let acc = simd::dot_rows(self.mode, qs, &self.pack[p * self.d..(p + 1) * self.d]);
            let m0 = p * PANEL_COLS;
            let cw = PANEL_COLS.min(self.n_sup - m0);
            if batched_exp {
                // Fast path for the exp-family kernels: stage this panel's
                // exp arguments (identical association to the
                // deterministic finish), batch-exp them through the SIMD
                // arm, then contract in the same (c outer, r inner)
                // support order as the deterministic loop below.
                let mut vals = [0.0f64; PANEL_ROWS * PANEL_COLS];
                for (r, accr) in acc.iter().enumerate().take(qr) {
                    for c in 0..cw {
                        // Unwrap is safe: batched_exp implies exp kernel.
                        vals[r * cw + c] =
                            KernelPanel::exp_arg(self.kernel, nq[r], self.norms[m0 + c], accr[c])
                                .unwrap();
                    }
                }
                simd::exp_slice(NumericsMode::Fast, &mut vals[..qr * cw]);
                for c in 0..cw {
                    let m = m0 + c;
                    let j = self.center_of[m] as usize;
                    let w = self.coefs[m];
                    for r in 0..qr {
                        cross[r * k + j] += w * vals[r * cw + c];
                    }
                }
            } else {
                for c in 0..cw {
                    let m = m0 + c;
                    let j = self.center_of[m] as usize;
                    let w = self.coefs[m];
                    let ns = self.norms[m];
                    for (r, accr) in acc.iter().enumerate().take(qr) {
                        let kval = KernelPanel::finish(self.kernel, nq[r], ns, accr[c]);
                        cross[r * k + j] += w * kval;
                    }
                }
            }
        }
        for r in 0..qr {
            for j in 0..k {
                out[r * k + j] = (kxx[r] - 2.0 * cross[r * k + j] + self.cc[j]).max(0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::kkmeans::CenterWindow;
    use crate::util::rng::Rng;

    fn model_for(d: usize, kernel: KernelFunction) -> (Dataset, KernelKMeansModel) {
        let mut rng = Rng::seeded(71);
        let ds = blobs(&SyntheticSpec::new(60, d, 3), &mut rng);
        let mut windows: Vec<CenterWindow> =
            (0..3).map(|j| CenterWindow::new(j * 9, 17)).collect();
        for step in 0..10 {
            for (j, w) in windows.iter_mut().enumerate() {
                let pts: Vec<usize> =
                    (0..1 + (step + j) % 4).map(|_| rng.below(ds.n)).collect();
                w.apply_update(0.4, &pts, None);
            }
        }
        let model = KernelKMeansModel::freeze(&ds, kernel, &mut windows);
        (ds, model)
    }

    #[test]
    fn batched_distances_match_scalar_bitwise() {
        for d in [1usize, 3, 16, 128] {
            let (ds, model) = model_for(d, KernelFunction::Gaussian { kappa: d as f64 + 1.0 });
            let engine = PredictEngine::new(&model);
            assert_eq!(engine.support_points(), model.support_points());
            // Odd batch remainders around the 4-row block size.
            for nq in [1usize, 2, 3, 4, 5, 7, 13] {
                let rows = &ds.features[..nq * d];
                let got = engine.distances_batch(rows);
                for q in 0..nq {
                    let want = model.distances(&rows[q * d..(q + 1) * d]);
                    for (j, w) in want.iter().enumerate() {
                        assert_eq!(
                            got[q * engine.k() + j].to_bits(),
                            w.to_bits(),
                            "d={d} nq={nq} q={q} j={j}"
                        );
                    }
                }
                let pred = engine.predict_batch(rows);
                for q in 0..nq {
                    assert_eq!(pred[q], model.predict(&rows[q * d..(q + 1) * d]));
                }
            }
        }
    }

    #[test]
    fn dot_product_kernels_served_identically() {
        for kernel in [
            KernelFunction::Linear,
            KernelFunction::Polynomial { gamma: 0.5, coef0: 1.0, degree: 2 },
            KernelFunction::Laplacian { sigma: 2.0 },
        ] {
            let (ds, model) = model_for(5, kernel);
            let engine = PredictEngine::new(&model);
            let rows = &ds.features[..9 * 5];
            let got = engine.distances_batch(rows);
            for q in 0..9 {
                let want = model.distances(&rows[q * 5..(q + 1) * 5]);
                for (j, w) in want.iter().enumerate() {
                    assert_eq!(got[q * 3 + j].to_bits(), w.to_bits(), "{kernel:?}");
                }
            }
        }
    }

    #[test]
    fn fast_mode_dot_kernels_bitwise_exp_kernels_within_tolerance() {
        // Dot-product kernels have no exp in the chain, so a Fast engine
        // must be bit-identical to scalar predict on every dispatch arm.
        for kernel in [
            KernelFunction::Linear,
            KernelFunction::Polynomial { gamma: 0.5, coef0: 1.0, degree: 2 },
        ] {
            let (ds, model) = model_for(5, kernel);
            let fast = PredictEngine::with_mode(&model, NumericsMode::Fast);
            assert_eq!(fast.mode(), NumericsMode::Fast);
            let rows = &ds.features[..9 * 5];
            let got = fast.distances_batch(rows);
            for q in 0..9 {
                let want = model.distances(&rows[q * 5..(q + 1) * 5]);
                for (j, w) in want.iter().enumerate() {
                    assert_eq!(got[q * 3 + j].to_bits(), w.to_bits(), "{kernel:?}");
                }
            }
        }
        // Gaussian: the only divergence is the exp ulp budget flowing
        // through the Σ w·K contraction — bound it by the coefficient
        // mass (kernel values are ≤ 1 for the normalized Gaussian).
        for d in [1usize, 3, 16, 128] {
            let (ds, model) = model_for(d, KernelFunction::Gaussian { kappa: d as f64 + 1.0 });
            let det = PredictEngine::new(&model);
            let fast = PredictEngine::with_mode(&model, NumericsMode::Fast);
            let coef_mass: f64 = model
                .centers
                .iter()
                .map(|(_, cfs, _)| cfs.iter().map(|c| c.abs()).sum::<f64>())
                .sum();
            let tol = 1e-12 * (1.0 + coef_mass);
            for nq in [1usize, 3, 4, 5, 13] {
                let rows = &ds.features[..nq * d];
                let a = det.distances_batch(rows);
                let b = fast.distances_batch(rows);
                for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
                    assert!((x - y).abs() <= tol, "d={d} nq={nq} i={i}: {x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let (_, model) = model_for(3, KernelFunction::Linear);
        let engine = PredictEngine::new(&model);
        assert!(engine.predict_batch(&[]).is_empty());
        assert!(engine.distances_batch(&[]).is_empty());
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn ragged_batch_panics_like_scalar_predict() {
        let (_, model) = model_for(3, KernelFunction::Linear);
        let engine = PredictEngine::new(&model);
        let _ = engine.predict_batch(&[0.0; 4]);
    }
}
