//! HTTP/1.1 wire framing for the prediction service (DESIGN.md §11).
//!
//! A deliberately small subset of RFC 9112, enough to serve JSON over
//! keep-alive connections with bounded resource use and without ever
//! panicking on attacker-controlled bytes:
//!
//! * request head parsing with a hard size cap ([`MAX_HEAD_BYTES`]);
//! * `Content-Length` body framing only (chunked transfer is rejected
//!   with 400 — no client this service targets needs it);
//! * `Expect: 100-continue` surfaced to the caller so the server can
//!   acknowledge before the client sends the body (curl inserts the
//!   header for bodies over ~1 KiB and stalls ~1 s if it is ignored —
//!   that stall would dominate every latency percentile);
//! * responses assembled into a single buffer and written with one
//!   syscall, always carrying `Content-Length` and a JSON body.
//!
//! The head reader and the body reader are separate functions on purpose:
//! the `100 Continue` interjection happens between them. Everything here
//! is pure byte-in/byte-out over `BufRead`/`Write`, so the unit tests run
//! against in-memory cursors with no sockets involved.

use std::io::{BufRead, Write};

use crate::util::json::Json;

/// Hard cap on the request line + headers, in bytes.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Parsed request head: the request line plus the framing headers the
/// service cares about. Unknown headers are skipped, not stored.
#[derive(Debug, Clone)]
pub struct RequestHead {
    /// Request method, uppercased by the client (`GET`, `POST`, …).
    pub method: String,
    /// Request target as sent (path plus optional query string).
    pub target: String,
    /// `Content-Length`, if present and well-formed.
    pub content_length: Option<usize>,
    /// Whether the client asked for `Expect: 100-continue`.
    pub expect_continue: bool,
    /// Whether the connection should be kept open after the response
    /// (HTTP/1.1 default true, HTTP/1.0 default false, `Connection`
    /// header overrides either way).
    pub keep_alive: bool,
}

impl RequestHead {
    /// The target with any query string stripped — what the router matches.
    pub fn path(&self) -> &str {
        match self.target.split_once('?') {
            Some((p, _)) => p,
            None => &self.target,
        }
    }
}

/// Why a request could not be framed. Maps onto a response (or silence)
/// in the connection handler.
#[derive(Debug)]
pub enum WireError {
    /// Clean end of stream before any request byte: no response owed.
    Closed,
    /// Read timeout with no request bytes consumed: the connection is
    /// idle, not broken. The caller may keep waiting or close politely.
    Idle,
    /// Unparseable or oversized head, truncated body, or unsupported
    /// framing → 400; connection framing is lost, so the handler closes.
    Malformed(String),
    /// `POST` without `Content-Length` → 411.
    LengthRequired,
    /// Advertised body length exceeds the configured cap → 413. The body
    /// was not read.
    TooLarge(usize),
    /// Transport error (reset, broken pipe): drop the connection silently.
    Io(String),
}

fn classify(e: std::io::Error, started: bool) -> WireError {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut if !started => WireError::Idle,
        ErrorKind::WouldBlock | ErrorKind::TimedOut => {
            WireError::Malformed("timed out mid-request".to_string())
        }
        ErrorKind::UnexpectedEof if !started => WireError::Closed,
        ErrorKind::UnexpectedEof => {
            WireError::Malformed("connection closed mid-request".to_string())
        }
        _ => WireError::Io(e.to_string()),
    }
}

/// Read one line terminated by `\n`, enforcing the running head budget.
fn read_line<R: BufRead>(
    r: &mut R,
    budget: &mut usize,
    started: bool,
) -> Result<String, WireError> {
    let mut buf = Vec::new();
    loop {
        let n = r
            .read_until(b'\n', &mut buf)
            .map_err(|e| classify(e, started || !buf.is_empty()))?;
        if n == 0 {
            return if buf.is_empty() && !started {
                Err(WireError::Closed)
            } else {
                Err(WireError::Malformed("connection closed mid-head".to_string()))
            };
        }
        if buf.len() > *budget {
            return Err(WireError::Malformed(format!(
                "request head exceeds {MAX_HEAD_BYTES} bytes"
            )));
        }
        if buf.last() == Some(&b'\n') {
            break;
        }
    }
    *budget -= buf.len();
    while matches!(buf.last(), Some(b'\n' | b'\r')) {
        buf.pop();
    }
    String::from_utf8(buf).map_err(|_| WireError::Malformed("non-utf8 bytes in head".to_string()))
}

/// Read and parse a request head (request line + headers) off `r`.
///
/// Blocks until a full head arrives, the socket's read timeout fires
/// ([`WireError::Idle`] when nothing was consumed yet), or the budget is
/// exhausted.
pub fn read_head<R: BufRead>(r: &mut R) -> Result<RequestHead, WireError> {
    let mut budget = MAX_HEAD_BYTES;
    let request_line = read_line(r, &mut budget, false)?;
    let mut parts = request_line.split_ascii_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) => (m.to_string(), t.to_string(), v),
        _ => {
            return Err(WireError::Malformed(format!(
                "malformed request line {request_line:?}"
            )))
        }
    };
    let mut keep_alive = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        other => {
            return Err(WireError::Malformed(format!(
                "unsupported protocol version {other:?}"
            )))
        }
    };
    let mut content_length: Option<usize> = None;
    let mut expect_continue = false;
    loop {
        let line = read_line(r, &mut budget, true)?;
        if line.is_empty() {
            break;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(WireError::Malformed(format!("malformed header line {line:?}")));
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        match name.as_str() {
            "content-length" => {
                let n: usize = value
                    .parse()
                    .map_err(|_| WireError::Malformed(format!("bad content-length {value:?}")))?;
                if content_length.is_some_and(|prev| prev != n) {
                    return Err(WireError::Malformed(
                        "conflicting content-length headers".to_string(),
                    ));
                }
                content_length = Some(n);
            }
            "transfer-encoding" => {
                return Err(WireError::Malformed(
                    "chunked transfer encoding is not supported; send content-length".to_string(),
                ));
            }
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    keep_alive = false;
                } else if v.contains("keep-alive") {
                    keep_alive = true;
                }
            }
            "expect" => {
                if value.eq_ignore_ascii_case("100-continue") {
                    expect_continue = true;
                }
            }
            _ => {}
        }
    }
    Ok(RequestHead { method, target, content_length, expect_continue, keep_alive })
}

/// Read exactly `len` body bytes, rejecting lengths above `max` without
/// consuming anything.
pub fn read_body<R: BufRead>(r: &mut R, len: usize, max: usize) -> Result<Vec<u8>, WireError> {
    if len > max {
        return Err(WireError::TooLarge(len));
    }
    let mut body = vec![0u8; len];
    let mut filled = 0usize;
    while filled < len {
        match r.read(&mut body[filled..]) {
            Ok(0) => {
                return Err(WireError::Malformed(format!(
                    "request body truncated: got {filled} of {len} bytes"
                )))
            }
            Ok(n) => filled += n,
            Err(e) => return Err(classify(e, true)),
        }
    }
    Ok(body)
}

/// Canonical reason phrase for the status codes this service emits.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// A response ready to serialize: status, JSON body, connection policy.
#[derive(Debug)]
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    /// Response body bytes (always JSON in this service).
    pub body: Vec<u8>,
    /// Close the connection after writing (framing lost or shutdown).
    pub close: bool,
    /// Value for an `Allow` header (405 responses).
    pub allow: Option<&'static str>,
    /// Value for a `Retry-After` header in seconds (load-shedding 503s and
    /// draining responses — tells well-behaved clients when to come back).
    pub retry_after: Option<u64>,
    /// `Content-Type` header value. JSON for every user-facing endpoint;
    /// the shard-worker distance protocol answers `application/octet-stream`
    /// (a CRC-framed binary body, see `serve::shard`).
    pub content_type: &'static str,
}

impl Response {
    /// A 200 response with the given JSON body.
    pub fn json(value: &Json) -> Response {
        Response {
            status: 200,
            body: value.to_string().into_bytes(),
            close: false,
            allow: None,
            retry_after: None,
            content_type: "application/json",
        }
    }

    /// A 200 response carrying a binary body (the shard-worker wire
    /// protocol; everything user-facing stays JSON).
    pub fn binary(body: Vec<u8>) -> Response {
        Response {
            status: 200,
            body,
            close: false,
            allow: None,
            retry_after: None,
            content_type: "application/octet-stream",
        }
    }

    /// An error response in the documented envelope
    /// `{"error": {"code": …, "message": …}}`.
    pub fn error(status: u16, code: &str, message: &str) -> Response {
        let body = Json::obj(vec![(
            "error",
            Json::obj(vec![
                ("code", Json::Str(code.to_string())),
                ("message", Json::Str(message.to_string())),
            ]),
        )]);
        Response {
            status,
            body: body.to_string().into_bytes(),
            close: false,
            allow: None,
            retry_after: None,
            content_type: "application/json",
        }
    }

    /// Mark the connection for close after this response.
    pub fn closing(mut self) -> Response {
        self.close = true;
        self
    }

    /// Attach a `Retry-After: secs` header (shed/drain responses).
    pub fn retry_after(mut self, secs: u64) -> Response {
        self.retry_after = Some(secs);
        self
    }

    /// Serialize head + body into one buffer and write it with a single
    /// `write_all` (one syscall on an unbuffered socket — latency matters
    /// more than elegance on the 1-row hot path).
    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        let mut out = Vec::with_capacity(self.body.len() + 128);
        out.extend_from_slice(
            format!("HTTP/1.1 {} {}\r\n", self.status, status_text(self.status)).as_bytes(),
        );
        out.extend_from_slice(format!("Content-Type: {}\r\n", self.content_type).as_bytes());
        out.extend_from_slice(format!("Content-Length: {}\r\n", self.body.len()).as_bytes());
        if let Some(allow) = self.allow {
            out.extend_from_slice(format!("Allow: {allow}\r\n").as_bytes());
        }
        if let Some(secs) = self.retry_after {
            out.extend_from_slice(format!("Retry-After: {secs}\r\n").as_bytes());
        }
        if self.close {
            out.extend_from_slice(b"Connection: close\r\n");
        }
        out.extend_from_slice(b"\r\n");
        out.extend_from_slice(&self.body);
        w.write_all(&out)?;
        w.flush()
    }
}

/// The interim `100 Continue` line sent before reading an expected body.
pub const CONTINUE_LINE: &[u8] = b"HTTP/1.1 100 Continue\r\n\r\n";

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn head_of(raw: &str) -> Result<RequestHead, WireError> {
        read_head(&mut Cursor::new(raw.as_bytes().to_vec()))
    }

    #[test]
    fn parses_a_post_head() {
        let h = head_of(
            "POST /v1/predict HTTP/1.1\r\nHost: x\r\nContent-Length: 12\r\n\
             Expect: 100-continue\r\n\r\n",
        )
        .unwrap();
        assert_eq!(h.method, "POST");
        assert_eq!(h.path(), "/v1/predict");
        assert_eq!(h.content_length, Some(12));
        assert!(h.expect_continue);
        assert!(h.keep_alive);
    }

    #[test]
    fn connection_close_and_http10_defaults() {
        let h = head_of("GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(!h.keep_alive);
        let h = head_of("GET /healthz HTTP/1.0\r\n\r\n").unwrap();
        assert!(!h.keep_alive);
        let h = head_of("GET /healthz HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap();
        assert!(h.keep_alive);
    }

    #[test]
    fn query_strings_are_stripped_by_path() {
        let h = head_of("GET /healthz?verbose=1 HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(h.path(), "/healthz");
        assert_eq!(h.target, "/healthz?verbose=1");
    }

    #[test]
    fn malformed_heads_are_rejected() {
        for raw in [
            "GARBAGE\r\n\r\n",
            "GET /x HTTP/2\r\n\r\n",
            "GET /x HTTP/1.1 extra\r\n\r\n",
            "POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n",
            "POST /x HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 9\r\n\r\n",
            "POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
            "GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n",
        ] {
            assert!(matches!(head_of(raw), Err(WireError::Malformed(_))), "accepted {raw:?}");
        }
    }

    #[test]
    fn empty_stream_is_closed_partial_is_malformed() {
        assert!(matches!(head_of(""), Err(WireError::Closed)));
        assert!(matches!(head_of("GET /x HT"), Err(WireError::Malformed(_))));
        assert!(matches!(
            head_of("GET /x HTTP/1.1\r\nHost: y\r\n"),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn oversized_head_is_bounded() {
        let raw = format!("GET /x HTTP/1.1\r\nPad: {}\r\n\r\n", "y".repeat(MAX_HEAD_BYTES));
        assert!(matches!(head_of(&raw), Err(WireError::Malformed(_))));
    }

    #[test]
    fn body_framing() {
        let mut r = Cursor::new(b"hello world".to_vec());
        assert_eq!(read_body(&mut r, 5, 1024).unwrap(), b"hello");

        let mut r = Cursor::new(b"short".to_vec());
        assert!(matches!(read_body(&mut r, 10, 1024), Err(WireError::Malformed(_))));

        let mut r = Cursor::new(Vec::new());
        assert!(matches!(read_body(&mut r, 10, 5), Err(WireError::TooLarge(10))));
    }

    #[test]
    fn responses_carry_length_and_envelope() {
        let mut out = Vec::new();
        Response::error(400, "invalid_json", "bad body").closing().write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 400 Bad Request\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"));
        let body = text.split("\r\n\r\n").nth(1).unwrap();
        assert!(text.contains(&format!("Content-Length: {}\r\n", body.len())));
        let parsed = Json::parse(body).unwrap();
        assert_eq!(parsed.get("error").get("code").as_str(), Some("invalid_json"));
        assert_eq!(parsed.get("error").get("message").as_str(), Some("bad body"));
    }

    #[test]
    fn retry_after_header_on_shed_responses() {
        let mut out = Vec::new();
        Response::error(503, "server_overloaded", "try later")
            .retry_after(2)
            .write_to(&mut out)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("Retry-After: 2\r\n"), "{text}");
        // Plain responses never carry the header.
        let mut out = Vec::new();
        Response::json(&Json::obj(vec![])).write_to(&mut out).unwrap();
        assert!(!String::from_utf8(out).unwrap().contains("Retry-After"));
    }

    #[test]
    fn allow_header_on_405() {
        let mut out = Vec::new();
        let mut resp = Response::error(405, "method_not_allowed", "use GET");
        resp.allow = Some("GET");
        resp.write_to(&mut out).unwrap();
        assert!(String::from_utf8(out).unwrap().contains("Allow: GET\r\n"));
    }
}
