//! Log-suffix delta replication and the hot-swap model registry
//! (DESIGN.md §14, ADR-006).
//!
//! PR 5's generation-stamped coefficient log makes every center window a
//! replayable sequence: `apply_update` only multiplies the global decay
//! `scale`, pushes one entry at the back, and trims whole entries off
//! the front. A replica that holds the stream state as of generation
//! (iteration) `g` can therefore catch up to generation `g'` from a
//! **delta**: the per-window dropped-front count plus appended entries,
//! the store rows appended since `g`, and a handful of absolute scalars
//! (`scale`, the learning-rate counters, the init point, the ⟨Ĉ,Ĉ⟩
//! cache) — instead of re-shipping the whole O(k·(τ+b)) snapshot.
//!
//! The append/trim model has two deliberate escape hatches, both
//! detected by content hashes captured in [`DeltaBase`]:
//!
//! * `CenterWindow` **renormalization** (scale underflow near 1e-150)
//!   rewrites the raw coefficients in place;
//! * the reservoir **compaction** rewrites store indices wholesale.
//!
//! Either rewrites history, so [`delta_from`] refuses with an error and
//! the caller falls back to a full snapshot — a delta is an
//! optimization, never a correctness risk. [`apply_delta`] validates the
//! replica is exactly at the delta's base generation (and validates
//! every index bound) before mutating anything, and the result is pinned
//! byte-equal to the primary's `snapshot_bytes()` by
//! `conformance_shard.rs`. On-disk, a delta travels as a kind-`delta`
//! artifact in the CRC'd v2 container (`serve::format::delta_to_bytes`).
//!
//! The serving side rides the same machinery: [`ArtifactWatch`] detects
//! artifact version bumps (cheap stat pre-check, then a payload CRC),
//! and [`ModelRegistry`] holds one or more named served models with
//! per-model request/swap counters, hot-swapping a rebuilt serving unit
//! when its artifact changes — the coordinator keeps answering from the
//! old unit until the new one is fully built.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::SystemTime;

use crate::kkmeans::learning_rate::RateState;
use crate::kkmeans::{CenterWindow, LearningRate, StreamingKernelKMeans};
use crate::kernels::KernelFunction;
use crate::util::crc32::crc32;
use crate::util::error::{Context, Result};
use crate::{bail, format_err};

// ---------------------------------------------------------------------------
// Delta replication over the coefficient log.

/// Content hash of one window entry (points + raw coefficient bits).
/// Any in-place rewrite — renormalization, compaction's index remap —
/// changes it, which is exactly what invalidates a log-suffix delta.
fn entry_hash(points: &[u32], raws: &[f64]) -> u64 {
    let mut buf = Vec::with_capacity(points.len() * 4 + raws.len() * 8);
    for p in points {
        buf.extend_from_slice(&p.to_le_bytes());
    }
    for r in raws {
        buf.extend_from_slice(&r.to_bits().to_le_bytes());
    }
    ((crc32(&buf) as u64) << 32) | (buf.len() as u64 & 0xFFFF_FFFF)
}

/// CRC of the first `n` store rows (the prefix a delta assumes frozen).
fn store_prefix_crc(s: &StreamingKernelKMeans, n: usize) -> u32 {
    let d = s.store.d;
    let mut buf = Vec::with_capacity(n * d * 4);
    for v in &s.store.features[..n * d] {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    crc32(&buf)
}

/// A primary's fingerprint of its own state at generation `g`: enough to
/// later cut a delta against (entry hashes per window, store prefix CRC)
/// without cloning any support array.
#[derive(Debug, Clone)]
pub struct DeltaBase {
    kernel: KernelFunction,
    d: usize,
    k: usize,
    tau: usize,
    batch_size: usize,
    rate_kind: LearningRate,
    iterations: usize,
    store_n: usize,
    store_crc: u32,
    /// Per-window entry hashes (`None` before initialization).
    windows: Option<Vec<Vec<u64>>>,
}

impl DeltaBase {
    /// The generation (batches consumed) this base was captured at.
    pub fn generation(&self) -> usize {
        self.iterations
    }
}

/// Fingerprint the current state of `s` (cheap: hashes, no data copies
/// beyond per-entry scratch).
pub fn capture_base(s: &StreamingKernelKMeans) -> DeltaBase {
    DeltaBase {
        kernel: s.kernel,
        d: s.store.d,
        k: s.k,
        tau: s.tau,
        batch_size: s.batch_size,
        rate_kind: s.rate.kind(),
        iterations: s.iterations,
        store_n: s.store.n,
        store_crc: store_prefix_crc(s, s.store.n),
        windows: s.windows.as_ref().map(|ws| {
            ws.iter()
                .map(|w| {
                    w.state_view()
                        .entries
                        .iter()
                        .map(|(pts, raws)| entry_hash(pts, raws))
                        .collect()
                })
                .collect()
        }),
    }
}

/// One window's change since the base: trim the front, append at the
/// back, then overwrite the absolute scalars.
#[derive(Debug, Clone, PartialEq)]
pub struct WinDelta {
    /// Entry count the base window had (apply-time identity check).
    pub(crate) base_entries: usize,
    /// Entries trimmed off the front since the base.
    pub(crate) dropped: usize,
    /// Entries appended at the back, with raw (pre-scale) coefficients.
    pub(crate) appended: Vec<(Vec<u32>, Vec<f64>)>,
    /// Absolute decay scale at the delta's generation.
    pub(crate) scale: f64,
    /// Absolute init point (index, raw weight), if still present.
    pub(crate) init_point: Option<(u32, f64)>,
    /// Absolute ⟨Ĉ,Ĉ⟩ cache, if maintained.
    pub(crate) cc_cache: Option<f64>,
    /// Absolute drift counter toward the next exact recomputation.
    pub(crate) updates_since_exact: u32,
}

/// The log suffix between two generations of one streaming fit —
/// everything a replica at the base generation needs to reach the
/// primary's current state bit-for-bit.
#[derive(Debug, Clone, PartialEq)]
pub struct LogDelta {
    pub(crate) kernel: KernelFunction,
    pub(crate) d: usize,
    pub(crate) k: usize,
    pub(crate) tau: usize,
    pub(crate) batch_size: usize,
    pub(crate) rate_kind: LearningRate,
    pub(crate) base_iterations: usize,
    pub(crate) base_store_n: usize,
    pub(crate) base_store_crc: u32,
    pub(crate) iterations: usize,
    pub(crate) store_n: usize,
    /// Store rows appended since the base (`(store_n − base_store_n)·d`
    /// values).
    pub(crate) store_rows: Vec<f32>,
    /// Absolute learning-rate counters (small: k values).
    pub(crate) rate_counts: Vec<f64>,
    /// Window count the base had (0 = uninitialized base).
    pub(crate) base_windows: usize,
    pub(crate) windows: Vec<WinDelta>,
}

impl LogDelta {
    /// Generation the delta starts from.
    pub fn base_generation(&self) -> usize {
        self.base_iterations
    }

    /// Generation the delta brings a replica to.
    pub fn generation(&self) -> usize {
        self.iterations
    }

    /// Store rows this delta appends.
    pub fn appended_rows(&self) -> usize {
        self.store_n - self.base_store_n
    }
}

/// Cut the delta from `base` (a fingerprint captured earlier from this
/// same fit) to the current state of `s`.
///
/// Fails — telling the caller to fall back to a full snapshot — when
/// history was rewritten since the base: a compaction remapped the
/// store, or a renormalization rewrote raw coefficients. Both are
/// detected by hash mismatch, never silently replicated.
pub fn delta_from(s: &StreamingKernelKMeans, base: &DeltaBase) -> Result<LogDelta> {
    if s.kernel != base.kernel
        || s.store.d != base.d
        || s.k != base.k
        || s.tau != base.tau
        || s.batch_size != base.batch_size
        || s.rate.kind() != base.rate_kind
    {
        bail!("delta base belongs to a different fit configuration");
    }
    if s.iterations < base.iterations {
        bail!(
            "stream is at generation {} but the base was captured at {}",
            s.iterations,
            base.iterations
        );
    }
    if s.store.n < base.store_n || store_prefix_crc(s, base.store_n) != base.store_crc {
        bail!(
            "store history rewritten since generation {} (compaction); \
             full snapshot required",
            base.iterations
        );
    }
    let windows = match (&base.windows, &s.windows) {
        (None, None) => Vec::new(),
        (Some(_), None) => bail!("stream lost its windows since the base was captured"),
        (base_hashes, Some(ws)) => {
            let empty: Vec<Vec<u64>> = Vec::new();
            let base_hashes = base_hashes.as_ref().unwrap_or(&empty);
            if !base_hashes.is_empty() && base_hashes.len() != ws.len() {
                bail!(
                    "base has {} windows but the stream has {}",
                    base_hashes.len(),
                    ws.len()
                );
            }
            let mut deltas = Vec::with_capacity(ws.len());
            for (j, w) in ws.iter().enumerate() {
                let view = w.state_view();
                let cur_hashes: Vec<u64> =
                    view.entries.iter().map(|(pts, raws)| entry_hash(pts, raws)).collect();
                let bh: &[u64] = base_hashes.get(j).map(Vec::as_slice).unwrap_or(&[]);
                let n = bh.len();
                let m = cur_hashes.len();
                // The window only trims the front and appends at the back,
                // so the surviving base entries must be a suffix of the
                // base matching a prefix of the current entries.
                let dropped = (n.saturating_sub(m)..=n)
                    .find(|&dr| bh[dr..] == cur_hashes[..n - dr])
                    .ok_or_else(|| {
                        format_err!(
                            "window {j} history rewritten since generation {} \
                             (renormalization); full snapshot required",
                            base.iterations
                        )
                    })?;
                let appended = view.entries[n - dropped..]
                    .iter()
                    .map(|(pts, raws)| (pts.to_vec(), raws.to_vec()))
                    .collect();
                deltas.push(WinDelta {
                    base_entries: n,
                    dropped,
                    appended,
                    scale: view.scale,
                    init_point: view.init_point,
                    cc_cache: view.cc_cache,
                    updates_since_exact: view.updates_since_exact,
                });
            }
            deltas
        }
    };
    Ok(LogDelta {
        kernel: s.kernel,
        d: s.store.d,
        k: s.k,
        tau: s.tau,
        batch_size: s.batch_size,
        rate_kind: s.rate.kind(),
        base_iterations: base.iterations,
        base_store_n: base.store_n,
        base_store_crc: base.store_crc,
        iterations: s.iterations,
        store_n: s.store.n,
        store_rows: s.store.features[base.store_n * s.store.d..s.store.n * s.store.d].to_vec(),
        rate_counts: s.rate.counts().to_vec(),
        base_windows: base.windows.as_ref().map(Vec::len).unwrap_or(0),
        windows,
    })
}

/// Replay `delta` onto a replica that sits exactly at its base
/// generation. All validation happens before any mutation, so a
/// rejected delta leaves the replica untouched; an accepted one makes
/// `replica.snapshot_bytes()` byte-equal to the primary's.
pub fn apply_delta(replica: &mut StreamingKernelKMeans, delta: &LogDelta) -> Result<()> {
    if replica.kernel != delta.kernel
        || replica.store.d != delta.d
        || replica.k != delta.k
        || replica.tau != delta.tau
        || replica.batch_size != delta.batch_size
        || replica.rate.kind() != delta.rate_kind
    {
        bail!("delta belongs to a different fit configuration");
    }
    if replica.iterations != delta.base_iterations {
        bail!(
            "replica is at generation {} but the delta starts at {}",
            replica.iterations,
            delta.base_iterations
        );
    }
    if replica.store.n != delta.base_store_n
        || store_prefix_crc(replica, delta.base_store_n) != delta.base_store_crc
    {
        bail!("replica store diverges from the delta's base; full snapshot required");
    }
    if delta.rate_counts.len() != replica.k {
        bail!(
            "delta carries {} learning-rate counters for k={}",
            delta.rate_counts.len(),
            replica.k
        );
    }
    if delta.store_rows.len() != (delta.store_n - delta.base_store_n) * delta.d {
        bail!("delta's appended store rows do not match its claimed row count");
    }
    let base_windows = replica.windows.as_ref().map(Vec::len).unwrap_or(0);
    if base_windows != delta.base_windows {
        bail!(
            "replica has {base_windows} windows but the delta's base had {}",
            delta.base_windows
        );
    }
    if base_windows > 0 && delta.windows.len() != base_windows {
        bail!(
            "delta carries {} window updates for {base_windows} windows",
            delta.windows.len()
        );
    }
    for (j, dw) in delta.windows.iter().enumerate() {
        if let Some(ws) = &replica.windows {
            let have = ws[j].state_view().entries.len();
            if have != dw.base_entries {
                bail!(
                    "window {j} has {have} entries but the delta's base had {}",
                    dw.base_entries
                );
            }
        } else if dw.base_entries != 0 || dw.dropped != 0 {
            bail!("delta window {j} trims entries from an uninitialized replica");
        }
        if dw.dropped > dw.base_entries {
            bail!(
                "delta window {j} drops {} of {} base entries",
                dw.dropped,
                dw.base_entries
            );
        }
        for (pts, raws) in &dw.appended {
            if pts.len() != raws.len() {
                bail!("delta window {j} carries a ragged appended entry");
            }
            if let Some(&bad) = pts.iter().find(|&&p| (p as usize) >= delta.store_n) {
                bail!(
                    "delta window {j} references store row {bad} beyond {} rows",
                    delta.store_n
                );
            }
        }
    }

    // Validated — mutate. Store first (windows index into it).
    replica.store.features.extend_from_slice(&delta.store_rows);
    replica.store.n = delta.store_n;
    replica.store.invalidate_caches();
    if !delta.windows.is_empty() {
        let old: Vec<CenterWindow> =
            replica.windows.take().map(|ws| ws.into_iter().collect()).unwrap_or_default();
        let mut rebuilt = Vec::with_capacity(delta.windows.len());
        for (j, dw) in delta.windows.iter().enumerate() {
            let mut st = match old.get(j) {
                Some(w) => w.owned_state(),
                // Uninitialized base: synthesize an empty state (validated
                // above: nothing is trimmed from it).
                None => CenterWindow::new(0, replica.tau).owned_state(),
            };
            if old.get(j).is_none() {
                st.entries.clear();
            }
            st.entries.drain(..dw.dropped);
            st.entries.extend(dw.appended.iter().cloned());
            st.scale = dw.scale;
            st.init_point = dw.init_point;
            st.cc_cache = dw.cc_cache;
            st.updates_since_exact = dw.updates_since_exact;
            rebuilt.push(CenterWindow::from_state(st));
        }
        replica.windows = Some(rebuilt);
    }
    replica.rate = RateState::from_parts(delta.rate_kind, delta.rate_counts.clone());
    replica.iterations = delta.iterations;
    Ok(())
}

// ---------------------------------------------------------------------------
// Artifact watching + the hot-swap model registry.

/// Change detector for a model artifact on disk: a cheap `stat`
/// (len + mtime) pre-check, then a full-content CRC to confirm — so a
/// `touch` without a content change never triggers a swap, and a content
/// change with an unchanged mtime (clock granularity) still does once
/// the length moves.
#[derive(Debug)]
pub struct ArtifactWatch {
    path: PathBuf,
    len: u64,
    mtime: Option<SystemTime>,
    crc: u32,
}

impl ArtifactWatch {
    /// Read `path` and fingerprint it; returns the watch plus the bytes
    /// just read (so the caller builds its first serving unit from the
    /// same content the fingerprint describes).
    pub fn new(path: &Path) -> Result<(ArtifactWatch, Vec<u8>)> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading model artifact {}", path.display()))?;
        let meta = std::fs::metadata(path)
            .with_context(|| format!("stat-ing model artifact {}", path.display()))?;
        Ok((
            ArtifactWatch {
                path: path.to_path_buf(),
                len: meta.len(),
                mtime: meta.modified().ok(),
                crc: crc32(&bytes),
            },
            bytes,
        ))
    }

    /// The watched path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Content CRC of the last accepted version (the artifact version
    /// number reported in `/v1/models`).
    pub fn version(&self) -> u32 {
        self.crc
    }

    /// Check for a content change. `Ok(None)` = unchanged; `Ok(Some)` =
    /// changed, with the new bytes (the fingerprint now describes them).
    /// Errors (artifact mid-rewrite, deleted) are returned for logging —
    /// the caller keeps serving the old version.
    pub fn poll(&mut self) -> std::result::Result<Option<Vec<u8>>, String> {
        let meta = std::fs::metadata(&self.path)
            .map_err(|e| format!("stat-ing {}: {e}", self.path.display()))?;
        if meta.len() == self.len && meta.modified().ok() == self.mtime {
            return Ok(None);
        }
        let bytes = std::fs::read(&self.path)
            .map_err(|e| format!("reading {}: {e}", self.path.display()))?;
        let crc = crc32(&bytes);
        self.len = meta.len();
        self.mtime = meta.modified().ok();
        if crc == self.crc {
            return Ok(None);
        }
        self.crc = crc;
        Ok(Some(bytes))
    }
}

/// One served model: its current serving unit (engine/shard set +
/// coalescer, opaque to this module), version, optional artifact watch,
/// and per-model counters.
pub struct RegisteredModel<T> {
    name: String,
    unit: RwLock<Arc<T>>,
    version: AtomicU64,
    watch: Mutex<Option<ArtifactWatch>>,
    requests: AtomicU64,
    swaps: AtomicU64,
}

impl<T> RegisteredModel<T> {
    /// The model's registry name (`?model=` routing key).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The current serving unit (an `Arc` clone — in-flight requests on
    /// the old unit finish on it even across a swap).
    pub fn unit(&self) -> Arc<T> {
        Arc::clone(&self.unit.read().unwrap_or_else(|p| p.into_inner()))
    }

    /// Current artifact version (content CRC; 0 for fit-on-the-fly
    /// models with no artifact).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Relaxed)
    }

    /// Count one predict request routed to this model.
    pub fn note_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests routed to this model so far.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Hot-swaps performed so far.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    fn swap(&self, unit: T, version: u64) {
        *self.unit.write().unwrap_or_else(|p| p.into_inner()) = Arc::new(unit);
        self.version.store(version, Ordering::Relaxed);
        self.swaps.fetch_add(1, Ordering::Relaxed);
    }
}

/// The set of models a coordinator serves: name-addressable, first entry
/// is the default, each entry hot-swappable from its artifact.
pub struct ModelRegistry<T> {
    entries: Vec<Arc<RegisteredModel<T>>>,
}

impl<T> ModelRegistry<T> {
    /// An empty registry (the server registers at least one model before
    /// binding).
    pub fn new() -> ModelRegistry<T> {
        ModelRegistry { entries: Vec::new() }
    }

    /// Register a model. The first registration becomes the default for
    /// requests that don't name one.
    pub fn register(
        &mut self,
        name: &str,
        unit: T,
        version: u64,
        watch: Option<ArtifactWatch>,
    ) -> Result<()> {
        if self.entries.iter().any(|e| e.name == name) {
            bail!("a model named {name:?} is already registered");
        }
        self.entries.push(Arc::new(RegisteredModel {
            name: name.to_string(),
            unit: RwLock::new(Arc::new(unit)),
            version: AtomicU64::new(version),
            watch: Mutex::new(watch),
            requests: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
        }));
        Ok(())
    }

    /// Look a model up by name; `None` asks for the default (first).
    pub fn lookup(&self, name: Option<&str>) -> Option<&Arc<RegisteredModel<T>>> {
        match name {
            None => self.entries.first(),
            Some(n) => self.entries.iter().find(|e| e.name == n),
        }
    }

    /// The default (first-registered) model.
    pub fn default_model(&self) -> &Arc<RegisteredModel<T>> {
        self.entries.first().expect("registry holds at least one model")
    }

    /// All registered models, registration order.
    pub fn entries(&self) -> &[Arc<RegisteredModel<T>>] {
        &self.entries
    }

    /// Poll every watched artifact; on a version bump, `rebuild` the
    /// serving unit from the new bytes and hot-swap it. A poll or
    /// rebuild failure (artifact mid-rewrite, corrupt) keeps the old
    /// unit serving and is reported via the returned list. Returns
    /// `(swapped, errors)`.
    pub fn refresh<F>(&self, rebuild: F) -> (usize, Vec<String>)
    where
        F: Fn(&str, &[u8]) -> std::result::Result<T, String>,
    {
        let mut swapped = 0;
        let mut errors = Vec::new();
        for entry in &self.entries {
            let mut watch = entry.watch.lock().unwrap_or_else(|p| p.into_inner());
            let Some(w) = watch.as_mut() else { continue };
            match w.poll() {
                Ok(None) => {}
                Ok(Some(bytes)) => match rebuild(&entry.name, &bytes) {
                    Ok(unit) => {
                        entry.swap(unit, w.version() as u64);
                        swapped += 1;
                    }
                    Err(e) => errors.push(format!(
                        "model {:?}: rebuilding from {} failed ({e}); keeping the \
                         previous version",
                        entry.name,
                        w.path().display()
                    )),
                },
                Err(e) => errors.push(format!("model {:?}: {e}", entry.name)),
            }
        }
        (swapped, errors)
    }
}

impl<T> Default for ModelRegistry<T> {
    fn default() -> Self {
        ModelRegistry::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn stream_for(seed: u64) -> (StreamingKernelKMeans, Rng) {
        let s = StreamingKernelKMeans::new(
            KernelFunction::Gaussian { kappa: 2.0 },
            4,
            3,
            8,
            9,
            LearningRate::Sklearn,
        );
        (s, Rng::seeded(seed))
    }

    fn batch(rng: &mut Rng, rows: usize, d: usize) -> Vec<f32> {
        (0..rows * d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn delta_replay_matches_full_snapshot() {
        let (mut primary, mut rng) = stream_for(5);
        for _ in 0..6 {
            let b = batch(&mut rng, 8, 4);
            primary.partial_fit(&b, &mut rng);
        }
        // Replica = full snapshot at generation g.
        let mut replica = StreamingKernelKMeans::resume_bytes(&primary.snapshot_bytes()).unwrap();
        let base = capture_base(&primary);
        assert_eq!(base.generation(), primary.iterations);
        // Primary advances; RNG is only drawn before the first batch, so
        // the replica needs no RNG coordination.
        for _ in 0..5 {
            let b = batch(&mut rng, 8, 4);
            primary.partial_fit(&b, &mut rng);
        }
        let delta = delta_from(&primary, &base).unwrap();
        assert_eq!(delta.base_generation(), base.generation());
        assert_eq!(delta.generation(), primary.iterations);
        assert!(delta.appended_rows() > 0);
        apply_delta(&mut replica, &delta).unwrap();
        assert_eq!(
            replica.snapshot_bytes(),
            primary.snapshot_bytes(),
            "delta replay must reproduce the primary snapshot byte-for-byte"
        );
    }

    #[test]
    fn delta_from_uninitialized_base() {
        let (mut primary, mut rng) = stream_for(11);
        let mut replica = StreamingKernelKMeans::resume_bytes(&primary.snapshot_bytes()).unwrap();
        let base = capture_base(&primary);
        for _ in 0..4 {
            let b = batch(&mut rng, 6, 4);
            primary.partial_fit(&b, &mut rng);
        }
        let delta = delta_from(&primary, &base).unwrap();
        apply_delta(&mut replica, &delta).unwrap();
        assert_eq!(replica.snapshot_bytes(), primary.snapshot_bytes());
    }

    #[test]
    fn stale_or_mismatched_replica_is_rejected_untouched() {
        let (mut primary, mut rng) = stream_for(23);
        for _ in 0..4 {
            let b = batch(&mut rng, 8, 4);
            primary.partial_fit(&b, &mut rng);
        }
        let base = capture_base(&primary);
        let b = batch(&mut rng, 8, 4);
        primary.partial_fit(&b, &mut rng);
        let delta = delta_from(&primary, &base).unwrap();
        // A replica one generation behind the base must refuse the delta…
        let (mut wrong, mut rng2) = stream_for(23);
        for _ in 0..3 {
            let b = batch(&mut rng2, 8, 4);
            wrong.partial_fit(&b, &mut rng2);
        }
        let before = wrong.snapshot_bytes();
        assert!(apply_delta(&mut wrong, &delta).is_err());
        // …and be left byte-identical (validation precedes mutation).
        assert_eq!(wrong.snapshot_bytes(), before);
    }

    #[test]
    fn compaction_invalidates_the_base() {
        let (mut primary, mut rng) = stream_for(31);
        for _ in 0..3 {
            let b = batch(&mut rng, 8, 4);
            primary.partial_fit(&b, &mut rng);
        }
        let base = capture_base(&primary);
        // Drive far enough that the reservoir compacts (store shrink or
        // remap) — the prefix CRC then refuses the delta.
        for _ in 0..120 {
            let b = batch(&mut rng, 16, 4);
            primary.partial_fit(&b, &mut rng);
        }
        if primary.stored_rows() >= base.store_n
            && store_prefix_crc(&primary, base.store_n) == base.store_crc
        {
            // Compaction did not trigger at this scale — the delta must
            // then simply work.
            let delta = delta_from(&primary, &base).unwrap();
            assert_eq!(delta.generation(), primary.iterations);
        } else {
            assert!(delta_from(&primary, &base).is_err());
        }
    }

    #[test]
    fn artifact_watch_detects_content_changes_only() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("mbkk_watch_test_{}.bin", std::process::id()));
        std::fs::write(&path, b"version-one").unwrap();
        let (mut watch, bytes) = ArtifactWatch::new(&path).unwrap();
        assert_eq!(bytes, b"version-one");
        assert_eq!(watch.poll().unwrap(), None, "unchanged file must not trigger");
        std::fs::write(&path, b"version-TWO!").unwrap();
        assert_eq!(watch.poll().unwrap().as_deref(), Some(b"version-TWO!".as_slice()));
        assert_eq!(watch.poll().unwrap(), None);
        std::fs::remove_file(&path).unwrap();
        assert!(watch.poll().is_err(), "a deleted artifact reports an error");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn registry_routes_counts_and_hot_swaps() {
        let mut reg: ModelRegistry<String> = ModelRegistry::new();
        reg.register("a", "unit-a".to_string(), 1, None).unwrap();
        reg.register("b", "unit-b".to_string(), 2, None).unwrap();
        assert!(reg.register("a", "dup".to_string(), 3, None).is_err());
        assert_eq!(*reg.lookup(None).unwrap().unit(), "unit-a");
        assert_eq!(*reg.lookup(Some("b")).unwrap().unit(), "unit-b");
        assert!(reg.lookup(Some("nope")).is_none());
        let a = reg.lookup(Some("a")).unwrap();
        a.note_request();
        a.note_request();
        assert_eq!(a.requests(), 2);
        assert_eq!(reg.lookup(Some("b")).unwrap().requests(), 0);
        // No watches → refresh is a no-op.
        let (swapped, errors) = reg.refresh(|_, _| Err("unused".to_string()));
        assert_eq!((swapped, errors.len()), (0, 0));
        // Watched entry hot-swaps on a version bump.
        let dir = std::env::temp_dir();
        let path = dir.join(format!("mbkk_registry_test_{}.bin", std::process::id()));
        std::fs::write(&path, b"v1").unwrap();
        let (watch, _bytes) = ArtifactWatch::new(&path).unwrap();
        let mut reg: ModelRegistry<String> = ModelRegistry::new();
        reg.register("m", "built-from-v1".to_string(), watch.version() as u64, Some(watch))
            .unwrap();
        std::fs::write(&path, b"v2-longer").unwrap();
        let (swapped, errors) = reg.refresh(|name, bytes| {
            assert_eq!(name, "m");
            Ok(format!("built-from-{}", String::from_utf8_lossy(bytes)))
        });
        assert_eq!((swapped, errors.len()), (1, 0));
        let m = reg.lookup(Some("m")).unwrap();
        assert_eq!(*m.unit(), "built-from-v2-longer");
        assert_eq!(m.swaps(), 1);
        // A rebuild failure keeps the old unit and reports the error.
        std::fs::write(&path, b"v3-corrupt!").unwrap();
        let (swapped, errors) = reg.refresh(|_, _| Err("bad magic".to_string()));
        assert_eq!(swapped, 0);
        assert_eq!(errors.len(), 1);
        assert_eq!(*reg.lookup(Some("m")).unwrap().unit(), "built-from-v2-longer");
        let _ = std::fs::remove_file(&path);
    }
}
