//! Minimal error-handling substrate (the `anyhow` replacement for this
//! zero-dependency offline build).
//!
//! Provides exactly the surface the crate uses: a string-chained [`Error`]
//! type, the [`Result`] alias, a [`Context`] extension trait for `Result`
//! and `Option`, and the [`crate::format_err!`] / [`crate::bail!`] macros.
//! Errors are formatted eagerly into a single human-readable message with
//! outer context prepended (`"reading foo.csv: No such file or directory"`),
//! which is all the CLI and coordinator ever do with them.

use std::fmt;

/// A formatted error message with context layers folded in.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap this error with an outer context line.
    pub fn wrap(self, ctx: impl fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> Result<()>` prints the Debug form on error; make it
        // the readable message rather than a struct dump.
        f.write_str(&self.msg)
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`, which
// is what keeps this blanket conversion coherent (no overlap with the
// reflexive `From<T> for T`).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Crate-wide result alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to `Result` and `Option` values.
pub trait Context<T> {
    /// Attach a fixed context message to the error/`None` case.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Attach a lazily-computed context message to the error/`None` case.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string, like `anyhow::anyhow!`.
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early from a `Result`-returning function with a formatted
/// [`Error`], like `anyhow::bail!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::format_err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path/xyz")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn context_prepends_message() {
        let err = io_fail().unwrap_err();
        let text = format!("{err}");
        assert!(text.starts_with("reading config: "), "{text}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<i32> {
            let v: i32 = "not a number".parse()?;
            Ok(v)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let err = none.context("missing field").unwrap_err();
        assert_eq!(format!("{err}"), "missing field");
        let some = Some(3u8).with_context(|| "unused").unwrap();
        assert_eq!(some, 3);
    }

    #[test]
    fn macros_format() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("bad value {}", 42);
            }
            Ok(())
        }
        assert_eq!(format!("{}", f(true).unwrap_err()), "bad value 42");
        assert!(f(false).is_ok());
        let e = format_err!("x={x}", x = 7).wrap("outer");
        assert_eq!(format!("{e}"), "outer: x=7");
    }
}
