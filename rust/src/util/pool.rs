//! The persistent worker pool behind every `par_*` helper (ADR-002).
//!
//! Earlier revisions spawned OS threads per parallel region through
//! `std::thread::scope` (ADR-001). That costs ~10µs of spawn/join per
//! region, which is invisible next to second-long materializations but
//! dominates the 1-2 ms Algorithm-2 iterations the paper's Õ(kb²) bound
//! promises — an iteration crosses several parallel regions (cross-term
//! contraction, distance finish, px sweep), so spawn overhead alone could
//! eat tens of percent of the budget. This module keeps `num_threads() − 1`
//! workers alive for the process lifetime and hands them *jobs*: a shared
//! closure plus an atomic task counter.
//!
//! Design constraints, in order:
//!
//! 1. **Zero dependencies** — std `Mutex`/`Condvar`/atomics only.
//! 2. **Borrowing closures** — the `par_*` helpers pass closures that
//!    borrow grams and output slices from the caller's stack, so jobs
//!    cannot be `'static`. [`run`] erases the closure lifetime and
//!    guarantees the erased reference is dead before it returns: it blocks
//!    until every task of its job has *finished* (not merely been claimed),
//!    and workers never touch a job whose task counter is exhausted.
//! 3. **Nested submission** — a worker executing a task may itself call
//!    [`run`] (matmul inside a coordinator grid cell, norms inside a panel
//!    fill). The submitting thread always participates in draining its own
//!    job, so a nested region completes even when every pool worker is
//!    busy; idle workers may steal nested tasks through the shared queue.
//!    No thread ever blocks while holding a task, so there is no circular
//!    wait.
//! 4. **Panic transparency** — a panicking task is caught on the worker,
//!    its payload is carried back, and the submitting thread re-raises it
//!    via `resume_unwind`, preserving `should_panic` messages exactly like
//!    the scoped-thread join used to.
//!
//! Scheduling is deliberately simple: a `Mutex<Vec<Arc<Job>>>` of live
//! jobs plus one `Condvar`. Tasks are claimed with `fetch_add` on the
//! job's counter, which gives dynamic load balancing for free (the
//! property the old `par_dynamic` built separately). The queue never holds
//! more than a handful of jobs (one per in-flight parallel region), so a
//! linear scan beats any cleverer structure.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Once, OnceLock};

/// A parallel region: `count` tasks sharing one lifetime-erased closure.
struct Job {
    /// The region's closure, borrowed from the submitting stack frame.
    /// Only dereferenced for claimed task indices `< count`, all of which
    /// complete before [`run`] returns — after that the pointer may dangle
    /// but is provably never read again (the claim counter is exhausted).
    f: *const (dyn Fn(usize) + Sync),
    /// Number of tasks in the region.
    count: usize,
    /// Next unclaimed task index (may overshoot `count`).
    next: AtomicUsize,
    /// Tasks claimed but not yet finished + tasks unclaimed.
    pending: AtomicUsize,
    /// Set when any task panicked.
    panicked: AtomicBool,
    /// First panic payload, re-raised on the submitting thread.
    payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    /// Completion signal: guards nothing, pairs with `pending`.
    done_lock: Mutex<()>,
    done_cv: Condvar,
}

// SAFETY: `f` is only shared between threads inside `run`'s lifetime
// window (see the field comment); the closure itself is `Sync`, and every
// other field is a thread-safe primitive.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

/// Live-job queue + worker parking lot.
struct Pool {
    jobs: Mutex<Vec<Arc<Job>>>,
    work_cv: Condvar,
    /// Number of worker threads (pool width, excluding submitters).
    workers: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();
static SPAWN_WORKERS: Once = Once::new();

/// The pool, spawning its workers on first use. `None` when
/// `num_threads() == 1` (everything stays serial).
fn pool() -> Option<&'static Pool> {
    let n = super::parallel::num_threads();
    if n <= 1 {
        return None;
    }
    let pool = POOL.get_or_init(|| Pool {
        jobs: Mutex::new(Vec::new()),
        work_cv: Condvar::new(),
        workers: n - 1,
    });
    SPAWN_WORKERS.call_once(|| {
        // The submitting thread always participates, so n−1 workers give n
        // lanes of parallelism.
        for w in 0..pool.workers {
            std::thread::Builder::new()
                .name(format!("mbkk-pool-{w}"))
                .spawn(move || worker_loop(pool))
                .expect("failed to spawn pool worker");
        }
    });
    Some(pool)
}

/// Worker main: nap on the condvar until some job has unclaimed tasks,
/// drain it, repeat. Exhausted jobs are pruned opportunistically (the
/// submitter also prunes its own job, so this is belt-and-braces).
fn worker_loop(pool: &'static Pool) {
    let mut guard = pool.jobs.lock().expect("pool queue poisoned");
    loop {
        let job = guard
            .iter()
            .find(|j| j.next.load(Ordering::Relaxed) < j.count)
            .cloned();
        match job {
            Some(job) => {
                drop(guard);
                run_tasks(&job);
                guard = pool.jobs.lock().expect("pool queue poisoned");
                guard.retain(|j| j.next.load(Ordering::Relaxed) < j.count);
            }
            None => {
                guard = pool.work_cv.wait(guard).expect("pool queue poisoned");
            }
        }
    }
}

/// Claim-and-execute loop shared by workers and the submitting thread.
fn run_tasks(job: &Job) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.count {
            return;
        }
        // SAFETY: `i < count`, so `run` has not returned yet and the
        // closure reference is alive (see the `Job::f` field contract).
        let f = unsafe { &*job.f };
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| {
            // Fault injection rides the panic-transparency path: an armed
            // `pool.job` failpoint (panic or err) surfaces to the submitter
            // exactly like a task panic, and the pool must survive it. The
            // name is process-global, so it is exercised by the chaos CI
            // sweep (one process, one pool user) rather than in-process
            // unit tests, which share the pool across concurrent tests.
            if crate::util::failpoint::armed() {
                if let Some(fault) = crate::util::failpoint::eval("pool.job") {
                    match fault {
                        crate::util::failpoint::Fault::Panic => {
                            panic!("failpoint pool.job: injected panic")
                        }
                        crate::util::failpoint::Fault::Err(msg) => {
                            panic!("failpoint pool.job: {msg}")
                        }
                    }
                }
            }
            f(i)
        })) {
            let mut slot = job.payload.lock().expect("panic slot poisoned");
            if slot.is_none() {
                *slot = Some(p);
            }
            drop(slot);
            job.panicked.store(true, Ordering::Relaxed);
        }
        // Release pairs with the Acquire load in `run`'s completion wait,
        // making this task's writes visible to the submitter.
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _g = job.done_lock.lock().expect("done lock poisoned");
            job.done_cv.notify_all();
        }
    }
}

/// Execute `f(0) … f(count − 1)` across the pool and the calling thread,
/// returning once **all** tasks have finished. Tasks are claimed from a
/// shared atomic counter, so irregular task costs load-balance
/// dynamically. Panics in any task are re-raised here with their original
/// payload. With one configured thread (or `count ≤ 1`) this is a plain
/// serial loop — no pool is ever spawned.
pub fn run(count: usize, f: &(dyn Fn(usize) + Sync)) {
    if count == 0 {
        return;
    }
    // Check the task count before touching the pool: a single-task region
    // must stay serial without spawning workers it will never use.
    let pool = if count > 1 { pool() } else { None };
    let Some(pool) = pool else {
        for i in 0..count {
            f(i);
        }
        return;
    };
    // SAFETY of the lifetime erasure: the reference is only dereferenced
    // for claimed tasks, and this function does not return until
    // `pending == 0`, i.e. until every dereference has completed.
    let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
        std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
    };
    let job = Arc::new(Job {
        f: f_static,
        count,
        next: AtomicUsize::new(0),
        pending: AtomicUsize::new(count),
        panicked: AtomicBool::new(false),
        payload: Mutex::new(None),
        done_lock: Mutex::new(()),
        done_cv: Condvar::new(),
    });
    {
        let mut q = pool.jobs.lock().expect("pool queue poisoned");
        q.push(Arc::clone(&job));
    }
    // Wake only as many workers as the job can use (the submitter takes
    // one lane itself) — notify_all would stampede the whole pool through
    // a futex wake + queue-mutex bounce for a 2-task region.
    for _ in 0..pool.workers.min(count - 1) {
        pool.work_cv.notify_one();
    }
    // Participate: drain our own job so completion never depends on pool
    // availability (this is what makes nested use deadlock-free).
    run_tasks(&job);
    {
        let mut g = job.done_lock.lock().expect("done lock poisoned");
        while job.pending.load(Ordering::Acquire) > 0 {
            g = job.done_cv.wait(g).expect("done lock poisoned");
        }
    }
    {
        let mut q = pool.jobs.lock().expect("pool queue poisoned");
        q.retain(|j| !Arc::ptr_eq(j, &job));
    }
    if job.panicked.load(Ordering::Relaxed) {
        let payload = job.payload.lock().expect("panic slot poisoned").take();
        match payload {
            Some(p) => resume_unwind(p),
            None => panic!("pool task panicked"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_index_exactly_once() {
        let flags: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        run(1000, &|i| {
            flags[i].fetch_add(1, Ordering::Relaxed);
        });
        for f in &flags {
            assert_eq!(f.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn zero_and_one_task_serial() {
        run(0, &|_| panic!("must not run"));
        let hit = AtomicUsize::new(0);
        run(1, &|i| {
            assert_eq!(i, 0);
            hit.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn nested_submission_completes() {
        // Every outer task submits an inner region; with a busy pool the
        // submitting threads must drain their own jobs.
        let total = AtomicUsize::new(0);
        run(8, &|_| {
            run(16, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 8 * 16);
    }

    #[test]
    fn writes_are_visible_after_run() {
        let mut out = vec![0usize; 4096];
        {
            let view = crate::util::parallel::SharedSlice::new(&mut out);
            let view = &view;
            run(4096, &|i| unsafe { view.write(i, i + 1) });
        }
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i + 1);
        }
    }

    #[test]
    fn panic_payload_is_preserved() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            run(64, &|i| {
                if i == 33 {
                    panic!("boom at 33");
                }
            });
        }));
        let payload = caught.expect_err("must propagate the panic");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(str::to_string)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("boom at 33"), "payload lost: {msg}");
        // The pool must stay usable after a panicked job.
        let n = AtomicUsize::new(0);
        run(128, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 128);
    }
}
