//! Minimal JSON parser and serializer.
//!
//! Serde is not available in this offline build, so the project carries its
//! own small JSON implementation: enough for the AOT artifact manifest
//! (`artifacts/manifest.json`), experiment configurations, and result
//! reports. Supports the full JSON value model; numbers are held as f64
//! (adequate for every payload in this project).

pub mod lazy;

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (held as f64; adequate for every payload here).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys ⇒ deterministic serialization).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset into the input where parsing failed.
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ----------------------------------------------------

    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers.
    pub fn arr_num<I: IntoIterator<Item = f64>>(xs: I) -> Json {
        Json::Arr(xs.into_iter().map(Json::Num).collect())
    }

    /// Build an array of strings.
    pub fn arr_str<I: IntoIterator<Item = String>>(xs: I) -> Json {
        Json::Arr(xs.into_iter().map(Json::Str).collect())
    }

    // ---- accessors --------------------------------------------------------

    /// The number, if this is a [`Json::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as a non-negative integer, if it is one exactly.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    /// The string, if this is a [`Json::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a [`Json::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The items, if this is a [`Json::Arr`].
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The map, if this is a [`Json::Obj`].
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    // ---- parsing ----------------------------------------------------------

    /// Parse a complete JSON document (trailing characters are an error).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- serialization ----------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    it.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let width = utf8_width(b);
                    let end = (start + width).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "3", "-2.5", "1e3", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x\ny"));
        assert_eq!(v.get("d").as_bool(), Some(true));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("name", Json::Str("gram".into())),
            ("shape", Json::arr_num([256.0, 128.0])),
            ("nested", Json::obj(vec![("x", Json::Num(1.5))])),
        ]);
        let v2 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escapes_and_multibyte() {
        let v = Json::parse(r#""é café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("é café ☕"));
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn errors_report_offsets() {
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn as_usize_rejects_non_integers() {
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(3.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }
}
