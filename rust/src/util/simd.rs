//! Runtime-dispatched SIMD micro-kernels for the panel engine (DESIGN.md §13).
//!
//! The panel engine (`kernels::panel`) pins every kernel value to one
//! sequential scalar f64 chain for bit-identity, which leaves the explicit
//! vector units idle unless the autovectorizer happens to find the pattern.
//! This module adds hand-written AVX2 (x86_64) and Neon (aarch64) arms for
//! the two hot loops — the `MR × NR` dot-product micro-kernel and the
//! batched `exp` finish pass — selected once per process by runtime CPU
//! feature detection, with the portable scalar chain as the fallback arm on
//! every other target.
//!
//! ## Numerics modes
//!
//! The arms are reached only through [`NumericsMode`]:
//!
//! * [`NumericsMode::Deterministic`] (the default) always takes the
//!   portable scalar chain and stays bit-identical to every release since
//!   the panel engine landed. All conformance, checkpoint-replay, and
//!   paper-reproduction paths use it.
//! * [`NumericsMode::Fast`] dispatches to the best available SIMD arm and
//!   trades bit-identity for throughput under the tolerance bounds below.
//!
//! ## Accuracy contract (the numbers the diff harness pins)
//!
//! * **Dot products: 0 ulp.** Every feature in this crate is an `f32`
//!   widened to f64, so each product has ≤ 48 mantissa bits and is *exact*
//!   in f64; a fused multiply-add of an exact product rounds identically to
//!   a separate multiply-then-add. The SIMD arms accumulate each output
//!   lane over dimensions in the same sequential order as
//!   [`fmath::dot_f64`](crate::util::fmath::dot_f64), so for f32-widened
//!   inputs (the only inputs this crate produces) the fast dot is
//!   **bit-identical** to the scalar chain. `tests/diff_simd_scalar.rs`
//!   asserts bitwise equality, not a tolerance.
//! * **Batched exp: ≤ [`EXP_ULP_BUDGET`] ulp** against `f64::exp`
//!   (typically ≤ 2 in practice). The vector arms and their scalar
//!   remainder tail ([`exp_fast_scalar`]) execute the identical operation
//!   sequence, so a value's result does not depend on which lane — or the
//!   tail — it landed in.
//! * **Portable arm: 0 ulp.** On targets with neither AVX2 nor Neon (and
//!   under `MBKK_NUMERICS_PORTABLE=1` or Miri), Fast mode degrades to the
//!   deterministic scalar chain, so Fast ≡ Deterministic bitwise there.
//!
//! `MBKK_NUMERICS_PORTABLE=1` pins dispatch to the portable arm for the
//! whole process (read once, before the first kernel call) — used by the
//! Miri CI job, the aarch64 cross-check, and for A/B debugging.

use std::sync::OnceLock;

/// Rows per micro-kernel invocation (register-tile height). The panel
/// engine's `PANEL_ROWS` is an alias of this.
pub const MR: usize = 4;

/// Columns per micro-kernel invocation (register-tile width). Together
/// with [`MR`] this yields 32 independent f64 accumulator chains. The
/// panel engine's `PANEL_COLS` is an alias of this.
pub const NR: usize = 8;

/// Asserted upper bound, in units in the last place, on the error of the
/// Fast-mode batched exp ([`exp_slice`]) against `f64::exp`. The Taylor
/// degree-13 Horner chain contributes ≲ 1.5 ulp and libm itself ≤ 1; the
/// budget leaves headroom for both. The diff harness asserts it on every
/// available dispatch arm.
pub const EXP_ULP_BUDGET: u64 = 4;

/// How kernel values are computed: the crate-wide numerics switch.
///
/// Threaded through `KernelPanel`, `Gram`, `PredictEngine`, `RunSpec`,
/// and the CLI (`--numerics`). See DESIGN.md §13 for when Fast is safe
/// (serving: yes; conformance/repro/checkpoint replay: no).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum NumericsMode {
    /// One sequential scalar f64 chain per value — bit-identical across
    /// every engine, tile shape, and platform. The default.
    #[default]
    Deterministic,
    /// Runtime-dispatched SIMD arms ([`Arch`]) for the dot micro-kernel
    /// and the batched exp finish. Dots stay bit-identical (f32-widened
    /// products are exact); exp is within [`EXP_ULP_BUDGET`] ulp.
    Fast,
}

impl NumericsMode {
    /// Parse a CLI flag value (`deterministic`/`det` or `fast`).
    pub fn from_name(name: &str) -> Option<NumericsMode> {
        match name {
            "deterministic" | "det" => Some(NumericsMode::Deterministic),
            "fast" => Some(NumericsMode::Fast),
            _ => None,
        }
    }

    /// Canonical flag spelling (inverse of [`NumericsMode::from_name`]).
    pub fn name(self) -> &'static str {
        match self {
            NumericsMode::Deterministic => "deterministic",
            NumericsMode::Fast => "fast",
        }
    }
}

/// A dispatch arm. All variants exist on all targets so tests and
/// diagnostics can name them; [`Arch::available`] says which can run here.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    /// x86_64 with AVX2 **and** FMA (Haswell 2013+). 4-lane f64 vectors.
    Avx2,
    /// aarch64 ASIMD (baseline on every ARMv8-A core). 2-lane f64 vectors.
    Neon,
    /// The scalar chain — identical arithmetic to Deterministic mode.
    Portable,
}

impl Arch {
    /// Whether this arm can execute on the current host.
    pub fn available(self) -> bool {
        match self {
            Arch::Portable => true,
            Arch::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                        && std::arch::is_x86_feature_detected!("fma")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            // ASIMD is mandatory in ARMv8-A, so presence == target arch.
            Arch::Neon => cfg!(target_arch = "aarch64"),
        }
    }
}

/// The arm Fast mode dispatches to, detected once per process. Honors
/// `MBKK_NUMERICS_PORTABLE=1` (any value but `0`) and always reports
/// [`Arch::Portable`] under Miri, which cannot execute vendor intrinsics.
pub fn detected_arch() -> Arch {
    static ARCH: OnceLock<Arch> = OnceLock::new();
    *ARCH.get_or_init(|| {
        if cfg!(miri) {
            return Arch::Portable;
        }
        if matches!(std::env::var("MBKK_NUMERICS_PORTABLE"), Ok(v) if !v.is_empty() && v != "0") {
            return Arch::Portable;
        }
        if Arch::Avx2.available() {
            Arch::Avx2
        } else if Arch::Neon.available() {
            Arch::Neon
        } else {
            Arch::Portable
        }
    })
}

/// Every arm the current host can execute — the diff harness iterates
/// this so the SIMD arms are exercised wherever they exist and the
/// portable arm is exercised everywhere.
pub fn test_arches() -> Vec<Arch> {
    [Arch::Avx2, Arch::Neon, Arch::Portable]
        .into_iter()
        .filter(|a| a.available())
        .collect()
}

// ---------------------------------------------------------------------------
// Dot micro-kernel
// ---------------------------------------------------------------------------

/// The portable register-tiled dot micro-kernel: up to [`MR`] feature rows
/// against one dimension-major packed [`NR`]-wide column panel
/// (`pack[t][c]` = column c's value in dimension t, zero-padded). Each of
/// the `MR × NR` accumulators is a sequential f64 chain over `d` —
/// bit-identical to [`fmath::dot_f64`](crate::util::fmath::dot_f64) — and
/// the chains are mutually independent, which is what the autovectorizer
/// needs. This is the single definition of the Deterministic panel dot
/// arithmetic; the SIMD arms below replay the same per-lane chains with
/// explicit vectors.
#[inline]
pub fn dot_rows_portable(rows: &[&[f32]], pack: &[[f64; NR]]) -> [[f64; NR]; MR] {
    let mut acc = [[0.0f64; NR]; MR];
    match rows {
        [a0, a1, a2, a3] => {
            // Zipped iteration (all streams have length d) keeps the
            // inner loop free of bounds checks.
            let streams = pack.iter().zip(*a0).zip(*a1).zip(*a2).zip(*a3);
            for ((((slab, &x0), &x1), &x2), &x3) in streams {
                let (v0, v1) = (x0 as f64, x1 as f64);
                let (v2, v3) = (x2 as f64, x3 as f64);
                for c in 0..NR {
                    acc[0][c] += v0 * slab[c];
                    acc[1][c] += v1 * slab[c];
                    acc[2][c] += v2 * slab[c];
                    acc[3][c] += v3 * slab[c];
                }
            }
        }
        _ => {
            for (accr, a) in acc.iter_mut().zip(rows.iter()) {
                for (slab, &x) in pack.iter().zip(a.iter()) {
                    let v = x as f64;
                    for c in 0..NR {
                        accr[c] += v * slab[c];
                    }
                }
            }
        }
    }
    acc
}

/// Mode-dispatched dot micro-kernel: Deterministic always takes
/// [`dot_rows_portable`]; Fast takes the [`detected_arch`] arm. For
/// f32-widened inputs all arms agree bitwise (see the module accuracy
/// contract), so Fast here changes throughput, never values.
#[inline]
pub fn dot_rows(mode: NumericsMode, rows: &[&[f32]], pack: &[[f64; NR]]) -> [[f64; NR]; MR] {
    match mode {
        NumericsMode::Deterministic => dot_rows_portable(rows, pack),
        NumericsMode::Fast => dot_rows_with_arch(detected_arch(), rows, pack),
    }
}

/// [`dot_rows`] pinned to an explicit arm — the diff harness's entry
/// point. Panics if `arch` is not [available](Arch::available) on this
/// host, or if any row's length differs from the packed dimension.
pub fn dot_rows_with_arch(arch: Arch, rows: &[&[f32]], pack: &[[f64; NR]]) -> [[f64; NR]; MR] {
    assert!(arch.available(), "numerics arm {arch:?} is not available on this host");
    assert!(rows.len() <= MR, "dot_rows: more than MR rows");
    for r in rows {
        assert_eq!(r.len(), pack.len(), "dot_rows: row length != packed dimension");
    }
    match arch {
        Arch::Portable => dot_rows_portable(rows, pack),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: availability was asserted above, so AVX2+FMA exist.
        Arch::Avx2 => unsafe { x86::dot_rows_avx2(rows, pack) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: ASIMD is baseline on every aarch64 target.
        Arch::Neon => unsafe { arm::dot_rows_neon(rows, pack) },
        #[allow(unreachable_patterns)] // arms cfg'd out on other targets
        _ => unreachable!("unavailable arm passed the availability assert"),
    }
}

// ---------------------------------------------------------------------------
// Batched exp
// ---------------------------------------------------------------------------

/// Upper clamp: `ln(f64::MAX)`. Above it the result is `+inf` exactly as
/// `f64::exp` returns.
const EXP_HI: f64 = 709.782712893384;
/// Lower clamp: below it even the smallest subnormal rounds to `+0.0`
/// (`exp(-746) ≈ 0.21 · 2^-1074`, under half the subnormal step).
const EXP_LO: f64 = -746.0;
/// `log2(e)`, for the `x = n·ln2 + r` range reduction.
const LOG2E: f64 = std::f64::consts::LOG2_E;
/// High half of the Cody–Waite `ln 2` split (fdlibm's 33-bit head):
/// `n · LN2_HI` is exact for every `|n| ≤ 2^20` we can produce.
const LN2_HI: f64 = 6.931_471_803_691_238e-1;
/// Low half of the Cody–Waite `ln 2` split.
const LN2_LO: f64 = 1.908_214_929_270_587_7e-10;
/// `1.5 · 2^52`: adding-then-subtracting it rounds to the nearest integer
/// under the default round-to-nearest-even mode — the same rule the SIMD
/// lanes use, unlike `f64::round` (which rounds halves away from zero).
const SHIFTER: f64 = 6_755_399_441_055_744.0;
/// Taylor coefficients `1/13! … 1/2!, 1, 1` for the Horner evaluation of
/// `exp(r)` on `|r| ≤ ln2/2`; truncation error ≲ 0.02 ulp at that radius.
const EXP_POLY: [f64; 14] = [
    1.0 / 6_227_020_800.0,
    1.0 / 479_001_600.0,
    1.0 / 39_916_800.0,
    1.0 / 3_628_800.0,
    1.0 / 362_880.0,
    1.0 / 40_320.0,
    1.0 / 5_040.0,
    1.0 / 720.0,
    1.0 / 120.0,
    1.0 / 24.0,
    1.0 / 6.0,
    1.0 / 2.0,
    1.0,
    1.0,
];

/// The scalar twin of the SIMD exp lanes: identical operation sequence
/// (shifter rounding, Cody–Waite reduction, degree-13 Horner with fused
/// multiply-adds, two-step power-of-two scaling), so the vector arms'
/// remainder tails produce bit-identical results to full lanes. Within
/// [`EXP_ULP_BUDGET`] ulp of `f64::exp`; propagates NaN, `+inf → +inf`,
/// underflows gradually through the subnormals to `+0.0`.
#[inline]
pub fn exp_fast_scalar(x: f64) -> f64 {
    if x.is_nan() {
        return x;
    }
    if x > EXP_HI {
        return f64::INFINITY;
    }
    if x < EXP_LO {
        return 0.0;
    }
    let t = x * LOG2E;
    let n = (t + SHIFTER) - SHIFTER;
    let r = (-n).mul_add(LN2_HI, x);
    let r = (-n).mul_add(LN2_LO, r);
    let mut p = EXP_POLY[0];
    for &c in &EXP_POLY[1..] {
        p = p.mul_add(r, c);
    }
    // 2^n in two half-exponent factors: each factor stays a normal f64 for
    // every reachable n (|n| ≤ 1077), and the final multiply performs the
    // single correctly-rounded step into the subnormal range (or to inf).
    let ni = n as i64;
    let h = ni >> 1;
    let s1 = f64::from_bits(((1023 + h) as u64) << 52);
    let s2 = f64::from_bits(((1023 + (ni - h)) as u64) << 52);
    p * s1 * s2
}

/// Mode-dispatched batched exponential: `xs[i] ← exp(xs[i])`.
/// Deterministic applies `f64::exp` per element (the panel engine's
/// pinned finish arithmetic); Fast dispatches to the [`detected_arch`]
/// arm, where [`Arch::Portable`] is again `f64::exp` — so Fast without
/// SIMD hardware stays bit-identical to Deterministic.
#[inline]
pub fn exp_slice(mode: NumericsMode, xs: &mut [f64]) {
    match mode {
        NumericsMode::Deterministic => {
            for x in xs {
                *x = x.exp();
            }
        }
        NumericsMode::Fast => exp_slice_with_arch(detected_arch(), xs),
    }
}

/// [`exp_slice`] pinned to an explicit arm — the diff harness's entry
/// point. Panics if `arch` is not [available](Arch::available) here.
pub fn exp_slice_with_arch(arch: Arch, xs: &mut [f64]) {
    assert!(arch.available(), "numerics arm {arch:?} is not available on this host");
    match arch {
        Arch::Portable => {
            for x in xs {
                *x = x.exp();
            }
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: availability was asserted above, so AVX2+FMA exist.
        Arch::Avx2 => unsafe { x86::exp_slice_avx2(xs) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: ASIMD is baseline on every aarch64 target.
        Arch::Neon => unsafe { arm::exp_slice_neon(xs) },
        #[allow(unreachable_patterns)] // arms cfg'd out on other targets
        _ => unreachable!("unavailable arm passed the availability assert"),
    }
}

/// Distance in representable steps between two f64s — the unit the diff
/// harness budgets in. `Some(0)` for bitwise-equal values, equal zeros of
/// either sign, or two NaNs; `None` when exactly one side is NaN or the
/// signs of nonzero values differ (no meaningful ulp distance exists).
pub fn ulp_distance(a: f64, b: f64) -> Option<u64> {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => return Some(0),
        (true, false) | (false, true) => return None,
        (false, false) => {}
    }
    if a == b {
        return Some(0); // covers +0 vs -0
    }
    if a.is_sign_positive() != b.is_sign_positive() {
        // One side may be a signed zero adjacent to a tiny value of the
        // other sign; measure through zero in that case.
        if a == 0.0 || b == 0.0 {
            let (za, zb) = (a.abs().to_bits(), b.abs().to_bits());
            return Some(za + zb);
        }
        return None;
    }
    Some(a.abs().to_bits().abs_diff(b.abs().to_bits()))
}

// ---------------------------------------------------------------------------
// AVX2 arm (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{EXP_HI, EXP_LO, EXP_POLY, LN2_HI, LN2_LO, LOG2E, MR, NR, SHIFTER};
    use std::arch::x86_64::*;

    /// AVX2+FMA dot micro-kernel. Each output lane accumulates over
    /// dimensions in the same sequential order as the portable chain; the
    /// fused multiply-add rounds identically to multiply-then-add because
    /// f32-widened products are exact in f64, so this arm is bit-identical
    /// to [`super::dot_rows_portable`] for the crate's inputs.
    ///
    /// # Safety
    /// Caller must ensure AVX2 and FMA are available and every row's
    /// length equals `pack.len()` (the dispatcher asserts both).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn dot_rows_avx2(rows: &[&[f32]], pack: &[[f64; NR]]) -> [[f64; NR]; MR] {
        let mut out = [[0.0f64; NR]; MR];
        let d = pack.len();
        match rows {
            [a0, a1, a2, a3] => {
                // 8 live accumulator registers (2 × 4-lane per row) plus
                // the two slab loads: 10 of the 16 ymm registers.
                let mut acc = [[_mm256_setzero_pd(); 2]; MR];
                for t in 0..d {
                    let slab = pack.get_unchecked(t).as_ptr();
                    let lo = _mm256_loadu_pd(slab);
                    let hi = _mm256_loadu_pd(slab.add(4));
                    let v0 = _mm256_set1_pd(*a0.get_unchecked(t) as f64);
                    let v1 = _mm256_set1_pd(*a1.get_unchecked(t) as f64);
                    let v2 = _mm256_set1_pd(*a2.get_unchecked(t) as f64);
                    let v3 = _mm256_set1_pd(*a3.get_unchecked(t) as f64);
                    acc[0][0] = _mm256_fmadd_pd(v0, lo, acc[0][0]);
                    acc[0][1] = _mm256_fmadd_pd(v0, hi, acc[0][1]);
                    acc[1][0] = _mm256_fmadd_pd(v1, lo, acc[1][0]);
                    acc[1][1] = _mm256_fmadd_pd(v1, hi, acc[1][1]);
                    acc[2][0] = _mm256_fmadd_pd(v2, lo, acc[2][0]);
                    acc[2][1] = _mm256_fmadd_pd(v2, hi, acc[2][1]);
                    acc[3][0] = _mm256_fmadd_pd(v3, lo, acc[3][0]);
                    acc[3][1] = _mm256_fmadd_pd(v3, hi, acc[3][1]);
                }
                for (o, a) in out.iter_mut().zip(acc.iter()) {
                    _mm256_storeu_pd(o.as_mut_ptr(), a[0]);
                    _mm256_storeu_pd(o.as_mut_ptr().add(4), a[1]);
                }
            }
            _ => {
                for (o, a) in out.iter_mut().zip(rows.iter()) {
                    let mut lo_acc = _mm256_setzero_pd();
                    let mut hi_acc = _mm256_setzero_pd();
                    for t in 0..d {
                        let slab = pack.get_unchecked(t).as_ptr();
                        let v = _mm256_set1_pd(*a.get_unchecked(t) as f64);
                        lo_acc = _mm256_fmadd_pd(v, _mm256_loadu_pd(slab), lo_acc);
                        hi_acc = _mm256_fmadd_pd(v, _mm256_loadu_pd(slab.add(4)), hi_acc);
                    }
                    _mm256_storeu_pd(o.as_mut_ptr(), lo_acc);
                    _mm256_storeu_pd(o.as_mut_ptr().add(4), hi_acc);
                }
            }
        }
        out
    }

    /// One 4-lane step of the batched exp. Same operation sequence as
    /// [`super::exp_fast_scalar`]; specials (overflow, underflow, NaN)
    /// handled by computing on a clamped copy and blending at the end.
    ///
    /// # Safety
    /// Caller must ensure AVX2 and FMA are available.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn exp4(x: __m256d) -> __m256d {
        let hi = _mm256_set1_pd(EXP_HI);
        let lo = _mm256_set1_pd(EXP_LO);
        // min/max return the second operand on NaN, so a NaN lane computes
        // on EXP_HI here and is blended back to the input NaN below.
        let xc = _mm256_max_pd(_mm256_min_pd(x, hi), lo);
        let shifter = _mm256_set1_pd(SHIFTER);
        let t = _mm256_mul_pd(xc, _mm256_set1_pd(LOG2E));
        let n = _mm256_sub_pd(_mm256_add_pd(t, shifter), shifter);
        let r = _mm256_fnmadd_pd(n, _mm256_set1_pd(LN2_HI), xc);
        let r = _mm256_fnmadd_pd(n, _mm256_set1_pd(LN2_LO), r);
        let mut p = _mm256_set1_pd(EXP_POLY[0]);
        for &c in &EXP_POLY[1..] {
            p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(c));
        }
        // 2^n in two half-exponent factors (AVX2 has no 64-bit arithmetic
        // shift, so halve as i32 before widening). srai floors like the
        // scalar `>> 1`.
        let n32 = _mm256_cvtpd_epi32(n);
        let h32 = _mm_srai_epi32::<1>(n32);
        let rest32 = _mm_sub_epi32(n32, h32);
        let bias = _mm256_set1_epi64x(1023);
        let s1 = _mm256_castsi256_pd(_mm256_slli_epi64::<52>(_mm256_add_epi64(
            _mm256_cvtepi32_epi64(h32),
            bias,
        )));
        let s2 = _mm256_castsi256_pd(_mm256_slli_epi64::<52>(_mm256_add_epi64(
            _mm256_cvtepi32_epi64(rest32),
            bias,
        )));
        let res = _mm256_mul_pd(_mm256_mul_pd(p, s1), s2);
        let big = _mm256_cmp_pd::<_CMP_GT_OQ>(x, hi);
        let small = _mm256_cmp_pd::<_CMP_LT_OQ>(x, lo);
        let nan = _mm256_cmp_pd::<_CMP_UNORD_Q>(x, x);
        let res = _mm256_blendv_pd(res, _mm256_set1_pd(f64::INFINITY), big);
        let res = _mm256_blendv_pd(res, _mm256_setzero_pd(), small);
        _mm256_blendv_pd(res, x, nan)
    }

    /// Batched exp over a slice: 4-lane body, scalar-twin tail.
    ///
    /// # Safety
    /// Caller must ensure AVX2 and FMA are available.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn exp_slice_avx2(xs: &mut [f64]) {
        let mut chunks = xs.chunks_exact_mut(4);
        for chunk in &mut chunks {
            let v = _mm256_loadu_pd(chunk.as_ptr());
            _mm256_storeu_pd(chunk.as_mut_ptr(), exp4(v));
        }
        for x in chunks.into_remainder() {
            *x = super::exp_fast_scalar(*x);
        }
    }
}

// ---------------------------------------------------------------------------
// Neon arm (aarch64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{EXP_HI, EXP_LO, EXP_POLY, LN2_HI, LN2_LO, LOG2E, MR, NR, SHIFTER};
    use std::arch::aarch64::*;

    /// Neon dot micro-kernel: 16 live 2-lane accumulators in the 4-row
    /// case. Same per-lane sequential chains as the portable arm; fused
    /// multiply-adds of exact (f32-widened) products round identically,
    /// so this arm is bit-identical for the crate's inputs.
    ///
    /// # Safety
    /// Caller must ensure every row's length equals `pack.len()` (the
    /// dispatcher asserts this; ASIMD itself is baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_rows_neon(rows: &[&[f32]], pack: &[[f64; NR]]) -> [[f64; NR]; MR] {
        let mut out = [[0.0f64; NR]; MR];
        let d = pack.len();
        match rows {
            [a0, a1, a2, a3] => {
                let mut acc = [[vdupq_n_f64(0.0); 4]; MR];
                for t in 0..d {
                    let slab = pack.get_unchecked(t).as_ptr();
                    let s0 = vld1q_f64(slab);
                    let s1 = vld1q_f64(slab.add(2));
                    let s2 = vld1q_f64(slab.add(4));
                    let s3 = vld1q_f64(slab.add(6));
                    let vs = [
                        *a0.get_unchecked(t) as f64,
                        *a1.get_unchecked(t) as f64,
                        *a2.get_unchecked(t) as f64,
                        *a3.get_unchecked(t) as f64,
                    ];
                    for (accr, &v) in acc.iter_mut().zip(vs.iter()) {
                        accr[0] = vfmaq_n_f64(accr[0], s0, v);
                        accr[1] = vfmaq_n_f64(accr[1], s1, v);
                        accr[2] = vfmaq_n_f64(accr[2], s2, v);
                        accr[3] = vfmaq_n_f64(accr[3], s3, v);
                    }
                }
                for (o, accr) in out.iter_mut().zip(acc.iter()) {
                    for (q, a) in accr.iter().enumerate() {
                        vst1q_f64(o.as_mut_ptr().add(2 * q), *a);
                    }
                }
            }
            _ => {
                for (o, a) in out.iter_mut().zip(rows.iter()) {
                    let mut accr = [vdupq_n_f64(0.0); 4];
                    for t in 0..d {
                        let slab = pack.get_unchecked(t).as_ptr();
                        let v = *a.get_unchecked(t) as f64;
                        accr[0] = vfmaq_n_f64(accr[0], vld1q_f64(slab), v);
                        accr[1] = vfmaq_n_f64(accr[1], vld1q_f64(slab.add(2)), v);
                        accr[2] = vfmaq_n_f64(accr[2], vld1q_f64(slab.add(4)), v);
                        accr[3] = vfmaq_n_f64(accr[3], vld1q_f64(slab.add(6)), v);
                    }
                    for (q, acc) in accr.iter().enumerate() {
                        vst1q_f64(o.as_mut_ptr().add(2 * q), *acc);
                    }
                }
            }
        }
        out
    }

    /// One 2-lane step of the batched exp; same operation sequence as
    /// [`super::exp_fast_scalar`]. Neon `fmin`/`fmax` propagate NaN, so a
    /// NaN lane flows NaN through the whole pipeline and the final select
    /// restores the input payload.
    ///
    /// # Safety
    /// ASIMD must be available (baseline on aarch64).
    #[target_feature(enable = "neon")]
    unsafe fn exp2_lanes(x: float64x2_t) -> float64x2_t {
        let hi = vdupq_n_f64(EXP_HI);
        let lo = vdupq_n_f64(EXP_LO);
        let xc = vmaxq_f64(vminq_f64(x, hi), lo);
        let shifter = vdupq_n_f64(SHIFTER);
        let t = vmulq_f64(xc, vdupq_n_f64(LOG2E));
        let n = vsubq_f64(vaddq_f64(t, shifter), shifter);
        let r = vfmsq_f64(xc, n, vdupq_n_f64(LN2_HI));
        let r = vfmsq_f64(r, n, vdupq_n_f64(LN2_LO));
        let mut p = vdupq_n_f64(EXP_POLY[0]);
        for &c in &EXP_POLY[1..] {
            p = vfmaq_f64(vdupq_n_f64(c), p, r);
        }
        // 2^n in two half-exponent factors; vshrq_n floors like `>> 1`.
        let ni = vcvtq_s64_f64(n);
        let h = vshrq_n_s64::<1>(ni);
        let rest = vsubq_s64(ni, h);
        let bias = vdupq_n_s64(1023);
        let s1 = vreinterpretq_f64_s64(vshlq_n_s64::<52>(vaddq_s64(h, bias)));
        let s2 = vreinterpretq_f64_s64(vshlq_n_s64::<52>(vaddq_s64(rest, bias)));
        let res = vmulq_f64(vmulq_f64(p, s1), s2);
        let big = vcgtq_f64(x, hi);
        let small = vcltq_f64(x, lo);
        let not_nan = vceqq_f64(x, x);
        let res = vbslq_f64(big, vdupq_n_f64(f64::INFINITY), res);
        let res = vbslq_f64(small, vdupq_n_f64(0.0), res);
        vbslq_f64(not_nan, res, x)
    }

    /// Batched exp over a slice: 2-lane body, scalar-twin tail.
    ///
    /// # Safety
    /// ASIMD must be available (baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn exp_slice_neon(xs: &mut [f64]) {
        let mut chunks = xs.chunks_exact_mut(2);
        for chunk in &mut chunks {
            let v = vld1q_f64(chunk.as_ptr());
            vst1q_f64(chunk.as_mut_ptr(), exp2_lanes(v));
        }
        for x in chunks.into_remainder() {
            *x = super::exp_fast_scalar(*x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fmath;
    use crate::util::rng::Rng;

    fn pack_cols(cols: &[Vec<f32>], d: usize) -> Vec<[f64; NR]> {
        let mut pack = vec![[0.0f64; NR]; d];
        for (c, col) in cols.iter().enumerate() {
            for (slab, &v) in pack.iter_mut().zip(col.iter()) {
                slab[c] = v as f64;
            }
        }
        pack
    }

    fn random_rows(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..d).map(|_| (rng.f64() * 4.0 - 2.0) as f32).collect())
            .collect()
    }

    #[test]
    fn mode_names_round_trip() {
        for mode in [NumericsMode::Deterministic, NumericsMode::Fast] {
            assert_eq!(NumericsMode::from_name(mode.name()), Some(mode));
        }
        assert_eq!(NumericsMode::from_name("det"), Some(NumericsMode::Deterministic));
        assert_eq!(NumericsMode::from_name("turbo"), None);
        assert_eq!(NumericsMode::default(), NumericsMode::Deterministic);
    }

    #[test]
    fn detected_arch_is_available_and_stable() {
        let a = detected_arch();
        assert!(a.available());
        assert_eq!(a, detected_arch(), "detection must latch");
        assert!(test_arches().contains(&Arch::Portable));
    }

    #[test]
    fn portable_dot_matches_fmath_per_value() {
        // Miri-friendly: pure safe scalar code. Each (row, col) lane of the
        // micro-kernel must equal the sequential fmath chain to the bit.
        let mut rng = Rng::seeded(41);
        for d in [1usize, 2, 3, 7, 8, 15, 16, 128] {
            let rows = random_rows(&mut rng, 4, d);
            let cols = random_rows(&mut rng, NR, d);
            let pack = pack_cols(&cols, d);
            for take in 1..=4usize {
                let views: Vec<&[f32]> = rows[..take].iter().map(|r| r.as_slice()).collect();
                let acc = dot_rows_portable(&views, &pack);
                for (r, row) in rows[..take].iter().enumerate() {
                    for (c, col) in cols.iter().enumerate() {
                        let want = fmath::dot_f64(row, col);
                        assert_eq!(
                            acc[r][c].to_bits(),
                            want.to_bits(),
                            "d={d} take={take} r={r} c={c}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn exp_fast_scalar_within_budget() {
        // Miri-friendly sweep (small but covering every regime): the
        // scalar twin is the reference for the SIMD lanes, so its own
        // error against libm bounds every arm's error.
        let mut worst = 0u64;
        let mut check = |x: f64| {
            let got = exp_fast_scalar(x);
            let want = x.exp();
            let d = ulp_distance(got, want)
                .unwrap_or_else(|| panic!("exp({x}): {got} vs {want} not comparable"));
            worst = worst.max(d);
            assert!(d <= EXP_ULP_BUDGET, "exp({x}) off by {d} ulp: {got} vs {want}");
        };
        let mut x = -745.5;
        while x <= 60.0 {
            check(x);
            x += 2.43;
        }
        for s in [
            0.0,
            -0.0,
            1.0,
            -1.0,
            1e-300,
            -1e-300,
            f64::MIN_POSITIVE / 8.0, // subnormal argument
            -708.0,
            -708.5,
            -744.0,
            -745.1,
            709.7,
            EXP_HI,
            EXP_LO,
        ] {
            check(s);
        }
        assert!(worst <= EXP_ULP_BUDGET);
    }

    #[test]
    fn exp_fast_scalar_specials() {
        assert_eq!(exp_fast_scalar(0.0), 1.0);
        assert_eq!(exp_fast_scalar(-0.0), 1.0);
        assert_eq!(exp_fast_scalar(f64::INFINITY), f64::INFINITY);
        assert_eq!(exp_fast_scalar(f64::NEG_INFINITY), 0.0);
        assert!(exp_fast_scalar(f64::NAN).is_nan());
        assert_eq!(exp_fast_scalar(-1000.0), 0.0);
        assert_eq!(exp_fast_scalar(1000.0), f64::INFINITY);
        // Gradual underflow: a deep-negative argument lands in the
        // subnormals, not a hard zero.
        let sub = exp_fast_scalar(-744.0);
        assert!(sub > 0.0 && sub < f64::MIN_POSITIVE, "expected subnormal, got {sub}");
    }

    #[test]
    fn ulp_distance_semantics() {
        assert_eq!(ulp_distance(1.0, 1.0), Some(0));
        assert_eq!(ulp_distance(0.0, -0.0), Some(0));
        assert_eq!(ulp_distance(f64::NAN, f64::NAN), Some(0));
        assert_eq!(ulp_distance(f64::NAN, 1.0), None);
        assert_eq!(ulp_distance(1.0, -1.0), None);
        assert_eq!(ulp_distance(1.0, 1.0 + f64::EPSILON), Some(1));
        assert_eq!(ulp_distance(f64::MAX, f64::INFINITY), Some(1));
        // Signed zero adjacent to the smallest subnormal: distance 1.
        assert_eq!(ulp_distance(0.0, f64::from_bits(1)), Some(1));
        assert_eq!(ulp_distance(-0.0, f64::from_bits(1)), Some(1));
    }

    // The SIMD arms execute vendor intrinsics, which Miri cannot
    // interpret; everything above runs under Miri, everything below is
    // additionally exercised by the dedicated diff harness
    // (tests/diff_simd_scalar.rs).
    #[cfg(not(miri))]
    #[test]
    fn simd_dot_arms_match_portable_bitwise() {
        let mut rng = Rng::seeded(97);
        for arch in test_arches() {
            for d in [1usize, 2, 3, 7, 8, 15, 16, 128] {
                let rows = random_rows(&mut rng, 4, d);
                let cols = random_rows(&mut rng, NR, d);
                let pack = pack_cols(&cols, d);
                for take in 1..=4usize {
                    let views: Vec<&[f32]> = rows[..take].iter().map(|r| r.as_slice()).collect();
                    let want = dot_rows_portable(&views, &pack);
                    let got = dot_rows_with_arch(arch, &views, &pack);
                    for r in 0..take {
                        for c in 0..NR {
                            assert_eq!(
                                got[r][c].to_bits(),
                                want[r][c].to_bits(),
                                "{arch:?} d={d} take={take} r={r} c={c}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[cfg(not(miri))]
    #[test]
    fn simd_exp_arms_match_scalar_twin_and_budget() {
        for arch in test_arches() {
            // Lengths straddling every remainder of both lane widths.
            for len in [1usize, 2, 3, 4, 5, 7, 8, 9, 31] {
                let xs: Vec<f64> =
                    (0..len).map(|i| -0.37 * (i as f64) - 0.001).collect();
                let mut got = xs.clone();
                exp_slice_with_arch(arch, &mut got);
                for (i, (&g, &x)) in got.iter().zip(xs.iter()).enumerate() {
                    let d = ulp_distance(g, x.exp()).unwrap();
                    assert!(
                        d <= EXP_ULP_BUDGET,
                        "{arch:?} len={len} i={i}: {g} vs {} ({d} ulp)",
                        x.exp()
                    );
                    if arch != Arch::Portable {
                        // Lane-position independence: any position must
                        // reproduce the scalar twin exactly.
                        assert_eq!(
                            g.to_bits(),
                            exp_fast_scalar(x).to_bits(),
                            "{arch:?} len={len} i={i} diverged from scalar twin"
                        );
                    }
                }
            }
        }
    }
}
