//! CRC-32 (IEEE 802.3 / zlib / PNG variant), table-driven, zero-dependency.
//!
//! Used by the artifact container (`serve::format`, DESIGN.md §12) to
//! checksum the header and payload sections independently, so a torn or
//! bit-flipped artifact is *detected* at load instead of deserializing into
//! a silently wrong model. The reflected polynomial `0xEDB88320` with init
//! and final-xor `0xFFFFFFFF` is the ubiquitous variant every external
//! tool (`cksum -o 3`, `python -c 'import zlib'`, `crc32(1)`) can verify,
//! which matters for operators inspecting artifacts out-of-band.
//!
//! The 1 KiB lookup table is built in a `const fn` at compile time — no
//! lazy init, no locks, no first-call latency on the serving path.

/// Reflected CRC-32 polynomial (IEEE 802.3).
const POLY: u32 = 0xEDB8_8320;

const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// CRC-32 of `bytes` (init `0xFFFFFFFF`, final xor `0xFFFFFFFF`).
pub fn crc32(bytes: &[u8]) -> u32 {
    update(0xFFFF_FFFF, bytes) ^ 0xFFFF_FFFF
}

/// Fold `bytes` into a running (pre-final-xor) CRC state. Exposed so large
/// artifacts could checksum incrementally; `state` starts at `0xFFFFFFFF`
/// and the caller applies the final xor.
pub fn update(mut state: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        state = (state >> 8) ^ TABLE[((state ^ b as u32) & 0xFF) as usize];
    }
    state
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_vectors() {
        // The standard check value for this CRC variant.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data = b"mini-batch kernel k-means artifact checksum";
        for split in 0..data.len() {
            let s = update(0xFFFF_FFFF, &data[..split]);
            let s = update(s, &data[split..]);
            assert_eq!(s ^ 0xFFFF_FFFF, crc32(data), "split at {split}");
        }
    }

    #[test]
    fn single_bit_flips_are_detected() {
        let data: Vec<u8> = (0u16..512).map(|i| (i * 7 + 3) as u8).collect();
        let good = crc32(&data);
        let mut bad = data.clone();
        for byte in (0..data.len()).step_by(37) {
            for bit in 0..8 {
                bad[byte] ^= 1 << bit;
                assert_ne!(crc32(&bad), good, "flip at byte {byte} bit {bit}");
                bad[byte] ^= 1 << bit;
            }
        }
    }
}
