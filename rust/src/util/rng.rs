//! Deterministic pseudo-random number generation.
//!
//! Implements SplitMix64 (for seeding) and Xoshiro256++ (the main stream),
//! plus the distribution helpers the clustering code needs: uniform floats,
//! bounded integers without modulo bias, Gaussian variates (Box–Muller),
//! Fisher–Yates shuffle, weighted choice (for k-means++ D² sampling), and
//! stream splitting so parallel experiment repeats get independent streams.
//!
//! Every stochastic component in the crate takes an explicit [`Rng`] so runs
//! are reproducible from a single seed recorded in the experiment report.

/// SplitMix64 step — used to expand a 64-bit seed into Xoshiro state and to
/// derive child seeds.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256++ PRNG. Fast, high quality, 2^256−1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_cache: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_cache: None }
    }

    /// Export the complete generator state — the four Xoshiro words plus
    /// the cached second Box–Muller variate — for training checkpoints.
    /// [`Rng::from_state`] restores it; the restored stream continues
    /// bit-identically to the original (DESIGN.md §12).
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_cache)
    }

    /// Rebuild a generator from [`Rng::state`] output.
    pub fn from_state(s: [u64; 4], gauss_cache: Option<f64>) -> Rng {
        Rng { s, gauss_cache }
    }

    /// Derive an independent child stream; deterministic in (self state, tag).
    pub fn split(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_cache: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal variate via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_cache.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_cache = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` indices uniformly from [0, n) **with** repetitions — the
    /// paper's batch sampling model.
    pub fn sample_with_replacement(&mut self, n: usize, m: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.sample_with_replacement_into(n, m, &mut out);
        out
    }

    /// [`Rng::sample_with_replacement`] into a caller-owned buffer
    /// (cleared, then filled) — draws the identical index sequence, but
    /// lets iteration loops reuse one batch buffer instead of allocating
    /// per iteration.
    pub fn sample_with_replacement_into(&mut self, n: usize, m: usize, out: &mut Vec<usize>) {
        out.clear();
        out.reserve(m);
        for _ in 0..m {
            out.push(self.below(n));
        }
    }

    /// Sample `m` distinct indices from [0, n) (partial Fisher–Yates when m ≪ n,
    /// selection-tracking otherwise).
    pub fn sample_without_replacement(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "cannot sample {m} distinct from {n}");
        if m * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(m);
            idx
        } else {
            let mut chosen = std::collections::HashSet::with_capacity(m * 2);
            let mut out = Vec::with_capacity(m);
            while out.len() < m {
                let i = self.below(n);
                if chosen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }

    /// Weighted choice: returns index i with probability w[i] / Σw.
    /// Used by k-means++ D² sampling. Weights must be non-negative with a
    /// positive sum; on degenerate input falls back to uniform.
    pub fn weighted_choice(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) || !total.is_finite() {
            return self.below(weights.len());
        }
        let mut target = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target < 0.0 {
                return i;
            }
        }
        weights.len() - 1 // numeric fallthrough
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_are_independent_and_deterministic() {
        let mut root1 = Rng::seeded(7);
        let mut root2 = Rng::seeded(7);
        let mut c1 = root1.split(3);
        let mut c2 = root2.split(3);
        for _ in 0..50 {
            assert_eq!(c1.next_u64(), c2.next_u64());
        }
        let mut other = Rng::seeded(7).split(4);
        assert_ne!(c1.next_u64(), other.next_u64());
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Rng::seeded(9);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Rng::seeded(11);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7)] += 1;
        }
        for &c in &counts {
            let expect = n / 7;
            assert!((c as i64 - expect as i64).abs() < (expect as i64) / 10);
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seeded(13);
        let n = 50_000;
        let mut m1 = 0.0;
        let mut m2 = 0.0;
        for _ in 0..n {
            let z = rng.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var={m2}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::seeded(5);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut rng = Rng::seeded(17);
        for &(n, m) in &[(100, 10), (100, 90), (5, 5)] {
            let s = rng.sample_without_replacement(n, m);
            assert_eq!(s.len(), m);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), m);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut rng = Rng::seeded(23);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.weighted_choice(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn weighted_choice_degenerate_falls_back_uniform() {
        let mut rng = Rng::seeded(29);
        let w = [0.0, 0.0];
        for _ in 0..10 {
            assert!(rng.weighted_choice(&w) < 2);
        }
    }
}
