//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `subcommand --flag --key value --key=value positional` grammars,
//! typed accessors with defaults, and a collected-error report for unknown
//! keys via [`Args::finish`].

use std::collections::BTreeMap;

/// Parsed command line: one optional subcommand, key→value options, bare
/// flags, and positional arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First bare token, e.g. `run` in `mbkk run --k 3`.
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Bare tokens that are neither the subcommand nor option values.
    pub positional: Vec<String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        // First bare token (not starting with '-') is the subcommand.
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.opts.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the process's own argv.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().insert(key.to_string());
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with a default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with default; panics with a friendly message on parse
    /// failure (CLI surface, so failing fast is correct).
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            None => default,
            Some(s) => s
                .parse()
                .unwrap_or_else(|_| panic!("--{key}: cannot parse {s:?}")),
        }
    }

    /// Comma-separated list option, e.g. `--batch-sizes 256,512,1024`.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.get(key) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| {
                    p.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{key}: cannot parse element {p:?}"))
                })
                .collect(),
        }
    }

    /// Bare flag (also true when given as `--key true/1`).
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        if self.flags.iter().any(|f| f == key) {
            return true;
        }
        matches!(self.opts.get(key).map(|s| s.as_str()), Some("true" | "1" | "yes"))
    }

    /// Returns the list of keys the user passed that no accessor touched —
    /// catches typos like `--bacth-size`.
    pub fn unknown_keys(&self) -> Vec<String> {
        let consumed = self.consumed.borrow();
        self.opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(*k))
            .cloned()
            .collect()
    }

    /// Abort with a message if any unrecognised options remain.
    pub fn finish(&self) {
        let unknown = self.unknown_keys();
        if !unknown.is_empty() {
            eprintln!("error: unknown option(s): {}", unknown.join(", "));
            std::process::exit(2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        // Note the grammar: a bare flag immediately followed by a bare token
        // would swallow it as a value, so positionals precede options.
        let a = parse(&["run", "extra", "--dataset", "rings", "--k=3", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("dataset"), Some("rings"));
        assert_eq!(a.get_parse_or("k", 0usize), 3);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["run"]);
        assert_eq!(a.get_or("dataset", "blobs"), "blobs");
        assert_eq!(a.get_parse_or("batch", 256usize), 256);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn list_option() {
        let a = parse(&["x", "--bs", "256,512, 1024"]);
        assert_eq!(a.get_list("bs", &[0usize]), vec![256, 512, 1024]);
        assert_eq!(a.get_list("tau", &[50usize, 100]), vec![50, 100]);
    }

    #[test]
    fn flag_followed_by_flag_not_swallowed() {
        let a = parse(&["x", "--fast", "--k", "3"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get_parse_or("k", 0usize), 3);
    }

    #[test]
    fn unknown_key_detection() {
        let a = parse(&["x", "--good", "1", "--typo", "2"]);
        let _ = a.get("good");
        assert_eq!(a.unknown_keys(), vec!["typo".to_string()]);
    }

    #[test]
    fn no_subcommand_when_leading_dash() {
        let a = parse(&["--k", "2"]);
        assert!(a.subcommand.is_none());
        assert_eq!(a.get_parse_or("k", 0usize), 2);
    }
}
