//! Data-parallel helpers over the persistent worker pool (rayon
//! replacement — see [`super::pool`] and ADR-002).
//!
//! The clustering hot paths are embarrassingly parallel over rows (batch
//! points, dataset points, matrix rows). [`par_chunks_mut`] splits an output
//! slice into contiguous chunks, one per worker; [`par_map_indexed`] maps an
//! index range; both fall back to the serial path for tiny inputs where
//! dispatch overhead dominates. No helper spawns OS threads per invocation:
//! every parallel region is a *job* submitted to the process-wide pool,
//! whose `num_threads() − 1` workers are spawned once and reused.

use super::pool;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: `MBKK_THREADS` env override, else
/// available parallelism, capped at 16 (the workloads stop scaling there).
/// Read once and cached — the pool sizes itself off the first call.
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let cached = CACHED.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("MBKK_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(16)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Minimum amount of work (items) per thread before parallelism pays off.
const MIN_ITEMS_PER_THREAD: usize = 256;

/// Run `f(chunk_start_index, chunk)` in parallel over contiguous mutable
/// chunks of `out`, with `chunk.len() ≈ out.len() / workers`.
pub fn par_chunks_mut<T: Send, F>(out: &mut [T], f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    let workers = num_threads().min(n.div_ceil(MIN_ITEMS_PER_THREAD)).max(1);
    if workers == 1 {
        f(0, out);
        return;
    }
    let chunk = n.div_ceil(workers);
    let njobs = n.div_ceil(chunk);
    let view = SharedSlice::new(out);
    let view = &view;
    pool::run(njobs, &|ci| {
        let start = ci * chunk;
        let len = chunk.min(n - start);
        // SAFETY: job indices map to disjoint [start, start+len) ranges.
        let piece = unsafe { view.chunk_mut(start, len) };
        f(start, piece);
    });
}

/// Like [`par_chunks_mut`] but splits on whole-row boundaries of a
/// row-major matrix with `row_len` elements per row. `f(first_row, rows)`
/// receives the index of its first row and a row-aligned mutable block.
pub fn par_rows_mut<T: Send, F>(out: &mut [T], row_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let workers = num_threads()
        .min(out.len().div_ceil(MIN_ITEMS_PER_THREAD))
        .max(1);
    par_rows_mut_workers(out, row_len, workers, f);
}

/// [`par_rows_mut`] with an explicit worker-count target, for callers whose
/// per-item cost is far from uniform bytes (matmul sizes its workers from a
/// flop estimate, not from `out.len()`).
pub fn par_rows_mut_workers<T: Send, F>(out: &mut [T], row_len: usize, workers: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(row_len > 0 && out.len() % row_len == 0, "non-rectangular data");
    let nrows = out.len() / row_len;
    if nrows == 0 {
        return;
    }
    let workers = workers.min(nrows).max(1);
    if workers == 1 {
        f(0, out);
        return;
    }
    let rows_per = nrows.div_ceil(workers);
    let njobs = nrows.div_ceil(rows_per);
    let view = SharedSlice::new(out);
    let view = &view;
    pool::run(njobs, &|bi| {
        let row0 = bi * rows_per;
        let rows = rows_per.min(nrows - row0);
        // SAFETY: job indices map to disjoint row-aligned ranges.
        let block = unsafe { view.chunk_mut(row0 * row_len, rows * row_len) };
        f(row0, block);
    });
}

/// Like [`par_rows_mut`], but hands each worker row-aligned blocks of
/// *three* parallel arrays describing the same rows: `a` with `la` elements
/// per row, `b` with `lb`, `c` with `lc`. Used by the fused
/// update-plus-argmin pass of Algorithm 1, which writes the `px` table, the
/// assignment vector, and the min-distance vector in one sweep over the
/// dataset (see `kkmeans::minibatch`).
pub fn par_rows_mut3<A: Send, B: Send, C: Send, F>(
    a: &mut [A],
    la: usize,
    b: &mut [B],
    lb: usize,
    c: &mut [C],
    lc: usize,
    f: F,
) where
    F: Fn(usize, &mut [A], &mut [B], &mut [C]) + Sync,
{
    assert!(la > 0 && lb > 0 && lc > 0, "zero-width rows");
    assert!(a.len() % la == 0, "non-rectangular data");
    let nrows = a.len() / la;
    assert_eq!(b.len(), nrows * lb, "row count mismatch (b)");
    assert_eq!(c.len(), nrows * lc, "row count mismatch (c)");
    if nrows == 0 {
        return;
    }
    let workers = num_threads()
        .min(a.len().div_ceil(MIN_ITEMS_PER_THREAD))
        .min(nrows)
        .max(1);
    if workers == 1 {
        f(0, a, b, c);
        return;
    }
    let rows_per = nrows.div_ceil(workers);
    let njobs = nrows.div_ceil(rows_per);
    let va = SharedSlice::new(a);
    let vb = SharedSlice::new(b);
    let vc = SharedSlice::new(c);
    let (va, vb, vc) = (&va, &vb, &vc);
    pool::run(njobs, &|bi| {
        let row0 = bi * rows_per;
        let rows = rows_per.min(nrows - row0);
        // SAFETY: job indices map to disjoint row-aligned ranges in each of
        // the three arrays.
        let (ba, bb, bc) = unsafe {
            (
                va.chunk_mut(row0 * la, rows * la),
                vb.chunk_mut(row0 * lb, rows * lb),
                vc.chunk_mut(row0 * lc, rows * lc),
            )
        };
        f(row0, ba, bb, bc);
    });
}

/// Run `f(i)` for every `i in 0..count` across the pool, one task per
/// index. Tasks are claimed from a shared atomic counter, so this
/// load-balances *dynamically*, which matters when work per index is
/// irregular — e.g. the symmetric gram tiles, where diagonal tiles do half
/// the work of off-diagonal ones.
pub fn par_dynamic<F>(count: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    pool::run(count, &f);
}

/// Shared-write view over a mutable slice for parallel kernels whose write
/// sets are *provably disjoint* but not expressible as contiguous chunks —
/// the symmetric gram materializer writes both `(i, j)` and its mirror
/// `(j, i)` from the tile that owns the unordered pair `{i, j}`.
///
/// Safety contract: concurrent [`SharedSlice::write`] calls from different
/// threads must target distinct indices, and [`SharedSlice::chunk_mut`]
/// subslices handed to different threads must not overlap. The only
/// constructor borrows the slice mutably for the view's lifetime, so no
/// other access can coexist.
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: the view is only a carrier for the raw pointer; all dereferencing
// goes through the `unsafe` methods whose contracts forbid overlapping
// access. `T: Send` bounds match sending &mut [T] chunks to threads.
unsafe impl<'a, T: Send> Send for SharedSlice<'a, T> {}
unsafe impl<'a, T: Send> Sync for SharedSlice<'a, T> {}

impl<'a, T> SharedSlice<'a, T> {
    /// Create a shared-write view over `slice`.
    pub fn new(slice: &'a mut [T]) -> SharedSlice<'a, T> {
        SharedSlice {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Number of elements in the underlying slice.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the underlying slice is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `value` at `idx`.
    ///
    /// # Safety
    ///
    /// `idx` must be in bounds, and no concurrent write (from any thread)
    /// may target the same index while this call executes.
    #[inline]
    pub unsafe fn write(&self, idx: usize, value: T) {
        debug_assert!(idx < self.len, "SharedSlice write out of bounds");
        *self.ptr.add(idx) = value;
    }

    /// Reborrow `[start, start + len)` as a mutable subslice.
    ///
    /// # Safety
    ///
    /// The range must be in bounds, and ranges handed to concurrently
    /// running closures must be pairwise disjoint (no element may be
    /// reachable through two live subslices).
    #[inline]
    pub unsafe fn chunk_mut(&self, start: usize, len: usize) -> &'a mut [T] {
        debug_assert!(start + len <= self.len, "SharedSlice chunk out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// Parallel map over `0..n`, collecting results in order.
pub fn par_map_indexed<T: Send, F>(n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
    T: Default + Clone,
{
    let mut out = vec![T::default(); n];
    par_chunks_mut(&mut out, |start, chunk| {
        for (i, slot) in chunk.iter_mut().enumerate() {
            *slot = f(start + i);
        }
    });
    out
}

/// Parallel fold: maps `0..n` through `map` on pool workers and reduces the
/// per-chunk partials with `reduce`, in chunk order (deterministic for a
/// fixed `num_threads()`). Used for objective evaluation (sums).
pub fn par_fold<A, M, R>(n: usize, identity: A, map: M, reduce: R) -> A
where
    A: Send + Clone,
    M: Fn(usize) -> A + Sync,
    R: Fn(A, A) -> A + Send + Sync,
{
    if n == 0 {
        return identity;
    }
    let workers = num_threads().min(n.div_ceil(MIN_ITEMS_PER_THREAD)).max(1);
    if workers == 1 {
        let mut acc = identity;
        for i in 0..n {
            acc = reduce(acc, map(i));
        }
        return acc;
    }
    let chunk = n.div_ceil(workers);
    let njobs = n.div_ceil(chunk);
    let partials: Vec<Mutex<Option<A>>> = (0..njobs).map(|_| Mutex::new(None)).collect();
    // Per-job identity seeds, cloned up front: `A` is only `Send`, so the
    // tasks take owned seeds instead of sharing `&identity` across threads.
    let seeds: Vec<Mutex<Option<A>>> =
        (0..njobs).map(|_| Mutex::new(Some(identity.clone()))).collect();
    {
        let partials = &partials;
        let seeds = &seeds;
        let map = &map;
        let reduce = &reduce;
        pool::run(njobs, &|ci| {
            let lo = ci * chunk;
            let hi = (lo + chunk).min(n);
            let mut acc = seeds[ci]
                .lock()
                .expect("par_fold seed poisoned")
                .take()
                .expect("par_fold seed claimed twice");
            for i in lo..hi {
                acc = reduce(acc, map(i));
            }
            *partials[ci].lock().expect("par_fold partial poisoned") = Some(acc);
        });
    }
    let mut acc = identity;
    for p in partials {
        let p = p.into_inner().expect("par_fold partial poisoned");
        acc = reduce(acc, p.expect("worker panicked"));
    }
    acc
}

/// Run a list of independent jobs with bounded concurrency (the pool's
/// width). Used by the experiment coordinator to run grid cells
/// concurrently.
pub fn par_run_jobs<T: Send, F>(jobs: Vec<F>) -> Vec<T>
where
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    if num_threads() == 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let queue: Vec<Mutex<Option<F>>> =
        jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    {
        let queue = &queue;
        let results = &results;
        pool::run(n, &|i| {
            let job = queue[i].lock().unwrap().take().expect("job claimed twice");
            let r = job();
            *results[i].lock().unwrap() = Some(r);
        });
    }
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job missing result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_chunks_covers_all_indices() {
        let mut out = vec![0usize; 10_000];
        par_chunks_mut(&mut out, |start, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = start + i;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i);
        }
    }

    #[test]
    fn par_map_matches_serial() {
        let got = par_map_indexed(5000, |i| i * i);
        let want: Vec<usize> = (0..5000).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_fold_sums() {
        let s = par_fold(10_000, 0u64, |i| i as u64, |a, b| a + b);
        assert_eq!(s, 9999 * 10_000 / 2);
    }

    #[test]
    fn small_inputs_take_serial_path() {
        let mut out = vec![0; 3];
        par_chunks_mut(&mut out, |start, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = start + i + 1;
            }
        });
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn par_run_jobs_preserves_order() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..50usize)
            .map(|i| Box::new(move || i * 2) as Box<dyn FnOnce() -> usize + Send>)
            .collect();
        let out = par_run_jobs(jobs);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_rows_mut3_aligned_rows() {
        let n = 1000;
        let k = 3;
        let mut a = vec![0usize; n * k];
        let mut b = vec![0usize; n];
        let mut c = vec![0.0f64; n];
        par_rows_mut3(&mut a, k, &mut b, 1, &mut c, 1, |row0, ba, bb, bc| {
            for (r, row) in ba.chunks_mut(k).enumerate() {
                let x = row0 + r;
                for (j, v) in row.iter_mut().enumerate() {
                    *v = x * k + j;
                }
                bb[r] = x;
                bc[r] = x as f64;
            }
        });
        for (i, v) in a.iter().enumerate() {
            assert_eq!(*v, i);
        }
        for (i, v) in b.iter().enumerate() {
            assert_eq!(*v, i);
        }
        assert_eq!(c[999], 999.0);
    }

    #[test]
    fn par_dynamic_covers_all_indices() {
        let flags: Vec<std::sync::atomic::AtomicUsize> =
            (0..500).map(|_| AtomicUsize::new(0)).collect();
        par_dynamic(500, |i| {
            flags[i].fetch_add(1, Ordering::Relaxed);
        });
        for f in &flags {
            assert_eq!(f.load(Ordering::Relaxed), 1);
        }
        par_dynamic(0, |_| panic!("must not run"));
    }

    #[test]
    fn shared_slice_disjoint_writes() {
        let mut data = vec![0u32; 256];
        {
            let view = SharedSlice::new(&mut data);
            assert_eq!(view.len(), 256);
            assert!(!view.is_empty());
            par_dynamic(256, |i| {
                // Each index written exactly once — the contract.
                unsafe { view.write(i, i as u32 + 1) };
            });
        }
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u32 + 1);
        }
    }

    #[test]
    fn empty_inputs_ok() {
        let mut e: Vec<u8> = vec![];
        par_chunks_mut(&mut e, |_, _| {});
        assert_eq!(par_fold(0, 7i32, |_| 0, |a, b| a + b), 7);
        let out: Vec<i32> = par_run_jobs(Vec::<Box<dyn FnOnce() -> i32 + Send>>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn nested_parallel_regions_complete() {
        // A region whose tasks submit further regions, with BOTH levels
        // genuinely on the pool: par_dynamic submits one task per index
        // (no serial-path threshold), so the outer tasks run on pool
        // workers and the inner submissions exercise nested draining. The
        // pool must never deadlock, and every inner result must land.
        let got: Vec<Mutex<u64>> = (0..64).map(|_| Mutex::new(0)).collect();
        par_dynamic(64, |i| {
            let inner = par_fold(512, 0u64, |j| (i * j) as u64, |a, b| a + b);
            *got[i].lock().unwrap() = inner;
        });
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v.lock().unwrap(), (i as u64) * (511 * 512 / 2));
        }
    }

    #[test]
    fn par_rows_mut_workers_explicit_count() {
        let mut out = vec![0usize; 37 * 4];
        par_rows_mut_workers(&mut out, 4, 8, |row0, block| {
            for (r, row) in block.chunks_mut(4).enumerate() {
                for v in row.iter_mut() {
                    *v = row0 + r;
                }
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i / 4);
        }
    }
}
