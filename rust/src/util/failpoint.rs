//! Zero-dependency fault injection (failpoints) — ADR-004, DESIGN.md §12.
//!
//! Every I/O and concurrency boundary in the crate evaluates a *named
//! failpoint* (artifact writes, checkpoint saves, HTTP accept/read/write,
//! the coalescer leader flush, worker-pool jobs). In production nothing is
//! configured and the check is a single relaxed atomic load — measured as
//! unobservable in `bench_serving`'s overhead case. Under test, the
//! `MBKK_FAILPOINTS` environment variable (or [`configure`] from test
//! code) arms specific points to panic, return an injected error, or stall
//! — which is how the chaos CI job kills a training run mid-write and how
//! the leader-panic recovery test poisons exactly one coalesced request.
//!
//! ## Spec grammar
//!
//! ```text
//! MBKK_FAILPOINTS = point [; point]*
//! point           = name "=" [ "after(" N "):" ] [ K "*" ] action
//! action          = "panic" | "err" | "err(" message ")" | "delay(" ms ")"
//! ```
//!
//! * `after(N):` — let the first N evaluations pass before acting.
//! * `K*` — act at most K times, then the point goes quiet.
//! * `panic` — panic at the evaluation site (crash simulation; the site's
//!   normal unwind path — catch, poison recovery, process death — is the
//!   thing under test).
//! * `err` / `err(message)` — the site fails with an injected
//!   [`Error`](crate::util::error::Error) through its ordinary error path.
//! * `delay(ms)` — sleep inline, then proceed normally (widens race and
//!   kill windows; the chaos job SIGKILLs a run stalled inside an
//!   artifact write to manufacture a torn file).
//!
//! Example: `MBKK_FAILPOINTS='checkpoint.save=after(2):1*panic'` crashes
//! the third checkpoint save, once.
//!
//! ## Hot-path contract
//!
//! [`armed`] is the only thing instrumented code calls when no failpoint
//! was ever configured: one `Once` completion check plus one relaxed
//! `AtomicBool` load, no locks, no allocation, no branch on string data.
//! The registry mutex is touched only when the process was explicitly
//! armed, where overhead is irrelevant by definition.

use crate::util::error::Result;
use crate::{bail, format_err};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once};

/// Environment variable holding the failpoint spec (parsed once, on the
/// first [`armed`] call anywhere in the process).
pub const ENV_VAR: &str = "MBKK_FAILPOINTS";

/// What an armed failpoint does when it acts. `delay` is handled inside
/// [`eval`] (it sleeps, then the site proceeds), so callers only ever see
/// the two fallible variants.
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    /// The site must panic (the caller's unwind path is under test).
    Panic,
    /// The site must fail with this message through its error path.
    Err(String),
}

#[derive(Clone, Debug, PartialEq)]
enum Action {
    Panic,
    Err(String),
    Delay(u64),
}

struct Entry {
    name: String,
    action: Action,
    /// Evaluations to let pass before acting (`after(N):`).
    skip: u64,
    /// Maximum number of times to act (`K*`; `u64::MAX` = unlimited).
    limit: u64,
    /// Total evaluations so far.
    hits: u64,
    /// Times the action actually ran.
    fired: u64,
}

static ARMED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();
static REGISTRY: Mutex<Vec<Entry>> = Mutex::new(Vec::new());

fn registry() -> std::sync::MutexGuard<'static, Vec<Entry>> {
    // A panicking failpoint can poison the registry mutex by design;
    // the registry itself is never left mid-mutation, so recover.
    REGISTRY.lock().unwrap_or_else(|p| p.into_inner())
}

/// Fast check: is any failpoint configured in this process? Instrumented
/// sites gate every [`eval`]/[`fire`] behind this so the disabled hot path
/// costs one relaxed atomic load.
#[inline]
pub fn armed() -> bool {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var(ENV_VAR) {
            if !spec.trim().is_empty() {
                if let Err(e) = configure(&spec) {
                    // A typo'd spec must not silently disable chaos tests.
                    eprintln!("mbkk: invalid {ENV_VAR} spec: {e}");
                }
            }
        }
    });
    ARMED.load(Ordering::Relaxed)
}

/// Evaluate the named failpoint. Returns `None` when the point is not
/// configured, still skipping, exhausted, or was a `delay` (the sleep
/// happens inline here). Callers match on the returned [`Fault`]; most use
/// [`fire`] instead.
pub fn eval(name: &str) -> Option<Fault> {
    if !armed() {
        return None;
    }
    let action = {
        let mut reg = registry();
        let e = reg.iter_mut().find(|e| e.name == name)?;
        let hit = e.hits;
        e.hits += 1;
        if hit < e.skip || e.fired >= e.limit {
            return None;
        }
        e.fired += 1;
        e.action.clone()
    };
    match action {
        Action::Delay(ms) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            None
        }
        Action::Panic => Some(Fault::Panic),
        Action::Err(msg) => Some(Fault::Err(msg)),
    }
}

/// Evaluate the named failpoint in a `Result` context: `panic` panics
/// here, `err` returns the injected error, anything else is `Ok(())`.
pub fn fire(name: &str) -> Result<()> {
    match eval(name) {
        None => Ok(()),
        Some(Fault::Err(msg)) => Err(format_err!("failpoint {name}: {msg}")),
        Some(Fault::Panic) => panic!("failpoint {name}: injected panic"),
    }
}

/// Parse and install a failpoint spec (see the module docs for the
/// grammar), arming the process. Points already configured under the same
/// name are replaced with fresh counters. Test code calls this directly;
/// the `MBKK_FAILPOINTS` environment variable routes here on first use.
pub fn configure(spec: &str) -> Result<()> {
    let mut parsed = Vec::new();
    for part in spec.split([';', ',']) {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, action_spec) = part
            .split_once('=')
            .ok_or_else(|| format_err!("failpoint spec {part:?} is not name=action"))?;
        let name = name.trim();
        if name.is_empty() {
            bail!("failpoint spec {part:?} has an empty name");
        }
        let (skip, limit, action) = parse_action(action_spec.trim())?;
        parsed.push(Entry { name: name.to_string(), action, skip, limit, hits: 0, fired: 0 });
    }
    let mut reg = registry();
    for entry in parsed {
        reg.retain(|e| e.name != entry.name);
        reg.push(entry);
    }
    drop(reg);
    ARMED.store(true, Ordering::Relaxed);
    Ok(())
}

/// `[after(N):][K*]action` → (skip, limit, action).
fn parse_action(mut s: &str) -> Result<(u64, u64, Action)> {
    let mut skip = 0u64;
    if let Some(rest) = s.strip_prefix("after(") {
        let (n, rest) = rest
            .split_once("):")
            .ok_or_else(|| format_err!("failpoint action {s:?}: after(N) needs \"):\""))?;
        skip = n
            .trim()
            .parse()
            .map_err(|_| format_err!("failpoint action {s:?}: bad after() count {n:?}"))?;
        s = rest;
    }
    let mut limit = u64::MAX;
    if let Some((count, rest)) = s.split_once('*') {
        limit = count
            .trim()
            .parse()
            .map_err(|_| format_err!("failpoint action {s:?}: bad count {count:?}"))?;
        s = rest;
    }
    let action = match s {
        "panic" => Action::Panic,
        "err" => Action::Err("injected error".to_string()),
        _ => {
            if let Some(msg) = s.strip_prefix("err(").and_then(|r| r.strip_suffix(')')) {
                Action::Err(msg.to_string())
            } else if let Some(ms) = s.strip_prefix("delay(").and_then(|r| r.strip_suffix(')')) {
                Action::Delay(ms.trim().parse().map_err(|_| {
                    format_err!("failpoint action {s:?}: bad delay milliseconds {ms:?}")
                })?)
            } else {
                bail!(
                    "unknown failpoint action {s:?} \
                     (known: panic, err, err(msg), delay(ms), with optional \
                     after(N): and K* prefixes)"
                );
            }
        }
    };
    Ok((skip, limit, action))
}

/// Remove one configured failpoint (tests pair [`configure`] with this so
/// parallel tests never see each other's points — names are per-test).
pub fn clear(name: &str) {
    registry().retain(|e| e.name != name);
}

/// Remove every configured failpoint and disarm the fast check. Intended
/// for process-level harnesses, not parallel unit tests (it would yank
/// points out from under a concurrently running test).
pub fn reset() {
    registry().clear();
    ARMED.store(false, Ordering::Relaxed);
}

/// How many times the named failpoint's action has run — lets tests assert
/// an injection actually happened rather than silently not firing.
pub fn fired_count(name: &str) -> u64 {
    registry().iter().find(|e| e.name == name).map_or(0, |e| e.fired)
}

/// Tests that arm *shared* failpoint names (the `artifact.write.*` /
/// `checkpoint.*` points evaluated by library code) serialize through
/// this mutex so cargo's parallel test threads don't consume each
/// other's injections. Tests arming names unique to themselves don't
/// need it.
#[doc(hidden)]
pub fn exclusive_test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Every test uses unique failpoint names and clears them on exit:
    // the registry is process-global and cargo runs tests in parallel.

    #[test]
    fn unconfigured_points_are_inert() {
        assert_eq!(eval("fp.test.never-configured"), None);
        assert!(fire("fp.test.never-configured").is_ok());
    }

    #[test]
    fn err_action_fires_through_the_error_path() {
        configure("fp.test.err=err(disk on fire)").unwrap();
        let e = fire("fp.test.err").unwrap_err();
        assert!(format!("{e}").contains("disk on fire"), "{e}");
        assert_eq!(fired_count("fp.test.err"), 1);
        clear("fp.test.err");
    }

    #[test]
    fn count_limit_exhausts() {
        configure("fp.test.limit=2*err").unwrap();
        assert!(fire("fp.test.limit").is_err());
        assert!(fire("fp.test.limit").is_err());
        assert!(fire("fp.test.limit").is_ok(), "third evaluation must pass");
        assert_eq!(fired_count("fp.test.limit"), 2);
        clear("fp.test.limit");
    }

    #[test]
    fn after_skips_then_fires() {
        configure("fp.test.after=after(3):err").unwrap();
        for i in 0..3 {
            assert!(fire("fp.test.after").is_ok(), "evaluation {i} must pass");
        }
        assert!(fire("fp.test.after").is_err());
        clear("fp.test.after");
    }

    #[test]
    fn after_and_limit_compose() {
        configure("fp.test.compose=after(1):1*err").unwrap();
        assert!(fire("fp.test.compose").is_ok());
        assert!(fire("fp.test.compose").is_err());
        assert!(fire("fp.test.compose").is_ok());
        clear("fp.test.compose");
    }

    #[test]
    fn panic_action_panics_at_the_site() {
        configure("fp.test.panic=1*panic").unwrap();
        let caught = std::panic::catch_unwind(|| fire("fp.test.panic"));
        assert!(caught.is_err(), "panic action must unwind");
        assert!(fire("fp.test.panic").is_ok(), "one-shot panic must exhaust");
        clear("fp.test.panic");
    }

    #[test]
    fn delay_sleeps_then_passes() {
        configure("fp.test.delay=delay(30)").unwrap();
        let t = std::time::Instant::now();
        assert!(fire("fp.test.delay").is_ok());
        assert!(t.elapsed() >= std::time::Duration::from_millis(25));
        clear("fp.test.delay");
    }

    #[test]
    fn reconfigure_replaces_counters() {
        configure("fp.test.replace=1*err").unwrap();
        assert!(fire("fp.test.replace").is_err());
        configure("fp.test.replace=1*err").unwrap();
        assert!(fire("fp.test.replace").is_err(), "fresh counters after reconfigure");
        clear("fp.test.replace");
    }

    #[test]
    fn multi_point_specs_and_separators() {
        configure("fp.test.m1=err; fp.test.m2=delay(0),fp.test.m3=err(x)").unwrap();
        assert!(fire("fp.test.m1").is_err());
        assert!(fire("fp.test.m2").is_ok());
        assert!(fire("fp.test.m3").is_err());
        for n in ["fp.test.m1", "fp.test.m2", "fp.test.m3"] {
            clear(n);
        }
    }

    #[test]
    fn bad_specs_are_rejected() {
        for bad in [
            "noequals",
            "=err",
            "x=explode",
            "x=delay(soon)",
            "x=after(2)panic",
            "x=many*err",
        ] {
            assert!(configure(bad).is_err(), "{bad:?} must be rejected");
        }
        // Rejected specs must not leave partial state behind.
        assert_eq!(eval("x"), None);
        assert_eq!(eval("noequals"), None);
    }
}
