//! General-purpose substrates the coordinator depends on.
//!
//! This build runs fully offline with only the `xla` crate vendored, so the
//! usual ecosystem crates (rand, serde, clap, rayon) are re-implemented here
//! at exactly the scope this project needs. Each module carries its own unit
//! tests.

pub mod cli;
pub mod crc32;
pub mod error;
pub mod failpoint;
pub mod fmath;
pub mod json;
pub mod parallel;
pub mod pool;
pub mod rng;
pub mod simd;
pub mod timing;
