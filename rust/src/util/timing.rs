//! Wall-clock timing helpers and a lightweight phase profiler.
//!
//! The experiment coordinator reports per-phase timings (kernel/gram
//! construction, initialization, iterations) exactly like the paper's plots
//! split "kernel time" (black bars) from clustering time.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Stopwatch returning elapsed seconds.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed time since [`Stopwatch::start`].
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds since [`Stopwatch::start`].
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::start();
    let out = f();
    (out, sw.secs())
}

/// Accumulating named-phase profiler.
///
/// ```no_run
/// // (no_run: doctest binaries lack the xla_extension rpath on this image)
/// use mbkk::util::timing::Profiler;
/// let mut prof = Profiler::new();
/// prof.scope("assign", || { /* work */ });
/// prof.add("update", 0.5e-3);
/// assert!(prof.total() > 0.0);
/// ```
#[derive(Debug, Default, Clone)]
pub struct Profiler {
    phases: BTreeMap<String, (f64, u64)>, // name -> (total secs, count)
}

impl Profiler {
    /// Empty profiler.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `secs` against the named phase.
    pub fn add(&mut self, phase: &str, secs: f64) {
        let e = self.phases.entry(phase.to_string()).or_insert((0.0, 0));
        e.0 += secs;
        e.1 += 1;
    }

    /// Time a closure under a phase name.
    pub fn scope<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let (out, secs) = timed(f);
        self.add(phase, secs);
        out
    }

    /// Total seconds attributed to a phase.
    pub fn phase_secs(&self, phase: &str) -> f64 {
        self.phases.get(phase).map(|(s, _)| *s).unwrap_or(0.0)
    }

    /// Number of recordings against a phase.
    pub fn phase_count(&self, phase: &str) -> u64 {
        self.phases.get(phase).map(|(_, c)| *c).unwrap_or(0)
    }

    /// Sum over all phases.
    pub fn total(&self) -> f64 {
        self.phases.values().map(|(s, _)| s).sum()
    }

    /// Merge another profiler's counters into this one.
    pub fn merge(&mut self, other: &Profiler) {
        for (k, (s, c)) in &other.phases {
            let e = self.phases.entry(k.clone()).or_insert((0.0, 0));
            e.0 += s;
            e.1 += c;
        }
    }

    /// Render a fixed-width summary table.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<24} {:>12} {:>10} {:>12}\n",
            "phase", "total (s)", "calls", "mean (ms)"
        ));
        for (name, (secs, count)) in &self.phases {
            let mean_ms = if *count > 0 { secs / *count as f64 * 1e3 } else { 0.0 };
            out.push_str(&format!(
                "{:<24} {:>12.4} {:>10} {:>12.4}\n",
                name, secs, count, mean_ms
            ));
        }
        out
    }

    /// Iterate phases as (name, total_secs, count).
    pub fn iter(&self) -> impl Iterator<Item = (&str, f64, u64)> {
        self.phases.iter().map(|(k, (s, c))| (k.as_str(), *s, *c))
    }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_secs(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.secs() >= 0.002);
    }

    #[test]
    fn profiler_accumulates() {
        let mut p = Profiler::new();
        p.add("a", 1.0);
        p.add("a", 2.0);
        p.add("b", 0.5);
        assert_eq!(p.phase_secs("a"), 3.0);
        assert_eq!(p.phase_count("a"), 2);
        assert_eq!(p.total(), 3.5);
        let report = p.report();
        assert!(report.contains('a') && report.contains('b'));
    }

    #[test]
    fn profiler_merge() {
        let mut p = Profiler::new();
        p.add("x", 1.0);
        let mut q = Profiler::new();
        q.add("x", 2.0);
        q.add("y", 3.0);
        p.merge(&q);
        assert_eq!(p.phase_secs("x"), 3.0);
        assert_eq!(p.phase_secs("y"), 3.0);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-10).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
