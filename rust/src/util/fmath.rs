//! Scalar floating-point primitives that pin the panel engine's
//! per-value reduction order.
//!
//! Every kernel value in the crate — scalar [`crate::kernels::KernelFunction::eval`],
//! panel tile fills, the materialized table, the streaming tile cache — is
//! computed from f32 features through **exactly** the arithmetic defined
//! here: each inner product is a single sequential f64 chain over the
//! feature dimension. The panel micro-kernels gain their speed from
//! instruction-level parallelism *across* output values (32 independent
//! chains in flight), never from re-associating *within* one value, so a
//! value computed by any tile shape, any thread count, or the scalar
//! fallback is bit-for-bit the same f64 — the invariant the
//! streaming-vs-materialized equivalence suite pins.

/// `Σ_t a[t]·b[t]` with each f32 widened to f64 and accumulated in one
/// sequential f64 chain — the reduction order every panel path replays.
#[inline]
pub fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for (x, y) in a.iter().zip(b.iter()) {
        s += (*x as f64) * (*y as f64);
    }
    s
}

/// `‖a‖²` via [`dot_f64`] — the cached per-row squared norm.
#[inline]
pub fn sq_norm_f64(a: &[f32]) -> f64 {
    dot_f64(a, a)
}

/// Squared Euclidean distance from cached squared norms and an inner
/// product: `(‖a‖² + ‖b‖²) − 2⟨a,b⟩`, clamped at 0 against cancellation
/// (the norms expansion can go a few ulp negative where the difference
/// form cannot). The association `(na + nb) − 2·dot` is part of the
/// bit-identity contract — do not re-order.
///
/// Edge-case semantics (pinned by the unit tests, relied on by the SIMD
/// arms in [`crate::util::simd`] which must reproduce them):
///
/// * Any NaN input yields **0.0**, not NaN: `f64::max` returns the
///   non-NaN operand, so the clamp swallows the NaN. Poisoned inputs
///   therefore degrade to "coincident points" rather than panicking or
///   propagating.
/// * `+inf` norms likewise collapse: `inf − inf = NaN`, which the clamp
///   maps to 0.0.
/// * Negative-zero norms behave as zero; the result compares `== 0.0` but
///   its zero **sign is unspecified** (LLVM's `maxnum` leaves the sign of
///   `max(-0.0, 0.0)` open) — assert `== 0.0`, never the sign bit.
/// * `d = 0` feature vectors give `dot = 0`, norms `0`, distance `0` —
///   never a panic.
#[inline]
pub fn sqdist_from_norms(na: f64, nb: f64, dot: f64) -> f64 {
    ((na + nb) - 2.0 * dot).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, -5.0, 6.0];
        assert_eq!(dot_f64(&a, &b), 4.0 - 10.0 + 18.0);
        assert_eq!(dot_f64(&[], &[]), 0.0);
        assert_eq!(sq_norm_f64(&[3.0, 4.0]), 25.0);
    }

    #[test]
    fn sqdist_exact_small_integers() {
        // (0,0) vs (3,4): norms 0 and 25, dot 0 → 25.
        assert_eq!(sqdist_from_norms(0.0, 25.0, 0.0), 25.0);
        // Identical points: (n + n) − 2n is exactly 0 in IEEE arithmetic.
        let n = sq_norm_f64(&[1.5, -2.25, 8.0]);
        assert_eq!(sqdist_from_norms(n, n, n), 0.0);
    }

    #[test]
    fn sqdist_clamps_cancellation() {
        // Force a tiny negative: na + nb slightly below 2·dot.
        let v = sqdist_from_norms(1.0, 1.0, 1.0 + 1e-15);
        assert_eq!(v, 0.0);
    }

    #[test]
    fn sqdist_nan_inputs_clamp_to_zero_not_panic() {
        // Document-and-pin: NaN anywhere yields 0.0 (the clamp's max
        // returns its non-NaN operand), never NaN and never a panic.
        assert_eq!(sqdist_from_norms(f64::NAN, 1.0, 0.5), 0.0);
        assert_eq!(sqdist_from_norms(1.0, f64::NAN, 0.5), 0.0);
        assert_eq!(sqdist_from_norms(1.0, 2.0, f64::NAN), 0.0);
        assert_eq!(sqdist_from_norms(f64::NAN, f64::NAN, f64::NAN), 0.0);
    }

    #[test]
    fn sqdist_infinite_inputs_never_yield_nan() {
        // inf − inf cancels to NaN inside the expression; the clamp pins
        // the result to 0.0. A one-sided inf survives as +inf.
        assert_eq!(sqdist_from_norms(f64::INFINITY, 1.0, f64::INFINITY), 0.0);
        assert_eq!(sqdist_from_norms(f64::INFINITY, f64::INFINITY, f64::INFINITY), 0.0);
        assert_eq!(sqdist_from_norms(f64::INFINITY, 1.0, 0.0), f64::INFINITY);
        assert_eq!(sqdist_from_norms(1.0, 1.0, f64::NEG_INFINITY), f64::INFINITY);
    }

    #[test]
    fn sqdist_negative_zero_norms_behave_as_zero() {
        // Compare with ==, not to_bits: the sign of a zero result from
        // max(-0.0, 0.0) is implementation-defined (LLVM maxnum), and we
        // deliberately pin only the value.
        assert_eq!(sqdist_from_norms(-0.0, -0.0, -0.0), 0.0);
        assert_eq!(sqdist_from_norms(-0.0, 0.0, 0.0), 0.0);
        assert_eq!(sqdist_from_norms(-0.0, 25.0, 0.0), 25.0);
    }

    #[test]
    fn zero_dimension_inputs_are_zero_not_panic() {
        // d = 0 rows: the whole chain degrades to zeros.
        let empty: [f32; 0] = [];
        assert_eq!(dot_f64(&empty, &empty), 0.0);
        assert_eq!(sq_norm_f64(&empty), 0.0);
        assert_eq!(sqdist_from_norms(0.0, 0.0, 0.0), 0.0);
    }

    #[test]
    fn dot_propagates_nonfinite_f32_inputs() {
        // dot_f64 itself has no clamp: NaN/inf features propagate into
        // the accumulator (and are then swallowed by sqdist's clamp
        // downstream). Pin that division of responsibility.
        assert!(dot_f64(&[f32::NAN], &[1.0]).is_nan());
        assert_eq!(dot_f64(&[f32::INFINITY], &[1.0]), f64::INFINITY);
        assert!(dot_f64(&[f32::INFINITY], &[0.0]).is_nan());
    }

    #[test]
    fn sqdist_is_commutative_in_norms() {
        let (na, nb, d) = (7.25, 0.125, 0.5);
        assert_eq!(
            sqdist_from_norms(na, nb, d).to_bits(),
            sqdist_from_norms(nb, na, d).to_bits()
        );
    }
}
