//! Scalar floating-point primitives that pin the panel engine's
//! per-value reduction order.
//!
//! Every kernel value in the crate — scalar [`crate::kernels::KernelFunction::eval`],
//! panel tile fills, the materialized table, the streaming tile cache — is
//! computed from f32 features through **exactly** the arithmetic defined
//! here: each inner product is a single sequential f64 chain over the
//! feature dimension. The panel micro-kernels gain their speed from
//! instruction-level parallelism *across* output values (32 independent
//! chains in flight), never from re-associating *within* one value, so a
//! value computed by any tile shape, any thread count, or the scalar
//! fallback is bit-for-bit the same f64 — the invariant the
//! streaming-vs-materialized equivalence suite pins.

/// `Σ_t a[t]·b[t]` with each f32 widened to f64 and accumulated in one
/// sequential f64 chain — the reduction order every panel path replays.
#[inline]
pub fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for (x, y) in a.iter().zip(b.iter()) {
        s += (*x as f64) * (*y as f64);
    }
    s
}

/// `‖a‖²` via [`dot_f64`] — the cached per-row squared norm.
#[inline]
pub fn sq_norm_f64(a: &[f32]) -> f64 {
    dot_f64(a, a)
}

/// Squared Euclidean distance from cached squared norms and an inner
/// product: `(‖a‖² + ‖b‖²) − 2⟨a,b⟩`, clamped at 0 against cancellation
/// (the norms expansion can go a few ulp negative where the difference
/// form cannot). The association `(na + nb) − 2·dot` is part of the
/// bit-identity contract — do not re-order.
#[inline]
pub fn sqdist_from_norms(na: f64, nb: f64, dot: f64) -> f64 {
    ((na + nb) - 2.0 * dot).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, -5.0, 6.0];
        assert_eq!(dot_f64(&a, &b), 4.0 - 10.0 + 18.0);
        assert_eq!(dot_f64(&[], &[]), 0.0);
        assert_eq!(sq_norm_f64(&[3.0, 4.0]), 25.0);
    }

    #[test]
    fn sqdist_exact_small_integers() {
        // (0,0) vs (3,4): norms 0 and 25, dot 0 → 25.
        assert_eq!(sqdist_from_norms(0.0, 25.0, 0.0), 25.0);
        // Identical points: (n + n) − 2n is exactly 0 in IEEE arithmetic.
        let n = sq_norm_f64(&[1.5, -2.25, 8.0]);
        assert_eq!(sqdist_from_norms(n, n, n), 0.0);
    }

    #[test]
    fn sqdist_clamps_cancellation() {
        // Force a tiny negative: na + nb slightly below 2·dot.
        let v = sqdist_from_norms(1.0, 1.0, 1.0 + 1e-15);
        assert_eq!(v, 0.0);
    }

    #[test]
    fn sqdist_is_commutative_in_norms() {
        let (na, nb, d) = (7.25, 0.125, 0.5);
        assert_eq!(
            sqdist_from_norms(na, nb, d).to_bits(),
            sqdist_from_norms(nb, na, d).to_bits()
        );
    }
}
