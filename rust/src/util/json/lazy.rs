//! Lazy, offset-based JSON field extraction (ADR-003).
//!
//! The HTTP request path must pull one large field — `points`, a
//! `[[f32; d]; rows]` array that dominates the body — out of a small
//! envelope object without paying for a full [`Json`](super::Json) tree
//! (per-element `Json::Num` allocations plus a `BTreeMap` per row would
//! multiply the body size several times over; mik-sdk's ADR-002 measured
//! the same partial-extraction pattern at ~33× for sparse reads).
//!
//! This module scans the byte buffer once, records the *offsets* of the
//! requested top-level fields, and hands each back as a [`RawValue`]
//! borrowing the original buffer. Small fields can then be bridged into
//! the eager parser ([`RawValue::parse_full`]); the hot `points` field has
//! a dedicated flat decoder ([`RawValue::parse_points`]) that parses each
//! number token **directly with `str::parse::<f32>`** — the same
//! single-rounding conversion the CSV loader uses — so a feature value
//! travels `text → f32` identically over HTTP and over `--csv`, keeping
//! the served predictions bit-identical to the CLI path. (Parsing into
//! `f64` first and casting would round twice and break that contract.)
//!
//! Skipped fields are validated *structurally* (balanced brackets, sound
//! string framing) but not lexically; only fields a caller actually
//! extracts get full validation. Errors carry byte offsets and never
//! panic on any input.

use super::{Json, JsonError};

/// An unparsed JSON value: a slice of the original buffer plus its offset.
///
/// Produced by [`fields`]; decode with [`parse_full`](RawValue::parse_full)
/// or [`parse_points`](RawValue::parse_points).
#[derive(Clone, Copy, Debug)]
pub struct RawValue<'a> {
    /// The value's bytes, trimmed of surrounding whitespace.
    pub bytes: &'a [u8],
    /// Byte offset of `bytes[0]` within the scanned buffer (for diagnostics).
    pub offset: usize,
}

/// A flat, rectangular batch of points decoded from a `[[num; d]; rows]`
/// JSON array (row-major, matching [`crate::data::Dataset`] layout).
#[derive(Clone, Debug, PartialEq)]
pub struct Points {
    /// Number of rows (points).
    pub rows: usize,
    /// Dimensionality shared by every row. 0 when `rows == 0`.
    pub d: usize,
    /// `rows * d` features, row-major.
    pub features: Vec<f32>,
}

/// Scan a top-level JSON object once and return the raw value of each
/// requested field, aligned with `keys` (`None` where the field is absent).
///
/// Only the requested fields are decoded later; everything else is
/// structurally skipped in place. Duplicate keys resolve to the last
/// occurrence, matching the eager parser's `BTreeMap` insert semantics.
pub fn fields<'a>(buf: &'a [u8], keys: &[&str]) -> Result<Vec<Option<RawValue<'a>>>, JsonError> {
    let mut s = Scanner { bytes: buf, pos: 0 };
    let mut out: Vec<Option<RawValue<'a>>> = vec![None; keys.len()];
    s.skip_ws();
    s.expect(b'{', "expected a JSON object")?;
    s.skip_ws();
    if s.peek() == Some(b'}') {
        s.pos += 1;
    } else {
        loop {
            s.skip_ws();
            let key = s.key()?;
            s.skip_ws();
            s.expect(b':', "expected ':' after object key")?;
            s.skip_ws();
            let start = s.pos;
            s.skip_value()?;
            let end = s.pos;
            if let Some(slot) = keys.iter().position(|k| key.matches(k.as_bytes())) {
                out[slot] = Some(RawValue { bytes: &buf[start..end], offset: start });
            }
            s.skip_ws();
            match s.bump() {
                Some(b',') => continue,
                Some(b'}') => break,
                _ => {
                    s.rewind();
                    return Err(s.err("expected ',' or '}' in object"));
                }
            }
        }
    }
    s.skip_ws();
    if s.pos != s.bytes.len() {
        return Err(s.err("trailing characters after JSON object"));
    }
    Ok(out)
}

impl RawValue<'_> {
    /// Bridge into the eager parser for small fields (options, names, …).
    ///
    /// Error offsets are rebased onto the original buffer.
    pub fn parse_full(&self) -> Result<Json, JsonError> {
        let text = std::str::from_utf8(self.bytes).map_err(|_| JsonError {
            msg: "invalid utf-8 in value".to_string(),
            offset: self.offset,
        })?;
        Json::parse(text).map_err(|e| JsonError { msg: e.msg, offset: self.offset + e.offset })
    }

    /// Decode a `[[num; d]; rows]` array into a flat row-major `Vec<f32>`.
    ///
    /// Enforces rectangularity (every row must have the first row's
    /// length) and rejects non-numeric elements. Each number token is
    /// converted with `str::parse::<f32>` — single rounding, identical to
    /// the CSV loader — so HTTP-submitted features match file-submitted
    /// features bit for bit. An empty outer array decodes to
    /// `rows == 0, d == 0`.
    pub fn parse_points(&self) -> Result<Points, JsonError> {
        let mut s = Scanner { bytes: self.bytes, pos: 0 };
        let base = self.offset;
        let rebase = |mut e: JsonError| {
            e.offset += base;
            e
        };
        s.skip_ws();
        s.expect(b'[', "\"points\" must be an array of rows").map_err(rebase)?;
        // ~6 bytes/number ("-0.25,") is a conservative pre-size guess.
        let mut features: Vec<f32> = Vec::with_capacity(self.bytes.len() / 6);
        let mut rows = 0usize;
        let mut d = 0usize;
        s.skip_ws();
        if s.peek() == Some(b']') {
            s.pos += 1;
        } else {
            loop {
                s.skip_ws();
                s.expect(b'[', "each row in \"points\" must be an array of numbers")
                    .map_err(rebase)?;
                let row_start = features.len();
                s.skip_ws();
                if s.peek() == Some(b']') {
                    s.pos += 1;
                } else {
                    loop {
                        s.skip_ws();
                        features.push(s.number_f32().map_err(rebase)?);
                        s.skip_ws();
                        match s.bump() {
                            Some(b',') => continue,
                            Some(b']') => break,
                            _ => {
                                s.rewind();
                                return Err(rebase(s.err("expected ',' or ']' in row")));
                            }
                        }
                    }
                }
                let row_len = features.len() - row_start;
                if rows == 0 {
                    d = row_len;
                } else if row_len != d {
                    return Err(rebase(s.err(&format!(
                        "ragged \"points\": row {rows} has {row_len} features, row 0 has {d}"
                    ))));
                }
                rows += 1;
                s.skip_ws();
                match s.bump() {
                    Some(b',') => continue,
                    Some(b']') => break,
                    _ => {
                        s.rewind();
                        return Err(rebase(s.err("expected ',' or ']' in \"points\"")));
                    }
                }
            }
        }
        s.skip_ws();
        if s.pos != s.bytes.len() {
            return Err(rebase(s.err("trailing characters after \"points\" array")));
        }
        Ok(Points { rows, d, features })
    }
}

/// An object key as it appears on the wire: raw bytes, possibly escaped.
struct RawKey<'a> {
    /// Key bytes *between* the quotes, escapes unresolved.
    raw: &'a [u8],
}

impl RawKey<'_> {
    /// Compare against a literal key. The fast path is a byte compare; keys
    /// containing escapes take the slow path through the eager string
    /// decoder so `"points"` still matches `points`.
    fn matches(&self, want: &[u8]) -> bool {
        if !self.raw.contains(&b'\\') {
            return self.raw == want;
        }
        let mut quoted = Vec::with_capacity(self.raw.len() + 2);
        quoted.push(b'"');
        quoted.extend_from_slice(self.raw);
        quoted.push(b'"');
        match std::str::from_utf8(&quoted).ok().and_then(|t| Json::parse(t).ok()) {
            Some(Json::Str(s)) => s.as_bytes() == want,
            _ => false,
        }
    }
}

/// A structural scanner: positions and skips, no tree construction.
/// Iterative throughout — arbitrarily nested input cannot overflow the
/// stack, and no code path panics.
struct Scanner<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Scanner<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    /// Undo the last `bump` so an error reports the offending byte.
    fn rewind(&mut self) {
        self.pos = self.pos.saturating_sub(1);
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8, msg: &str) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(msg))
        }
    }

    /// Read an object key, returning its raw (still-escaped) bytes.
    fn key(&mut self) -> Result<RawKey<'a>, JsonError> {
        self.expect(b'"', "expected '\"' starting object key")?;
        let start = self.pos;
        self.skip_string_tail()?;
        Ok(RawKey { raw: &self.bytes[start..self.pos - 1] })
    }

    /// Skip the remainder of a string whose opening quote was consumed.
    fn skip_string_tail(&mut self) -> Result<(), JsonError> {
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(()),
                Some(b'\\') => {
                    if self.bump().is_none() {
                        return Err(self.err("unterminated escape"));
                    }
                }
                Some(_) => {}
            }
        }
    }

    /// Structurally skip one JSON value of any kind without building it.
    /// Containers are tracked with a depth counter, not recursion.
    fn skip_value(&mut self) -> Result<(), JsonError> {
        let mut depth = 0usize;
        loop {
            self.skip_ws();
            match self.peek() {
                None => return Err(self.err("unexpected end of input")),
                Some(b'[' | b'{') => {
                    depth += 1;
                    self.pos += 1;
                }
                Some(b']' | b'}') => {
                    if depth == 0 {
                        return Err(self.err("unexpected closing bracket"));
                    }
                    depth -= 1;
                    self.pos += 1;
                    if depth == 0 {
                        return Ok(());
                    }
                }
                Some(b'"') => {
                    self.pos += 1;
                    self.skip_string_tail()?;
                    if depth == 0 {
                        return Ok(());
                    }
                }
                Some(b',' | b':') => {
                    if depth == 0 {
                        return Err(self.err("unexpected separator"));
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    self.skip_scalar()?;
                    if depth == 0 {
                        return Ok(());
                    }
                }
            }
        }
    }

    /// Skip a scalar token (number / literal) up to the next delimiter.
    fn skip_scalar(&mut self) -> Result<(), JsonError> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            match b {
                b',' | b']' | b'}' | b':' | b' ' | b'\t' | b'\n' | b'\r' | b'"' | b'['
                | b'{' => break,
                _ => self.pos += 1,
            }
        }
        if self.pos == start {
            Err(self.err("expected a value"))
        } else {
            Ok(())
        }
    }

    /// Parse one number token directly into f32 (single rounding; the
    /// CSV-parity conversion). Rejects tokens that do not start like a
    /// JSON number so `inf` / `nan` / `+1` never sneak in through Rust's
    /// more liberal float grammar.
    fn number_f32(&mut self) -> Result<f32, JsonError> {
        let start = self.pos;
        match self.peek() {
            Some(b'-') => {
                self.pos += 1;
                if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.pos = start;
                    return Err(self.err("expected a number"));
                }
            }
            Some(c) if c.is_ascii_digit() => {}
            _ => return Err(self.err("expected a number")),
        }
        while let Some(b) = self.peek() {
            match b {
                b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-' => self.pos += 1,
                _ => break,
            }
        }
        // The token is ASCII by construction of the loop above.
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number encoding"))?;
        text.parse::<f32>().map_err(|_| {
            let mut e = self.err(&format!("bad number '{text}'"));
            e.offset = start;
            e
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extracts_requested_fields_only() {
        let body = br#"{"model": "blobs", "points": [[1.0, 2.0]], "opts": {"x": [1, {"y": 2}]}}"#;
        let got = fields(body, &["points", "model", "absent"]).unwrap();
        assert_eq!(got[0].unwrap().bytes, b"[[1.0, 2.0]]");
        assert_eq!(got[1].unwrap().bytes, b"\"blobs\"");
        assert!(got[2].is_none());
    }

    #[test]
    fn parse_points_matches_csv_parse() {
        let body = br#"{"points": [[0.1, -2.5e-3, 3], [4.25, 1e9, -0]]}"#;
        let raw = fields(body, &["points"]).unwrap()[0].unwrap();
        let pts = raw.parse_points().unwrap();
        assert_eq!((pts.rows, pts.d), (2, 3));
        // Exact parity with the CSV loader's `token.parse::<f32>()`.
        let want: Vec<f32> = ["0.1", "-2.5e-3", "3", "4.25", "1e9", "-0"]
            .iter()
            .map(|t| t.parse::<f32>().unwrap())
            .collect();
        assert_eq!(
            pts.features.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn lazy_agrees_with_full_tree_on_values() {
        let body = br#"{"points": [[1.5, 2], [3, 4.125]], "tag": "t"}"#;
        let raw = fields(body, &["points"]).unwrap()[0].unwrap();
        let pts = raw.parse_points().unwrap();
        let tree = Json::parse(std::str::from_utf8(body).unwrap()).unwrap();
        let rows = tree.get("points").as_arr().unwrap();
        let flat: Vec<f32> = rows
            .iter()
            .flat_map(|r| r.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32))
            .collect();
        // These literals are exactly representable, so the double-rounded
        // tree path agrees; parse_points is the one that stays exact in
        // general.
        assert_eq!(pts.features, flat);
    }

    #[test]
    fn empty_and_ragged_points() {
        let empty = fields(br#"{"points": []}"#, &["points"]).unwrap()[0].unwrap();
        let pts = empty.parse_points().unwrap();
        assert_eq!((pts.rows, pts.d, pts.features.len()), (0, 0, 0));

        let ragged = fields(br#"{"points": [[1, 2], [3]]}"#, &["points"]).unwrap()[0].unwrap();
        let err = ragged.parse_points().unwrap_err();
        assert!(err.msg.contains("ragged"), "{}", err.msg);
    }

    #[test]
    fn rejects_non_numbers_in_points() {
        for bad in [
            r#"{"points": [["a"]]}"#,
            r#"{"points": [[nan]]}"#,
            r#"{"points": [[+1]]}"#,
            r#"{"points": [[1, ]]}"#,
            r#"{"points": 3}"#,
            r#"{"points": [3]}"#,
        ] {
            let raw = fields(bad.as_bytes(), &["points"]).unwrap()[0].unwrap();
            assert!(raw.parse_points().is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn malformed_envelopes_error_never_panic() {
        for bad in [
            &b""[..],
            b"[1, 2]",
            b"{",
            b"{\"a\"",
            b"{\"a\": }",
            b"{\"a\": 1,}",
            b"{\"a\": \"unterminated",
            b"{\"a\": 1} trailing",
            b"{\"a\": [1, {2}",
            b"not json at all",
        ] {
            assert!(fields(bad, &["a"]).is_err());
        }
    }

    #[test]
    fn deep_nesting_does_not_recurse() {
        // 100k nested arrays in a skipped field: iterative skip handles it.
        let mut body = Vec::from(&b"{\"deep\": "[..]);
        body.extend_from_slice(&vec![b'['; 100_000]);
        body.extend_from_slice(&vec![b']'; 100_000]);
        body.extend_from_slice(b", \"x\": 1}");
        let got = fields(&body, &["x"]).unwrap();
        assert_eq!(got[0].unwrap().bytes, b"1");
    }

    #[test]
    fn escaped_keys_still_match() {
        // The wire key "points" unescapes to "points": slow-path compare.
        let body = br#"{"\u0070oints": [[1]]}"#;
        let got = fields(body, &["points"]).unwrap();
        let pts = got[0].unwrap().parse_points().unwrap();
        assert_eq!((pts.rows, pts.d), (1, 1));
    }

    #[test]
    fn duplicate_keys_take_last() {
        let body = br#"{"a": 1, "a": 2}"#;
        let got = fields(body, &["a"]).unwrap();
        assert_eq!(got[0].unwrap().bytes, b"2");
        // Same answer as the eager parser's BTreeMap insert.
        let tree = Json::parse(std::str::from_utf8(body).unwrap()).unwrap();
        assert_eq!(tree.get("a").as_f64(), Some(2.0));
    }

    #[test]
    fn parse_full_rebases_error_offsets() {
        let body = br#"{"pad": 111111, "opts": {"x": nope}}"#;
        let raw = fields(body, &["opts"]).unwrap()[0].unwrap();
        let err = raw.parse_full().unwrap_err();
        // The offset points into the original buffer, inside "opts".
        assert!(err.offset > raw.offset, "offset {} not rebased", err.offset);
    }
}
