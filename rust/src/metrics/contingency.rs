//! Contingency table between two labelings — the shared substrate of ARI
//! and NMI. Stored sparsely (cluster-pair → count) so k_a·k_b never
//! materializes densely.

use std::collections::BTreeMap;

/// Sparse contingency table.
#[derive(Debug, Clone)]
pub struct Contingency {
    /// Total number of points.
    pub n: usize,
    /// Count per (row cluster, col cluster) pair.
    counts: BTreeMap<(usize, usize), usize>,
    /// Cluster sizes of the first labeling.
    pub row_sums: Vec<usize>,
    /// Cluster sizes of the second labeling.
    pub col_sums: Vec<usize>,
}

impl Contingency {
    /// Build the table from two labelings over the same points.
    pub fn new(labels_a: &[usize], labels_b: &[usize]) -> Contingency {
        assert_eq!(
            labels_a.len(),
            labels_b.len(),
            "labelings must cover the same points"
        );
        let ka = labels_a.iter().copied().max().map(|m| m + 1).unwrap_or(0);
        let kb = labels_b.iter().copied().max().map(|m| m + 1).unwrap_or(0);
        let mut counts = BTreeMap::new();
        let mut row_sums = vec![0usize; ka];
        let mut col_sums = vec![0usize; kb];
        for (&a, &b) in labels_a.iter().zip(labels_b.iter()) {
            *counts.entry((a, b)).or_insert(0) += 1;
            row_sums[a] += 1;
            col_sums[b] += 1;
        }
        Contingency { n: labels_a.len(), counts, row_sums, col_sums }
    }

    /// Iterate non-zero cells as (row, col, count).
    pub fn cells(&self) -> impl Iterator<Item = (usize, usize, usize)> + '_ {
        self.counts.iter().map(|(&(i, j), &v)| (i, j, v))
    }

    /// Cell lookup (0 when absent).
    pub fn get(&self, i: usize, j: usize) -> usize {
        self.counts.get(&(i, j)).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_counts_and_margins() {
        let a = [0, 0, 1, 1, 1];
        let b = [0, 1, 1, 1, 0];
        let c = Contingency::new(&a, &b);
        assert_eq!(c.n, 5);
        assert_eq!(c.get(0, 0), 1);
        assert_eq!(c.get(0, 1), 1);
        assert_eq!(c.get(1, 1), 2);
        assert_eq!(c.get(1, 0), 1);
        assert_eq!(c.row_sums, vec![2, 3]);
        assert_eq!(c.col_sums, vec![2, 3]);
        let total: usize = c.cells().map(|(_, _, v)| v).sum();
        assert_eq!(total, 5);
    }

    #[test]
    fn empty_labelings() {
        let c = Contingency::new(&[], &[]);
        assert_eq!(c.n, 0);
        assert_eq!(c.cells().count(), 0);
    }

    #[test]
    #[should_panic(expected = "same points")]
    fn mismatched_lengths_panic() {
        let _ = Contingency::new(&[0, 1], &[0]);
    }
}
