//! Clustering evaluation metrics: Adjusted Rand Index (Rand 1971; Gates &
//! Ahn 2017) and Normalized Mutual Information (Lancichinetti et al. 2009)
//! — the two scores the paper reports — plus the contingency-table
//! machinery they share.

mod contingency;

pub use contingency::Contingency;

/// Adjusted Rand Index between two labelings.
///
/// `ARI = (RI − E[RI]) / (max RI − E[RI])`, computed from the contingency
/// table with pair counts. 1.0 = identical partitions (up to relabeling),
/// ~0 = independent, negative = worse than chance.
pub fn ari(labels_a: &[usize], labels_b: &[usize]) -> f64 {
    let c = Contingency::new(labels_a, labels_b);
    let n = c.n as f64;
    if n < 2.0 {
        return 1.0;
    }
    let comb2 = |x: f64| x * (x - 1.0) / 2.0;
    let sum_cells: f64 = c.cells().map(|(_, _, v)| comb2(v as f64)).sum();
    let sum_a: f64 = c.row_sums.iter().map(|&v| comb2(v as f64)).sum();
    let sum_b: f64 = c.col_sums.iter().map(|&v| comb2(v as f64)).sum();
    let expected = sum_a * sum_b / comb2(n);
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        // Both partitions trivial (all-one-cluster or all-singletons).
        return if (sum_cells - expected).abs() < 1e-12 { 1.0 } else { 0.0 };
    }
    (sum_cells - expected) / (max_index - expected)
}

/// Normalized Mutual Information with arithmetic-mean normalization
/// (`NMI = 2·I(A;B) / (H(A) + H(B))`, sklearn's default). 1.0 = identical
/// partitions, 0 = independent.
pub fn nmi(labels_a: &[usize], labels_b: &[usize]) -> f64 {
    let c = Contingency::new(labels_a, labels_b);
    let n = c.n as f64;
    if n == 0.0 {
        return 1.0;
    }
    let mut h_a = 0.0;
    for &r in &c.row_sums {
        if r > 0 {
            let p = r as f64 / n;
            h_a -= p * p.ln();
        }
    }
    let mut h_b = 0.0;
    for &s in &c.col_sums {
        if s > 0 {
            let p = s as f64 / n;
            h_b -= p * p.ln();
        }
    }
    if h_a <= 0.0 && h_b <= 0.0 {
        return 1.0; // both partitions trivial and identical in structure
    }
    let mut mi = 0.0;
    for (i, j, v) in c.cells() {
        if v > 0 {
            let pij = v as f64 / n;
            let pi = c.row_sums[i] as f64 / n;
            let pj = c.col_sums[j] as f64 / n;
            mi += pij * (pij / (pi * pj)).ln();
        }
    }
    (2.0 * mi / (h_a + h_b)).clamp(0.0, 1.0)
}

/// Cluster-size histogram of a labeling (diagnostics for reports).
pub fn cluster_sizes(labels: &[usize]) -> Vec<usize> {
    let k = labels.iter().copied().max().map(|m| m + 1).unwrap_or(0);
    let mut sizes = vec![0usize; k];
    for &l in labels {
        sizes[l] += 1;
    }
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ari_perfect_match() {
        let a = [0, 0, 1, 1, 2, 2];
        assert!((ari(&a, &a) - 1.0).abs() < 1e-12);
        // Relabeled version still perfect.
        let b = [2, 2, 0, 0, 1, 1];
        assert!((ari(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ari_known_value() {
        // sklearn: adjusted_rand_score([0,0,1,1],[0,0,1,2]) = 0.5714285714...
        let a = [0, 0, 1, 1];
        let b = [0, 0, 1, 2];
        assert!((ari(&a, &b) - 0.5714285714285714).abs() < 1e-9);
    }

    #[test]
    fn ari_independent_near_zero() {
        let mut rng = Rng::seeded(1);
        let a: Vec<usize> = (0..5000).map(|_| rng.below(4)).collect();
        let b: Vec<usize> = (0..5000).map(|_| rng.below(4)).collect();
        assert!(ari(&a, &b).abs() < 0.02);
    }

    #[test]
    fn ari_single_cluster_vs_same() {
        let a = [0, 0, 0];
        assert!((ari(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nmi_perfect_and_relabeled() {
        let a = [0, 0, 1, 1, 2, 2];
        let b = [1, 1, 2, 2, 0, 0];
        assert!((nmi(&a, &a) - 1.0).abs() < 1e-12);
        assert!((nmi(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nmi_known_value() {
        // Hand computation with arithmetic-mean normalization:
        // H(A)=ln2, H(B)=−(½ln½ + 2·¼ln¼)≈1.0397, I(A;B)=ln2
        // ⇒ NMI = 2·ln2/(ln2+1.0397) = 0.8000…
        let a = [0, 0, 1, 1];
        let b = [0, 0, 1, 2];
        let got = nmi(&a, &b);
        assert!((got - 0.8).abs() < 1e-3, "nmi={got}");
    }

    #[test]
    fn nmi_independent_near_zero() {
        let mut rng = Rng::seeded(2);
        let a: Vec<usize> = (0..5000).map(|_| rng.below(5)).collect();
        let b: Vec<usize> = (0..5000).map(|_| rng.below(5)).collect();
        assert!(nmi(&a, &b) < 0.01);
    }

    #[test]
    fn metrics_symmetric() {
        let a = [0, 1, 1, 2, 0, 2, 1];
        let b = [1, 1, 0, 2, 2, 0, 0];
        assert!((ari(&a, &b) - ari(&b, &a)).abs() < 1e-12);
        assert!((nmi(&a, &b) - nmi(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn cluster_sizes_counts() {
        assert_eq!(cluster_sizes(&[0, 2, 2, 1]), vec![1, 1, 2]);
        assert_eq!(cluster_sizes(&[]), Vec::<usize>::new());
    }
}
