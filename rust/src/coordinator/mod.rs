//! Experiment coordinator — the launcher that regenerates every table and
//! figure in the paper's evaluation (§6 + Appendix C).
//!
//! * [`experiment`] — the run model: a [`experiment::RunSpec`] names a
//!   (dataset, kernel, algorithm, b, τ, seed) cell; [`experiment::run_one`]
//!   executes it and returns metrics + timings. Kernel-matrix construction
//!   is timed separately, mirroring the paper's black "kernel time" bars.
//! * [`figures`] — the figure/table registry: which grid each paper figure
//!   sweeps, and drivers that aggregate repeats into CSV + markdown under
//!   `results/`.
//! * [`report`] — aggregation (mean/std over seeds) and writers.
//! * [`repro`] — the `repro-speedup` preset: full-batch vs mini-batch
//!   (fixed and nested schedules) under a shared ε, emitting the
//!   deterministic reproduction table plus machine-local timings.
//!
//! * [`checkpoint`] — durable rotating training checkpoints + `--resume
//!   auto` selection (crash-safe atomic writes, checksum-validated
//!   snapshots, bit-identical replay — DESIGN.md §12).
//!
//! The CLI (`mbkk figures …`, `mbkk run …`, `mbkk gamma-table`) is a thin
//! wrapper over this module; `examples/paper_figures.rs` is the end-to-end
//! driver.

pub mod checkpoint;
pub mod experiment;
pub mod figures;
pub mod report;
pub mod repro;

pub use checkpoint::CheckpointConfig;
pub use experiment::{AlgoSpec, KernelSpec, RunOutcome, RunSpec};
pub use figures::{figure_ids, run_figure, run_gamma_table, FigureSpec};
pub use repro::{run_repro, ReproOptions, ReproRow};
