//! Durable rotating training checkpoints (DESIGN.md §12, ADR-004).
//!
//! A checkpointed `run`/`fit` periodically snapshots the full truncated
//! trainer state ([`crate::kkmeans::TrainSnapshot`]) into
//! `ckpt-<iter>.mbkk` files under a checkpoint directory, each written
//! with the crash-safe atomic protocol (same-dir temp + fsync + rename)
//! and the v2 checksummed artifact format. Rotation keeps the newest
//! `keep` snapshots plus an advisory `manifest.json`.
//!
//! Resume (`--resume auto`) selects the **newest checksum-valid** snapshot
//! whose spec fingerprint matches, silently skipping torn or corrupt files
//! (a crash mid-write leaves at most one of those, and the atomic protocol
//! makes even that window tiny). Selection scans the directory rather than
//! trusting the manifest: the manifest is itself a file that can be lost
//! to a crash, and it must never be able to veto a valid snapshot.
//!
//! A resumed run replays only the remaining iterations from the restored
//! RNG + window state and is **bit-identical** to the uninterrupted run —
//! pinned by `kkmeans::truncated` tests at the algorithm layer and by
//! `experiment` tests (and the CI chaos job) end to end.

use std::path::{Path, PathBuf};

use crate::kkmeans::TrainSnapshot;
use crate::serve::format;
use crate::util::error::{Context, Result};
use crate::util::failpoint;
use crate::util::json::Json;

/// Default number of rotated snapshots to keep on disk.
pub const DEFAULT_KEEP: usize = 3;

/// Where and how often a training run snapshots itself.
#[derive(Clone, Debug)]
pub struct CheckpointConfig {
    /// Directory for `ckpt-*.mbkk` + `manifest.json` (created on demand).
    pub dir: PathBuf,
    /// Snapshot cadence in iterations (0 disables checkpointing).
    pub every: usize,
    /// How many snapshots rotation retains (clamped to ≥ 1).
    pub keep: usize,
}

impl CheckpointConfig {
    /// A config with the default retention.
    pub fn new(dir: PathBuf, every: usize) -> CheckpointConfig {
        CheckpointConfig { dir, every, keep: DEFAULT_KEEP }
    }
}

/// `ckpt-00000042.mbkk` — zero-padded so lexicographic = numeric order.
fn snapshot_name(iter: usize) -> String {
    format!("ckpt-{iter:08}.mbkk")
}

/// Parse `ckpt-NNNNNNNN.mbkk` back to its iteration, rejecting strays.
fn parse_snapshot_name(name: &str) -> Option<usize> {
    let digits = name.strip_prefix("ckpt-")?.strip_suffix(".mbkk")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Snapshot files in `dir`, sorted by iteration ascending. Non-snapshot
/// files are ignored (the manifest, editor droppings, temp files).
fn list_snapshots(dir: &Path) -> Result<Vec<(usize, PathBuf)>> {
    let mut out = Vec::new();
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing checkpoint dir {}", dir.display()))?;
    for entry in entries {
        let entry = entry.with_context(|| format!("listing checkpoint dir {}", dir.display()))?;
        let name = entry.file_name();
        if let Some(iter) = name.to_str().and_then(parse_snapshot_name) {
            out.push((iter, entry.path()));
        }
    }
    out.sort();
    Ok(out)
}

/// Persist one snapshot durably and rotate old ones out.
///
/// `fingerprint` is the canonical spec string resume compares against;
/// `n` is the training-set size (validates indices at load time).
pub fn save_snapshot(
    cfg: &CheckpointConfig,
    snap: &TrainSnapshot,
    fingerprint: &str,
    n: usize,
) -> Result<()> {
    failpoint::fire("checkpoint.save")?;
    std::fs::create_dir_all(&cfg.dir)
        .with_context(|| format!("creating checkpoint dir {}", cfg.dir.display()))?;
    let bytes = format::train_to_bytes(snap, fingerprint, n);
    let path = cfg.dir.join(snapshot_name(snap.iterations()));
    format::atomic_write(&path, &bytes)?;
    rotate(cfg)
}

/// Prune to the newest `keep` snapshots and rewrite the advisory manifest.
fn rotate(cfg: &CheckpointConfig) -> Result<()> {
    let mut snaps = list_snapshots(&cfg.dir)?;
    let keep = cfg.keep.max(1);
    while snaps.len() > keep {
        let (_, path) = snaps.remove(0);
        std::fs::remove_file(&path)
            .with_context(|| format!("pruning old checkpoint {}", path.display()))?;
    }
    let manifest = Json::obj(vec![
        ("keep", Json::Num(keep as f64)),
        (
            "snapshots",
            Json::Arr(
                snaps
                    .iter()
                    .rev()
                    .map(|(i, _)| Json::Str(snapshot_name(*i)))
                    .collect(),
            ),
        ),
    ]);
    format::atomic_write(&cfg.dir.join("manifest.json"), manifest.to_string().as_bytes())
}

/// Select the newest checksum-valid snapshot for `--resume auto`.
///
/// Walks snapshots newest-first; a torn or corrupt file is *skipped* with
/// a note on stderr (falling back to the previous valid one), while a
/// valid snapshot written by a **different spec** is a hard error — that
/// is a user pointing a run at the wrong directory, and silently starting
/// fresh (or resuming the wrong run) would be worse than stopping.
/// `Ok(None)` means no snapshot files exist (or the directory doesn't).
pub fn load_latest(
    dir: &Path,
    fingerprint: &str,
    n: usize,
) -> Result<Option<(TrainSnapshot, PathBuf)>> {
    failpoint::fire("checkpoint.resume")?;
    if !dir.exists() {
        return Ok(None);
    }
    let mut snaps = list_snapshots(dir)?;
    snaps.reverse();
    for (_, path) in snaps {
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("mbkk: skipping unreadable checkpoint {}: {e}", path.display());
                continue;
            }
        };
        match format::train_from_bytes(&bytes) {
            Ok((snap, meta)) => {
                if meta.fingerprint != fingerprint {
                    crate::bail!(
                        "checkpoint {} was written by a different run \
                         configuration (found fingerprint {:?}, this run is {:?}); \
                         refusing to resume — point --checkpoint-dir at this run's \
                         directory or use --resume never",
                        path.display(),
                        meta.fingerprint,
                        fingerprint
                    );
                }
                if meta.n != n {
                    crate::bail!(
                        "checkpoint {} was trained on n={} points but this run has n={}",
                        path.display(),
                        meta.n,
                        n
                    );
                }
                return Ok(Some((snap, path)));
            }
            Err(e) => {
                eprintln!(
                    "mbkk: skipping corrupt checkpoint {} ({e}); trying the previous one",
                    path.display()
                );
            }
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::kernels::{Gram, KernelFunction};
    use crate::kkmeans::{
        Init, LearningRate, NativeBackend, ScheduleSpec, TerminationMode, TruncatedConfig,
        TruncatedMiniBatchKernelKMeans,
    };
    use crate::util::rng::Rng;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mbkk-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Collect real snapshots from a short truncated fit.
    fn snapshots(n: usize, every: usize) -> (Vec<TrainSnapshot>, usize) {
        let mut rng = Rng::seeded(77);
        let ds = blobs(&SyntheticSpec::new(n, 4, 3), &mut rng);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 2.0 });
        let algo = TruncatedMiniBatchKernelKMeans::new(TruncatedConfig {
            k: 3,
            batch_size: 32,
            schedule: ScheduleSpec::Fixed,
            tau: 60,
            max_iters: 10,
            epsilon: None,
            termination: TerminationMode::default(),
            learning_rate: LearningRate::Beta,
            init: Init::KMeansPlusPlus,
            weights: None,
        });
        let mut fit_rng = Rng::seeded(5);
        let mut snaps = Vec::new();
        algo.fit_with_backend_resumable(&gram, &mut NativeBackend, &mut fit_rng, None, every, &mut |s| {
            snaps.push(s.clone());
            Ok(())
        })
        .unwrap();
        (snaps, ds.n)
    }

    #[test]
    fn snapshot_names_roundtrip_and_reject_strays() {
        assert_eq!(snapshot_name(42), "ckpt-00000042.mbkk");
        assert_eq!(parse_snapshot_name("ckpt-00000042.mbkk"), Some(42));
        for stray in ["manifest.json", "ckpt-.mbkk", "ckpt-12.tmp", "ckpt-x2.mbkk", "note.txt"] {
            assert_eq!(parse_snapshot_name(stray), None, "{stray}");
        }
    }

    #[test]
    fn save_rotate_and_load_latest() {
        let dir = tmpdir("rotate");
        let (snaps, n) = snapshots(200, 2);
        assert!(snaps.len() >= 4, "need ≥4 snapshots, got {}", snaps.len());
        let cfg = CheckpointConfig { dir: dir.clone(), every: 2, keep: 2 };
        for s in &snaps {
            save_snapshot(&cfg, s, "spec-a", n).unwrap();
        }
        // Rotation keeps exactly `keep`, the newest ones.
        let on_disk = list_snapshots(&dir).unwrap();
        assert_eq!(on_disk.len(), 2);
        assert_eq!(on_disk.last().unwrap().0, snaps.last().unwrap().iterations());
        // Manifest lists them newest-first.
        let manifest =
            Json::parse(&std::fs::read_to_string(dir.join("manifest.json")).unwrap()).unwrap();
        let listed = manifest.get("snapshots").as_arr().unwrap();
        assert_eq!(listed[0].as_str(), Some(snapshot_name(on_disk[1].0).as_str()));
        // load_latest returns the newest snapshot, bit-identical.
        let (loaded, path) = load_latest(&dir, "spec-a", n).unwrap().expect("a snapshot");
        assert_eq!(path, on_disk.last().unwrap().1);
        assert_eq!(loaded.iterations(), snaps.last().unwrap().iterations());
        assert_eq!(
            format::train_to_bytes(&loaded, "spec-a", n),
            format::train_to_bytes(snaps.last().unwrap(), "spec-a", n)
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_newest_falls_back_to_previous_valid() {
        let dir = tmpdir("fallback");
        let (snaps, n) = snapshots(200, 2);
        let cfg = CheckpointConfig { dir: dir.clone(), every: 2, keep: 3 };
        for s in snaps.iter().take(3) {
            save_snapshot(&cfg, s, "spec-a", n).unwrap();
        }
        let on_disk = list_snapshots(&dir).unwrap();
        assert_eq!(on_disk.len(), 3);
        // Tear the newest snapshot mid-payload (a simulated crash that
        // somehow survived the atomic protocol) and bit-flip the second.
        let newest = &on_disk[2].1;
        let bytes = std::fs::read(newest).unwrap();
        std::fs::write(newest, &bytes[..bytes.len() / 2]).unwrap();
        let second = &on_disk[1].1;
        let mut bytes = std::fs::read(second).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(second, &bytes).unwrap();
        // Selection must land on the oldest — the only checksum-valid one.
        let (loaded, path) = load_latest(&dir, "spec-a", n).unwrap().expect("fallback");
        assert_eq!(path, on_disk[0].1);
        assert_eq!(loaded.iterations(), snaps[0].iterations());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mismatched_fingerprint_is_a_hard_error_and_empty_dir_is_none() {
        let dir = tmpdir("fprint");
        assert!(load_latest(&dir, "spec-a", 200).unwrap().is_none());
        assert!(load_latest(&dir.join("never-created"), "spec-a", 200).unwrap().is_none());
        let (snaps, n) = snapshots(200, 4);
        let cfg = CheckpointConfig::new(dir.clone(), 4);
        save_snapshot(&cfg, &snaps[0], "spec-a", n).unwrap();
        let err = load_latest(&dir, "spec-B", n).unwrap_err().to_string();
        assert!(err.contains("different run configuration"), "{err}");
        let err = load_latest(&dir, "spec-a", n + 1).unwrap_err().to_string();
        assert!(err.contains("n="), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_failpoints_surface_as_errors() {
        let _x = failpoint::exclusive_test_lock();
        let dir = tmpdir("failpoint");
        let (snaps, n) = snapshots(200, 4);
        let cfg = CheckpointConfig::new(dir.clone(), 4);
        failpoint::configure("checkpoint.save=1*err(disk on fire)").unwrap();
        let err = save_snapshot(&cfg, &snaps[0], "spec-a", n).unwrap_err().to_string();
        assert!(err.contains("disk on fire"), "{err}");
        failpoint::clear("checkpoint.save");
        save_snapshot(&cfg, &snaps[0], "spec-a", n).unwrap();
        failpoint::configure("checkpoint.resume=1*err(resume vetoed)").unwrap();
        let err = load_latest(&dir, "spec-a", n).unwrap_err().to_string();
        assert!(err.contains("resume vetoed"), "{err}");
        failpoint::clear("checkpoint.resume");
        assert!(load_latest(&dir, "spec-a", n).unwrap().is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
