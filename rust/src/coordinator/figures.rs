//! The figure/table registry: every figure and table in the paper's
//! evaluation, mapped to the grid that regenerates it.
//!
//! * **Figure 1** — the headline comparison: all four dataset proxies,
//!   Gaussian kernel, b=1024, τ=200, the five algorithm bars.
//! * **Figures 2–13** — the appendix grid: {mnist, har, letter, pendigits}
//!   × {gaussian, knn, heat}, sweeping b and τ for the mini-batch
//!   algorithms with both learning rates, against the full-batch baseline.
//! * **Table 1** — empirical γ per dataset × kernel.
//!
//! Run via `mbkk figures --fig N` / `--all` or `examples/paper_figures.rs`.
//! Results land in `results/` as CSV + markdown; EXPERIMENTS.md records the
//! paper-vs-measured comparison.

use super::experiment::{run_with_gram, AlgoSpec, KernelSpec, RunOutcome, RunSpec};
use super::report::{write_reports, Row};
use crate::data::registry;
use crate::kkmeans::LearningRate;
use crate::util::error::Result;
use crate::util::parallel::par_run_jobs;
use crate::util::rng::Rng;
use std::path::Path;

/// Declarative description of one paper figure.
#[derive(Clone, Debug)]
pub struct FigureSpec {
    /// Figure id, 1..=13.
    pub id: usize,
    /// Registry dataset name (`"*"` = all four paper proxies).
    pub dataset: &'static str,
    /// Kernel family the figure sweeps.
    pub kernel_name: &'static str,
    /// Batch sizes swept (mini-batch algorithms).
    pub batch_sizes: &'static [usize],
    /// τ values swept (truncated algorithm).
    pub taus: &'static [usize],
}

const PAPER_BS: &[usize] = &[256, 512, 1024, 2048];
const PAPER_TAUS: &[usize] = &[50, 100, 200, 300];
const FIG1_BS: &[usize] = &[1024];
const FIG1_TAUS: &[usize] = &[200];

/// All figure ids (1 = main figure; 2–13 = appendix grid in paper order).
pub fn figure_ids() -> Vec<usize> {
    (1..=13).collect()
}

/// The registry. Figures 2–13 follow the paper's ordering: MNIST (2–4),
/// HAR (5–7), Letters (8–10), PenDigits (11–13), each × {gaussian, knn,
/// heat}.
pub fn figure_spec(id: usize) -> FigureSpec {
    let (dataset, kernel_name) = match id {
        1 => ("*", "gaussian"), // all four datasets
        2 => ("synth_mnist", "gaussian"),
        3 => ("synth_mnist", "knn"),
        4 => ("synth_mnist", "heat"),
        5 => ("synth_har", "gaussian"),
        6 => ("synth_har", "knn"),
        7 => ("synth_har", "heat"),
        8 => ("synth_letters", "gaussian"),
        9 => ("synth_letters", "knn"),
        10 => ("synth_letters", "heat"),
        11 => ("synth_pendigits", "gaussian"),
        12 => ("synth_pendigits", "knn"),
        13 => ("synth_pendigits", "heat"),
        other => panic!("unknown figure {other} (1..=13)"),
    };
    FigureSpec {
        id,
        dataset,
        kernel_name,
        batch_sizes: if id == 1 { FIG1_BS } else { PAPER_BS },
        taus: if id == 1 { FIG1_TAUS } else { PAPER_TAUS },
    }
}

/// Options controlling a figure regeneration run.
#[derive(Clone, Debug)]
pub struct FigureOptions {
    /// Dataset scale factor (1.0 = paper-matched n; default smaller).
    pub scale: f64,
    /// Seeds per grid cell (paper: 10).
    pub repeats: usize,
    /// Iterations per run (paper: 200).
    pub max_iters: usize,
    /// Reduced grid (first/last of each sweep) for CI-speed runs.
    pub quick: bool,
    /// Base RNG seed.
    pub seed: u64,
}

impl Default for FigureOptions {
    fn default() -> Self {
        FigureOptions { scale: 0.25, repeats: 3, max_iters: 200, quick: false, seed: 7 }
    }
}

fn thin<T: Copy>(xs: &[T], quick: bool) -> Vec<T> {
    if quick && xs.len() > 2 {
        vec![xs[0], xs[xs.len() - 1]]
    } else {
        xs.to_vec()
    }
}

/// The algorithm roster of the appendix figures.
fn roster(batch_sizes: &[usize], taus: &[usize]) -> Vec<(AlgoSpec, usize, usize)> {
    let mut cells = Vec::new();
    // Full batch: one cell (b, τ irrelevant).
    cells.push((AlgoSpec::FullKkm, 0, 0));
    for &b in batch_sizes {
        for lr in [LearningRate::Beta, LearningRate::Sklearn] {
            cells.push((AlgoSpec::MbKkm(lr), b, 0));
            cells.push((AlgoSpec::MbKm(lr), b, 0));
            for &tau in taus {
                cells.push((AlgoSpec::TruncKkm(lr), b, tau));
            }
        }
    }
    cells
}

/// Regenerate one figure; returns the aggregated rows (also written to
/// `out_dir` as `figN_<dataset>_<kernel>.{csv,md}` when `out_dir` is given).
pub fn run_figure(id: usize, opts: &FigureOptions, out_dir: Option<&Path>) -> Result<Vec<Row>> {
    let spec = figure_spec(id);
    let datasets: Vec<&str> = if spec.dataset == "*" {
        registry::PAPER_PROXIES.to_vec()
    } else {
        vec![spec.dataset]
    };
    let mut all_rows = Vec::new();
    for dataset in datasets {
        let rows = run_grid(
            &format!("fig{id}"),
            dataset,
            KernelSpec::from_name(spec.kernel_name),
            &thin(spec.batch_sizes, opts.quick),
            &thin(spec.taus, opts.quick),
            opts,
        )?;
        all_rows.extend(rows);
    }
    if let Some(dir) = out_dir {
        let stem = if spec.dataset == "*" {
            format!("fig{id}_all_{}", spec.kernel_name)
        } else {
            format!("fig{id}_{}_{}", spec.dataset, spec.kernel_name)
        };
        write_reports(dir, &stem, &all_rows)?;
    }
    Ok(all_rows)
}

/// Run the full grid for one (dataset, kernel): builds the dataset and gram
/// once, then runs every (algo, b, τ, seed) cell in parallel.
fn run_grid(
    figure: &str,
    dataset: &str,
    kernel: KernelSpec,
    batch_sizes: &[usize],
    taus: &[usize],
    opts: &FigureOptions,
) -> Result<Vec<Row>> {
    let ds = registry::load(dataset, opts.scale, opts.seed);
    let k = registry::default_k(dataset);
    let mut rng = Rng::seeded(opts.seed ^ 0xF16);
    let (gram, kernel_secs) = kernel.build(&ds, &mut rng);
    eprintln!(
        "[figures] {figure} {dataset}/{} n={} k={k} gamma={:.4} kernel_secs={:.2}",
        kernel.name(),
        ds.n,
        gram.gamma(),
        kernel_secs
    );

    let cells = roster(batch_sizes, taus);
    let mut rows = Vec::new();
    for (algo, b, tau) in cells {
        let spec = RunSpec {
            dataset: dataset.to_string(),
            scale: opts.scale,
            kernel,
            algo,
            k,
            batch_size: if b == 0 { 1024 } else { b },
            schedule: crate::kkmeans::ScheduleSpec::Fixed,
            tau: if tau == 0 { usize::MAX } else { tau },
            max_iters: opts.max_iters,
            epsilon: None,
            seed: 0,
            // Figure grids are paper-protocol artifacts: always deterministic.
            numerics: crate::kernels::NumericsMode::Deterministic,
        };
        // Repeats run in parallel; each clones the spec with its own seed.
        let jobs: Vec<_> = (0..opts.repeats)
            .map(|rep| {
                let mut s = spec.clone();
                s.seed = opts.seed.wrapping_add(rep as u64 * 7919);
                let ds = &ds;
                let gram = &gram;
                move || run_with_gram(&s, ds, Some(gram), kernel_secs)
            })
            .collect();
        let outcomes: Vec<RunOutcome> = par_run_jobs(jobs);
        rows.push(Row::aggregate(
            figure,
            dataset,
            kernel.name(),
            &algo.name(),
            b,
            tau,
            &outcomes,
        ));
        let last = rows.last().unwrap();
        eprintln!(
            "[figures]   {} b={b} tau={tau}: ARI {:.3}±{:.3} in {:.2}s",
            algo.name(),
            last.ari.mean,
            last.ari.std,
            last.cluster_secs.mean
        );
    }
    Ok(rows)
}

/// Table 1: γ per dataset × kernel type.
pub fn run_gamma_table(scale: f64, seed: u64, out_dir: Option<&Path>) -> Result<String> {
    let mut md = String::from("| Dataset | Kernel Type | γ |\n|---|---|---|\n");
    let mut csv = String::from("dataset,kernel,gamma\n");
    for &dataset in registry::PAPER_PROXIES {
        let ds = registry::load(dataset, scale, seed);
        for kernel_name in ["knn", "heat", "gaussian"] {
            let kernel = KernelSpec::from_name(kernel_name);
            let mut rng = Rng::seeded(seed);
            let (gram, _) = kernel.build(&ds, &mut rng);
            let gamma = gram.gamma();
            md.push_str(&format!("| {dataset} | {kernel_name} | {gamma:.3e} |\n"));
            csv.push_str(&format!("{dataset},{kernel_name},{gamma}\n"));
            eprintln!("[gamma] {dataset}/{kernel_name}: {gamma:.4}");
        }
    }
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("table1_gamma.md"), &md)?;
        std::fs::write(dir.join("table1_gamma.csv"), &csv)?;
    }
    Ok(md)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_paper_layout() {
        assert_eq!(figure_ids().len(), 13);
        let f1 = figure_spec(1);
        assert_eq!(f1.dataset, "*");
        assert_eq!(f1.batch_sizes, &[1024]);
        // 4 datasets × 3 kernels in paper order.
        let mut seen = std::collections::BTreeSet::new();
        for id in 2..=13 {
            let f = figure_spec(id);
            seen.insert((f.dataset, f.kernel_name));
            assert_eq!(f.batch_sizes.len(), 4);
            assert_eq!(f.taus.len(), 4);
        }
        assert_eq!(seen.len(), 12);
    }

    #[test]
    fn roster_contains_all_paper_algorithms() {
        let cells = roster(&[256, 1024], &[50, 200]);
        let names: std::collections::BTreeSet<String> =
            cells.iter().map(|(a, _, _)| a.name()).collect();
        for want in ["full-kkm", "bmb-kkm", "mb-kkm", "btrunc-kkm", "trunc-kkm", "bmb-km", "mb-km"] {
            assert!(names.contains(want), "missing {want}");
        }
        // full(1) + per-b: 2·(mbkkm+mbkm) + 2·2 trunc  = 1 + 2·(2+2+4) = 17
        assert_eq!(cells.len(), 1 + 2 * (2 + 2 + 4));
    }

    #[test]
    fn tiny_figure_run_produces_rows() {
        // Scale far down so this stays a unit test.
        let opts = FigureOptions {
            scale: 0.02,
            repeats: 2,
            max_iters: 8,
            quick: true,
            seed: 5,
        };
        let rows = run_figure(11, &opts, None).unwrap();
        assert!(!rows.is_empty());
        for r in &rows {
            assert_eq!(r.dataset, "synth_pendigits");
            assert_eq!(r.kernel, "gaussian");
            assert_eq!(r.repeats, 2);
            assert!(r.ari.mean.is_finite());
        }
        // quick ⇒ b sweep thinned to {256, 2048}.
        let bs: std::collections::BTreeSet<usize> =
            rows.iter().map(|r| r.batch_size).filter(|&b| b > 0).collect();
        assert_eq!(bs, [256usize, 2048].into_iter().collect());
    }

    #[test]
    fn gamma_table_small() {
        let md = run_gamma_table(0.02, 3, None).unwrap();
        // 4 datasets × 3 kernels = 12 data rows + 2 header lines.
        assert_eq!(md.lines().count(), 14);
        assert!(md.contains("gaussian"));
    }
}
