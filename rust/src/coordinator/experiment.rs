//! The experiment run model: one cell of a paper figure's grid.

use crate::data::{registry, Dataset};
use crate::kernels::{graph, sigma, Gram, KernelFunction};
use crate::kkmeans::{
    FullBatchConfig, FullBatchKernelKMeans, Init, LearningRate, MiniBatchConfig,
    MiniBatchKernelKMeans, TruncatedConfig, TruncatedMiniBatchKernelKMeans,
};
use crate::kmeans::{KMeans, KMeansConfig, MiniBatchKMeans, MiniBatchKMeansConfig};
use crate::metrics::{ari, nmi};
use crate::util::rng::Rng;
use crate::util::timing::Stopwatch;

/// Which kernel to build for a dataset.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelSpec {
    /// Gaussian with κ from the Wang et al. heuristic × `multiplier`
    /// (the paper's "manual tuning" knob).
    Gaussian { multiplier: f64 },
    /// k-nn kernel `D⁻¹AD⁻¹`.
    Knn { neighbors: usize },
    /// Heat kernel `exp(−t·L̃)` on the knn graph.
    Heat { neighbors: usize, t: f64 },
}

impl KernelSpec {
    /// Short display name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            KernelSpec::Gaussian { .. } => "gaussian",
            KernelSpec::Knn { .. } => "knn",
            KernelSpec::Heat { .. } => "heat",
        }
    }

    /// Paper defaults per kernel family.
    pub fn from_name(name: &str) -> KernelSpec {
        match name {
            "gaussian" => KernelSpec::Gaussian { multiplier: 1.0 },
            "knn" => KernelSpec::Knn { neighbors: 10 },
            "heat" => KernelSpec::Heat { neighbors: 10, t: 100.0 },
            other => panic!("unknown kernel {other:?} (gaussian|knn|heat)"),
        }
    }

    /// Build the gram provider; returns (gram, build seconds). Feature
    /// kernels are *materialized* so every algorithm pays only lookups —
    /// this matches the paper's protocol, which precomputes the kernel
    /// matrix and reports that cost as the black bars.
    pub fn build(&self, ds: &Dataset, rng: &mut Rng) -> (Gram<'static>, f64) {
        let sw = Stopwatch::start();
        let gram = match *self {
            KernelSpec::Gaussian { multiplier } => {
                let kappa = sigma::kappa_heuristic_with(
                    ds,
                    rng,
                    sigma::DEFAULT_PAIR_SAMPLES,
                    multiplier,
                );
                Gram::on_the_fly(ds, KernelFunction::Gaussian { kappa }).materialize()
            }
            KernelSpec::Knn { neighbors } => graph::knn_kernel(ds, neighbors),
            KernelSpec::Heat { neighbors, t } => graph::heat_kernel(ds, neighbors, t),
        };
        (gram, sw.secs())
    }

    /// The Gaussian κ for this dataset (used by the XLA backend path, which
    /// needs the un-materialized feature kernel).
    pub fn gaussian_kappa(&self, ds: &Dataset, rng: &mut Rng) -> Option<f64> {
        match *self {
            KernelSpec::Gaussian { multiplier } => Some(sigma::kappa_heuristic_with(
                ds,
                rng,
                sigma::DEFAULT_PAIR_SAMPLES,
                multiplier,
            )),
            _ => None,
        }
    }
}

/// Which algorithm a grid cell runs. β-prefixed names (paper convention)
/// use the Schwartzman (2023) learning rate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoSpec {
    /// Full-batch kernel k-means (baseline, O(n²)/iter).
    FullKkm,
    /// Algorithm 1 (untruncated mini-batch kernel k-means).
    MbKkm(LearningRate),
    /// Algorithm 2 (truncated) — the paper's contribution.
    TruncKkm(LearningRate),
    /// Non-kernel mini-batch k-means (Sculley).
    MbKm(LearningRate),
    /// Non-kernel Lloyd's (extra baseline).
    Lloyd,
}

impl AlgoSpec {
    /// Display name in the paper's convention (β prefix → `b`).
    pub fn name(&self) -> String {
        match self {
            AlgoSpec::FullKkm => "full-kkm".into(),
            AlgoSpec::MbKkm(lr) => format!("{}mb-kkm", beta_prefix(*lr)),
            AlgoSpec::TruncKkm(lr) => format!("{}trunc-kkm", beta_prefix(*lr)),
            AlgoSpec::MbKm(lr) => format!("{}mb-km", beta_prefix(*lr)),
            AlgoSpec::Lloyd => "kmeans".into(),
        }
    }

    /// Parse a CLI algorithm name (panics on unknown names).
    pub fn from_name(name: &str) -> AlgoSpec {
        match name {
            "full-kkm" => AlgoSpec::FullKkm,
            "mb-kkm" => AlgoSpec::MbKkm(LearningRate::Sklearn),
            "bmb-kkm" | "β-mb-kkm" => AlgoSpec::MbKkm(LearningRate::Beta),
            "trunc-kkm" => AlgoSpec::TruncKkm(LearningRate::Sklearn),
            "btrunc-kkm" | "β-trunc-kkm" => AlgoSpec::TruncKkm(LearningRate::Beta),
            "mb-km" => AlgoSpec::MbKm(LearningRate::Sklearn),
            "bmb-km" | "β-mb-km" => AlgoSpec::MbKm(LearningRate::Beta),
            "kmeans" => AlgoSpec::Lloyd,
            other => panic!(
                "unknown algo {other:?} (full-kkm | [b]mb-kkm | [b]trunc-kkm | [b]mb-km | kmeans)"
            ),
        }
    }

    /// Whether the algorithm needs the kernel/gram at all.
    pub fn is_kernelized(&self) -> bool {
        !matches!(self, AlgoSpec::MbKm(_) | AlgoSpec::Lloyd)
    }
}

fn beta_prefix(lr: LearningRate) -> &'static str {
    match lr {
        LearningRate::Beta => "b",
        LearningRate::Sklearn => "",
    }
}

/// One grid cell: everything needed to reproduce a single run.
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Registry dataset name.
    pub dataset: String,
    /// Global dataset scale factor (DESIGN.md §3 substitution).
    pub scale: f64,
    /// Which kernel to build.
    pub kernel: KernelSpec,
    /// Which algorithm to run.
    pub algo: AlgoSpec,
    /// Number of clusters.
    pub k: usize,
    /// Batch size `b` (mini-batch algorithms).
    pub batch_size: usize,
    /// Truncation parameter τ (Algorithm 2).
    pub tau: usize,
    /// Iteration budget.
    pub max_iters: usize,
    /// ε for early stopping; None = fixed iterations (paper protocol).
    pub epsilon: Option<f64>,
    /// RNG seed (dataset + run streams derive from it).
    pub seed: u64,
}

impl RunSpec {
    /// Compact one-line cell description for logs.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{} b={} tau={} seed={}",
            self.dataset,
            self.kernel.name(),
            self.algo.name(),
            self.batch_size,
            self.tau,
            self.seed
        )
    }
}

/// Metrics from one run.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    /// Adjusted Rand Index against ground truth (NaN when unlabeled).
    pub ari: f64,
    /// Normalized Mutual Information against ground truth (NaN when unlabeled).
    pub nmi: f64,
    /// Final full-dataset objective `f_X(C)`.
    pub objective: f64,
    /// Iterations executed.
    pub iterations: usize,
    /// Whether the ε early-stopping condition fired.
    pub converged: bool,
    /// Clustering wall-clock (excludes kernel construction).
    pub cluster_secs: f64,
    /// Kernel/gram construction wall-clock (the paper's black bars).
    pub kernel_secs: f64,
    /// γ of the gram (Table 1).
    pub gamma: f64,
}

/// Execute a run against a pre-built dataset + gram (lets the figure driver
/// share one gram across the whole grid). `kernel_secs` is threaded through
/// into the outcome.
pub fn run_with_gram(
    spec: &RunSpec,
    ds: &Dataset,
    gram: &Gram,
    kernel_secs: f64,
) -> RunOutcome {
    let mut rng = Rng::seeded(spec.seed ^ 0x5EED);
    let sw = Stopwatch::start();
    let fit = match spec.algo {
        AlgoSpec::FullKkm => FullBatchKernelKMeans::new(FullBatchConfig {
            k: spec.k,
            max_iters: spec.max_iters,
            epsilon: spec.epsilon,
            init: Init::KMeansPlusPlus,
            weights: None,
        })
        .fit(gram, &mut rng),
        AlgoSpec::MbKkm(lr) => MiniBatchKernelKMeans::new(MiniBatchConfig {
            k: spec.k,
            batch_size: spec.batch_size,
            max_iters: spec.max_iters,
            epsilon: spec.epsilon,
            learning_rate: lr,
            init: Init::KMeansPlusPlus,
            weights: None,
        })
        .fit(gram, &mut rng),
        AlgoSpec::TruncKkm(lr) => TruncatedMiniBatchKernelKMeans::new(TruncatedConfig {
            k: spec.k,
            batch_size: spec.batch_size,
            tau: spec.tau,
            max_iters: spec.max_iters,
            epsilon: spec.epsilon,
            learning_rate: lr,
            init: Init::KMeansPlusPlus,
            weights: None,
        })
        .fit(gram, &mut rng),
        AlgoSpec::MbKm(lr) => MiniBatchKMeans::new(MiniBatchKMeansConfig {
            k: spec.k,
            batch_size: spec.batch_size,
            max_iters: spec.max_iters,
            epsilon: spec.epsilon,
            learning_rate: lr,
        })
        .fit(ds, &mut rng),
        AlgoSpec::Lloyd => KMeans::new(KMeansConfig {
            k: spec.k,
            max_iters: spec.max_iters,
            epsilon: spec.epsilon,
        })
        .fit(ds, &mut rng),
    };
    let cluster_secs = sw.secs();
    let (ari_v, nmi_v) = match &ds.labels {
        Some(truth) => (ari(truth, &fit.assignments), nmi(truth, &fit.assignments)),
        None => (f64::NAN, f64::NAN),
    };
    RunOutcome {
        ari: ari_v,
        nmi: nmi_v,
        objective: fit.objective,
        iterations: fit.iterations,
        converged: fit.converged,
        cluster_secs,
        kernel_secs,
        gamma: gram.gamma(),
    }
}

/// Execute a fully self-contained run (builds dataset + gram itself).
pub fn run_one(spec: &RunSpec) -> RunOutcome {
    let ds = registry::load(&spec.dataset, spec.scale, spec.seed);
    let mut rng = Rng::seeded(spec.seed ^ 0xC0DE);
    let (gram, kernel_secs) = if spec.algo.is_kernelized() {
        spec.kernel.build(&ds, &mut rng)
    } else {
        (Gram::precomputed("unused", 0, Vec::new()), 0.0)
    };
    if spec.algo.is_kernelized() {
        run_with_gram(spec, &ds, &gram, kernel_secs)
    } else {
        // Non-kernel algorithms never touch the gram.
        let dummy = Gram::precomputed("unused", 0, Vec::new());
        let mut out = run_with_gram(spec, &ds, &dummy, 0.0);
        out.gamma = f64::NAN;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_spec(algo: AlgoSpec) -> RunSpec {
        RunSpec {
            dataset: "blobs".into(),
            scale: 0.05,
            kernel: KernelSpec::Gaussian { multiplier: 1.0 },
            algo,
            k: 5,
            batch_size: 64,
            tau: 50,
            max_iters: 20,
            epsilon: None,
            seed: 3,
        }
    }

    #[test]
    fn every_algorithm_runs_end_to_end() {
        for algo in [
            AlgoSpec::FullKkm,
            AlgoSpec::MbKkm(LearningRate::Beta),
            AlgoSpec::TruncKkm(LearningRate::Beta),
            AlgoSpec::TruncKkm(LearningRate::Sklearn),
            AlgoSpec::MbKm(LearningRate::Beta),
            AlgoSpec::Lloyd,
        ] {
            let out = run_one(&base_spec(algo));
            assert!(out.ari.is_finite(), "{algo:?}");
            assert!(out.objective.is_finite(), "{algo:?}");
            assert!(out.cluster_secs >= 0.0);
            // blobs at separation 3 should cluster reasonably with any algo.
            assert!(out.ari > 0.3, "{algo:?}: ARI={}", out.ari);
        }
    }

    #[test]
    fn kernel_names_roundtrip() {
        for name in ["gaussian", "knn", "heat"] {
            assert_eq!(KernelSpec::from_name(name).name(), name);
        }
    }

    #[test]
    fn algo_names_roundtrip() {
        for name in [
            "full-kkm", "mb-kkm", "bmb-kkm", "trunc-kkm", "btrunc-kkm", "mb-km",
            "bmb-km", "kmeans",
        ] {
            assert_eq!(AlgoSpec::from_name(name).name(), name);
        }
    }

    #[test]
    fn knn_kernel_run_has_small_gamma() {
        let mut spec = base_spec(AlgoSpec::TruncKkm(LearningRate::Beta));
        spec.kernel = KernelSpec::Knn { neighbors: 8 };
        let out = run_one(&spec);
        assert!(out.gamma < 0.5, "knn gamma should be ≪ 1, got {}", out.gamma);
        assert!(out.ari.is_finite());
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = base_spec(AlgoSpec::TruncKkm(LearningRate::Beta));
        let a = run_one(&spec);
        let b = run_one(&spec);
        assert_eq!(a.ari, b.ari);
        assert_eq!(a.objective, b.objective);
    }
}
