//! The experiment run model: one cell of a paper figure's grid.
//!
//! Besides the grid-cell model ([`RunSpec`] → [`RunOutcome`]), this module
//! owns the **gram realization policy** ([`GramStrategy`]): whether a run's
//! kernel is materialized into a dense n×n table (the paper's protocol,
//! fine up to the [`DEFAULT_MAX_TABLE_BYTES`] threshold) or served by the
//! streaming tile-LRU provider (`O(n·d + cache)` memory, the path that
//! unlocks million-point runs). Algorithms only ever see
//! `&dyn KernelProvider`, so the choice is made once, here.

use super::checkpoint::{self, CheckpointConfig};
use crate::bail;
use crate::data::{registry, Dataset};
use crate::kernels::{
    graph, sigma, CachedGram, CacheStats, Gram, KernelFunction, KernelProvider, NumericsMode,
};
use crate::kkmeans::{
    FullBatchConfig, FullBatchKernelKMeans, Init, KernelKMeansModel, LearningRate,
    MiniBatchConfig, MiniBatchKernelKMeans, NativeBackend, ScheduleSpec, TerminationDecision,
    TerminationMode, TruncatedConfig, TruncatedMiniBatchKernelKMeans,
};
use crate::kmeans::{KMeans, KMeansConfig, MiniBatchKMeans, MiniBatchKMeansConfig};
use crate::metrics::{ari, nmi};
use crate::util::rng::Rng;
use crate::util::timing::{Profiler, Stopwatch};

/// Which kernel to build for a dataset.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelSpec {
    /// Gaussian with κ from the Wang et al. heuristic × `multiplier`
    /// (the paper's "manual tuning" knob).
    Gaussian { multiplier: f64 },
    /// k-nn kernel `D⁻¹AD⁻¹`.
    Knn { neighbors: usize },
    /// Heat kernel `exp(−t·L̃)` on the knn graph.
    Heat { neighbors: usize, t: f64 },
}

impl KernelSpec {
    /// Short display name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            KernelSpec::Gaussian { .. } => "gaussian",
            KernelSpec::Knn { .. } => "knn",
            KernelSpec::Heat { .. } => "heat",
        }
    }

    /// Paper defaults per kernel family.
    pub fn from_name(name: &str) -> KernelSpec {
        match name {
            "gaussian" => KernelSpec::Gaussian { multiplier: 1.0 },
            "knn" => KernelSpec::Knn { neighbors: 10 },
            "heat" => KernelSpec::Heat { neighbors: 10, t: 100.0 },
            other => panic!("unknown kernel {other:?} (gaussian|knn|heat)"),
        }
    }

    /// Build a fully *materialized* gram; returns (gram, build seconds).
    /// This matches the paper's protocol, which precomputes the kernel
    /// matrix and reports that cost as the black bars. The figure driver
    /// uses it to share one table across a whole grid; scale-sensitive
    /// paths go through [`KernelSpec::build_with`] instead.
    pub fn build(&self, ds: &Dataset, rng: &mut Rng) -> (Gram<'static>, f64) {
        let sw = Stopwatch::start();
        let built = self
            .build_with(ds, rng, GramStrategy::Materialize, NumericsMode::Deterministic)
            .0;
        let gram = match built {
            BuiltGram::Materialized(g) => g,
            BuiltGram::Streaming(_) => unreachable!("Materialize never streams"),
        };
        (gram, sw.secs())
    }

    /// Build the gram provider under a [`GramStrategy`]; returns the built
    /// provider and the build seconds. Feature kernels honour the strategy
    /// (materialize vs stream) and the numerics mode (DESIGN.md §13: Fast
    /// batches the exp finish through the SIMD lanes; dot kernels are
    /// bit-identical either way); graph kernels are dense n×n by
    /// construction and always materialize (forcing `Stream` for them
    /// panics with a clear message — their O(n²) build cost dwarfs any
    /// table saving) and are unaffected by the numerics mode.
    pub fn build_with<'a>(
        &self,
        ds: &'a Dataset,
        rng: &mut Rng,
        strategy: GramStrategy,
        numerics: NumericsMode,
    ) -> (BuiltGram<'a>, f64) {
        let sw = Stopwatch::start();
        let built = match *self {
            KernelSpec::Gaussian { multiplier } => {
                build_gaussian(ds, rng, multiplier, strategy, numerics).0
            }
            KernelSpec::Knn { neighbors } => {
                check_graph_kernel_feasible("knn", ds.n, strategy);
                BuiltGram::Materialized(graph::knn_kernel(ds, neighbors))
            }
            KernelSpec::Heat { neighbors, t } => {
                check_graph_kernel_feasible("heat", ds.n, strategy);
                BuiltGram::Materialized(graph::heat_kernel(ds, neighbors, t))
            }
        };
        (built, sw.secs())
    }

    /// The Gaussian κ for this dataset (used by the XLA backend path, which
    /// needs the un-materialized feature kernel).
    pub fn gaussian_kappa(&self, ds: &Dataset, rng: &mut Rng) -> Option<f64> {
        match *self {
            KernelSpec::Gaussian { multiplier } => Some(sigma::kappa_heuristic_with(
                ds,
                rng,
                sigma::DEFAULT_PAIR_SAMPLES,
                multiplier,
            )),
            _ => None,
        }
    }
}

/// Resolve the Gaussian feature kernel (κ heuristic) and realize its gram
/// under a strategy — the single Gaussian build path shared by
/// [`KernelSpec::build_with`] (and through it every `run`) and
/// [`fit_servable_model`], so the two can never drift in RNG consumption
/// or gram realization.
fn build_gaussian<'a>(
    ds: &'a Dataset,
    rng: &mut Rng,
    multiplier: f64,
    strategy: GramStrategy,
    numerics: NumericsMode,
) -> (BuiltGram<'a>, KernelFunction) {
    let kappa =
        sigma::kappa_heuristic_with(ds, rng, sigma::DEFAULT_PAIR_SAMPLES, multiplier);
    let func = KernelFunction::Gaussian { kappa };
    let fly = Gram::on_the_fly_with(ds, func, numerics);
    let built = if strategy.materializes(ds.n) {
        BuiltGram::Materialized(fly.materialize())
    } else {
        BuiltGram::Streaming(CachedGram::new(fly, strategy.cache_bytes()))
    };
    (built, func)
}

/// Fail fast instead of attempting a multi-TB allocation: graph kernels
/// are dense n×n by construction, so explicit `Stream` is contradictory
/// and an `Auto` run whose table would blow the budget must error *before*
/// `knn_adjacency` starts its O(n²) build, not OOM inside it.
fn check_graph_kernel_feasible(kernel: &str, n: usize, strategy: GramStrategy) {
    assert!(
        !matches!(strategy, GramStrategy::Stream { .. }),
        "--stream is not supported for the {kernel} kernel: graph kernels \
         are built as dense n×n matrices regardless (run without --stream)"
    );
    assert!(
        strategy.materializes(n),
        "the {kernel} kernel over n={n} points needs a dense n×n matrix \
         ({:.1} GB) exceeding the configured table budget; graph kernels \
         cannot stream — reduce --scale, use a feature kernel \
         (--kernel gaussian), or force the dense build with --materialize",
        4.0 * (n as f64) * (n as f64) / 1e9
    );
}

/// Largest dense kernel table [`GramStrategy::Auto`] will materialize:
/// 2 GiB of f32, i.e. n ≈ 23k. Above it the streaming tile-LRU provider
/// serves the run in `O(n·d + cache)` memory.
pub const DEFAULT_MAX_TABLE_BYTES: usize = 2 << 30;

/// Default tile-LRU cache budget (MiB) for streaming runs.
pub const DEFAULT_CACHE_MB: usize = 64;

/// How a run's kernel access is realized (the n-threshold policy that
/// replaces the unconditional `Gram::materialize()` of earlier revisions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GramStrategy {
    /// Materialize when the n×n f32 table fits `max_table_bytes`; stream
    /// through a `cache_mb`-MiB tile-LRU cache otherwise.
    Auto {
        /// Largest table the policy will allocate, in bytes.
        max_table_bytes: usize,
        /// Tile-LRU budget (MiB) for runs that fall on the streaming side.
        cache_mb: usize,
    },
    /// Always materialize (the paper's protocol; O(n²) memory).
    Materialize,
    /// Always stream (feature kernels only; `--stream` on the CLI).
    Stream {
        /// Tile-LRU budget in MiB.
        cache_mb: usize,
    },
}

impl Default for GramStrategy {
    fn default() -> Self {
        GramStrategy::Auto {
            max_table_bytes: DEFAULT_MAX_TABLE_BYTES,
            cache_mb: DEFAULT_CACHE_MB,
        }
    }
}

impl GramStrategy {
    /// Whether a feature kernel over `n` points gets a dense table.
    pub fn materializes(&self, n: usize) -> bool {
        match *self {
            GramStrategy::Materialize => true,
            GramStrategy::Stream { .. } => false,
            GramStrategy::Auto { max_table_bytes, .. } => {
                (n as u128) * (n as u128) * 4 <= max_table_bytes as u128
            }
        }
    }

    /// Tile-LRU budget in bytes for the streaming side of this strategy.
    pub fn cache_bytes(&self) -> usize {
        match *self {
            GramStrategy::Auto { cache_mb, .. } | GramStrategy::Stream { cache_mb } => {
                cache_mb << 20
            }
            GramStrategy::Materialize => DEFAULT_CACHE_MB << 20,
        }
    }

    /// Algorithm-aware effective strategy. Full-batch kernel k-means reads
    /// all n² pairs every iteration, so the dense table is the only
    /// sensible representation: explicit `Stream` is rejected (it would
    /// only add cache overhead and ulp-level reduction-order differences),
    /// and an `Auto` run whose table cannot fit fails fast instead of
    /// thrashing the tile cache for hours. Mini-batch algorithms pass
    /// through unchanged.
    pub fn resolve(self, algo: AlgoSpec, n: usize) -> GramStrategy {
        if !matches!(algo, AlgoSpec::FullKkm) {
            return self;
        }
        assert!(
            !matches!(self, GramStrategy::Stream { .. }),
            "--stream is not supported for full-kkm: every full-batch iteration \
             touches all n² kernel pairs, so streaming only adds overhead (run \
             without --stream, or use a mini-batch algorithm)"
        );
        assert!(
            self.materializes(n),
            "full-kkm over n={n} needs the dense n×n table ({:.1} GB), which \
             exceeds the table budget — use a mini-batch algorithm at this \
             scale, or force the table with --materialize",
            4.0 * (n as f64) * (n as f64) / 1e9
        );
        GramStrategy::Materialize
    }
}

/// A realized gram provider: either a dense table (detached from the
/// dataset) or a streaming cached provider borrowing the dataset's
/// features.
pub enum BuiltGram<'a> {
    /// Dense n×n table (O(n²) memory, O(1) lookups).
    Materialized(Gram<'static>),
    /// Tile-LRU-cached on-demand evaluation (O(cache) memory).
    Streaming(CachedGram<'a>),
}

impl BuiltGram<'_> {
    /// The provider to hand to algorithms.
    pub fn provider(&self) -> &dyn KernelProvider {
        match self {
            BuiltGram::Materialized(g) => g,
            BuiltGram::Streaming(c) => c,
        }
    }

    /// `"materialized"` or `"streaming"` for logs.
    pub fn mode(&self) -> &'static str {
        match self {
            BuiltGram::Materialized(_) => "materialized",
            BuiltGram::Streaming(_) => "streaming",
        }
    }

    /// Tile-cache counters (streaming mode only).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        match self {
            BuiltGram::Materialized(_) => None,
            BuiltGram::Streaming(c) => Some(c.cache_stats()),
        }
    }
}

/// Which algorithm a grid cell runs. β-prefixed names (paper convention)
/// use the Schwartzman (2023) learning rate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoSpec {
    /// Full-batch kernel k-means (baseline, O(n²)/iter).
    FullKkm,
    /// Algorithm 1 (untruncated mini-batch kernel k-means).
    MbKkm(LearningRate),
    /// Algorithm 2 (truncated) — the paper's contribution.
    TruncKkm(LearningRate),
    /// Non-kernel mini-batch k-means (Sculley).
    MbKm(LearningRate),
    /// Non-kernel Lloyd's (extra baseline).
    Lloyd,
}

impl AlgoSpec {
    /// Display name in the paper's convention (β prefix → `b`).
    pub fn name(&self) -> String {
        match self {
            AlgoSpec::FullKkm => "full-kkm".into(),
            AlgoSpec::MbKkm(lr) => format!("{}mb-kkm", beta_prefix(*lr)),
            AlgoSpec::TruncKkm(lr) => format!("{}trunc-kkm", beta_prefix(*lr)),
            AlgoSpec::MbKm(lr) => format!("{}mb-km", beta_prefix(*lr)),
            AlgoSpec::Lloyd => "kmeans".into(),
        }
    }

    /// Parse a CLI algorithm name (panics on unknown names).
    pub fn from_name(name: &str) -> AlgoSpec {
        match name {
            "full-kkm" => AlgoSpec::FullKkm,
            "mb-kkm" => AlgoSpec::MbKkm(LearningRate::Sklearn),
            "bmb-kkm" | "β-mb-kkm" => AlgoSpec::MbKkm(LearningRate::Beta),
            "trunc-kkm" => AlgoSpec::TruncKkm(LearningRate::Sklearn),
            "btrunc-kkm" | "β-trunc-kkm" => AlgoSpec::TruncKkm(LearningRate::Beta),
            "mb-km" => AlgoSpec::MbKm(LearningRate::Sklearn),
            "bmb-km" | "β-mb-km" => AlgoSpec::MbKm(LearningRate::Beta),
            "kmeans" => AlgoSpec::Lloyd,
            other => panic!(
                "unknown algo {other:?} (full-kkm | [b]mb-kkm | [b]trunc-kkm | [b]mb-km | kmeans)"
            ),
        }
    }

    /// Whether the algorithm needs the kernel/gram at all.
    pub fn is_kernelized(&self) -> bool {
        !matches!(self, AlgoSpec::MbKm(_) | AlgoSpec::Lloyd)
    }
}

fn beta_prefix(lr: LearningRate) -> &'static str {
    match lr {
        LearningRate::Beta => "b",
        LearningRate::Sklearn => "",
    }
}

/// One grid cell: everything needed to reproduce a single run.
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Registry dataset name.
    pub dataset: String,
    /// Global dataset scale factor (DESIGN.md §3 substitution).
    pub scale: f64,
    /// Which kernel to build.
    pub kernel: KernelSpec,
    /// Which algorithm to run.
    pub algo: AlgoSpec,
    /// Number of clusters.
    pub k: usize,
    /// Batch size `b` (mini-batch algorithms).
    pub batch_size: usize,
    /// Batch schedule for the mini-batch algorithms (fixed or nested).
    pub schedule: ScheduleSpec,
    /// Truncation parameter τ (Algorithm 2).
    pub tau: usize,
    /// Iteration budget.
    pub max_iters: usize,
    /// ε for early stopping; None = fixed iterations (paper protocol).
    pub epsilon: Option<f64>,
    /// RNG seed (dataset + run streams derive from it).
    pub seed: u64,
    /// Numerics mode for the gram fills (DESIGN.md §13). Deterministic is
    /// the default and the only mode conformance/repro artifacts use; Fast
    /// batches the exp finish through the SIMD lanes (≤ 4 ulp per value).
    pub numerics: NumericsMode,
}

impl RunSpec {
    /// Compact one-line cell description for logs.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{} b={} sched={} tau={} seed={}",
            self.dataset,
            self.kernel.name(),
            self.algo.name(),
            self.batch_size,
            self.schedule.label(),
            self.tau,
            self.seed
        )
    }

    /// Canonical string naming everything that affects the fit's bit
    /// stream. Stored in every checkpoint and compared at `--resume auto`
    /// time, so state from a different run configuration can never be
    /// replayed into this one (the `v2|` prefix versions the encoding
    /// itself — v2 added the numerics field, which changes gram bits in
    /// Fast mode and so must invalidate Deterministic checkpoints and vice
    /// versa). Exhaustive over the spec's fields on purpose — a field
    /// that *doesn't* change results (there is none today) would merely
    /// force a fresh start, which is safe; the reverse is not.
    pub fn fingerprint(&self) -> String {
        let kernel = match self.kernel {
            KernelSpec::Gaussian { multiplier } => format!("gaussian:{multiplier}"),
            KernelSpec::Knn { neighbors } => format!("knn:{neighbors}"),
            KernelSpec::Heat { neighbors, t } => format!("heat:{neighbors}:{t}"),
        };
        format!(
            "v2|ds={}|scale={}|kernel={}|algo={}|k={}|b={}|sched={}|tau={}|iters={}|eps={:?}|seed={}|num={}",
            self.dataset,
            self.scale,
            kernel,
            self.algo.name(),
            self.k,
            self.batch_size,
            self.schedule.label(),
            self.tau,
            self.max_iters,
            self.epsilon,
            self.seed,
            self.numerics.name()
        )
    }
}

/// Metrics from one run.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    /// Adjusted Rand Index against ground truth (NaN when unlabeled).
    pub ari: f64,
    /// Normalized Mutual Information against ground truth (NaN when unlabeled).
    pub nmi: f64,
    /// Final full-dataset objective `f_X(C)`.
    pub objective: f64,
    /// Iterations executed.
    pub iterations: usize,
    /// Whether the ε early-stopping condition fired.
    pub converged: bool,
    /// Clustering wall-clock (excludes kernel construction).
    pub cluster_secs: f64,
    /// Kernel/gram construction wall-clock (the paper's black bars).
    pub kernel_secs: f64,
    /// γ of the gram (Table 1).
    pub gamma: f64,
    /// The ε stop rule's recorded decision sequence (empty without ε) —
    /// replayable evidence for how termination was reached.
    pub decisions: Vec<TerminationDecision>,
    /// The fit's per-phase timing breakdown (init/refresh/assign/moments/
    /// update/stopping/finalize for the mini-batch algorithms) — surfaced
    /// by the CLI's `--profile` flag.
    pub profiler: Profiler,
}

/// k-means++ candidate cap for coordinator-driven *mini-batch* runs: above
/// this n the init switches to D² sampling over a uniform subsample (the
/// paper's "any reasonable initialization" covers this) — full-candidate
/// k-means++ at streaming scale would pay k·n single-column kernel fetches
/// before the first iteration even starts. Full-batch runs keep the full
/// k-means++ (their per-iteration cost dwarfs it).
pub const INIT_SAMPLE_THRESHOLD: usize = 65_536;

/// Mini-batch init policy: full kernel k-means++ up to
/// [`INIT_SAMPLE_THRESHOLD`] candidates, sampled k-means++ above it.
fn default_init(n: usize) -> Init {
    if n > INIT_SAMPLE_THRESHOLD {
        Init::KMeansPlusPlusOnSample(INIT_SAMPLE_THRESHOLD)
    } else {
        Init::KMeansPlusPlus
    }
}

/// Execute a run against a pre-built dataset + gram (lets the figure driver
/// share one gram across the whole grid). `kernel_secs` is threaded through
/// into the outcome.
///
/// `gram` is `None` exactly when no kernel is needed — the non-kernel
/// algorithms (`mb-km`, `kmeans`) run straight off the features, and the
/// "no gram" case is typed instead of sentinel-valued. Kernelized
/// algorithms panic on `None`.
pub fn run_with_gram(
    spec: &RunSpec,
    ds: &Dataset,
    gram: Option<&dyn KernelProvider>,
    kernel_secs: f64,
) -> RunOutcome {
    let mut rng = Rng::seeded(spec.seed ^ 0x5EED);
    let sw = Stopwatch::start();
    let fit = match spec.algo {
        // Full batch keeps the paper-protocol full k-means++: its O(n·k)
        // init is dwarfed by the O(n²) iterations, and sampling would
        // change results for forced large-n materialized runs.
        AlgoSpec::FullKkm => FullBatchKernelKMeans::new(FullBatchConfig {
            k: spec.k,
            max_iters: spec.max_iters,
            epsilon: spec.epsilon,
            init: Init::KMeansPlusPlus,
            weights: None,
        })
        .fit(gram.expect("kernelized algorithm requires a gram provider"), &mut rng),
        AlgoSpec::MbKkm(lr) => MiniBatchKernelKMeans::new(MiniBatchConfig {
            k: spec.k,
            batch_size: spec.batch_size,
            schedule: spec.schedule,
            max_iters: spec.max_iters,
            epsilon: spec.epsilon,
            termination: TerminationMode::default(),
            learning_rate: lr,
            init: default_init(ds.n),
            weights: None,
        })
        .fit(gram.expect("kernelized algorithm requires a gram provider"), &mut rng),
        AlgoSpec::TruncKkm(lr) => TruncatedMiniBatchKernelKMeans::new(TruncatedConfig {
            k: spec.k,
            batch_size: spec.batch_size,
            schedule: spec.schedule,
            tau: spec.tau,
            max_iters: spec.max_iters,
            epsilon: spec.epsilon,
            termination: TerminationMode::default(),
            learning_rate: lr,
            init: default_init(ds.n),
            weights: None,
        })
        .fit(gram.expect("kernelized algorithm requires a gram provider"), &mut rng),
        AlgoSpec::MbKm(lr) => MiniBatchKMeans::new(MiniBatchKMeansConfig {
            k: spec.k,
            batch_size: spec.batch_size,
            max_iters: spec.max_iters,
            epsilon: spec.epsilon,
            learning_rate: lr,
        })
        .fit(ds, &mut rng),
        AlgoSpec::Lloyd => KMeans::new(KMeansConfig {
            k: spec.k,
            max_iters: spec.max_iters,
            epsilon: spec.epsilon,
        })
        .fit(ds, &mut rng),
    };
    let cluster_secs = sw.secs();
    let (ari_v, nmi_v) = match &ds.labels {
        Some(truth) => (ari(truth, &fit.assignments), nmi(truth, &fit.assignments)),
        None => (f64::NAN, f64::NAN),
    };
    RunOutcome {
        ari: ari_v,
        nmi: nmi_v,
        objective: fit.objective,
        iterations: fit.iterations,
        converged: fit.converged,
        cluster_secs,
        kernel_secs,
        gamma: gram.map(|g| g.gamma()).unwrap_or(f64::NAN),
        decisions: fit.decisions,
        profiler: fit.profiler,
    }
}

/// Execute a fully self-contained run under the default [`GramStrategy`]
/// (materialize below the table threshold, stream above it).
pub fn run_one(spec: &RunSpec) -> RunOutcome {
    run_one_with(spec, GramStrategy::default())
}

/// [`run_one`] with an explicit gram-realization strategy (the CLI threads
/// `--stream` / `--cache-mb` through here).
pub fn run_one_with(spec: &RunSpec, strategy: GramStrategy) -> RunOutcome {
    let ds = registry::load(&spec.dataset, spec.scale, spec.seed);
    run_on_dataset(spec, &ds, strategy).0
}

/// How the gram was realized for a run — the CLI surfaces this next to the
/// outcome.
pub struct GramReport {
    /// Provider display name.
    pub label: String,
    /// `"materialized"` or `"streaming"`.
    pub mode: &'static str,
    /// Tile-cache counters (streaming mode only).
    pub cache: Option<CacheStats>,
}

/// Execute a run against an already-loaded dataset under a strategy —
/// the single code path behind both [`run_one_with`] and the CLI `run`
/// subcommand (which loads datasets from CSV too), so the rng derivation,
/// strategy resolution, and build order can never drift between them.
/// Returns `None` for the report when the algorithm needs no kernel.
pub fn run_on_dataset(
    spec: &RunSpec,
    ds: &Dataset,
    strategy: GramStrategy,
) -> (RunOutcome, Option<GramReport>) {
    if spec.algo.is_kernelized() {
        let strategy = strategy.resolve(spec.algo, ds.n);
        let mut rng = Rng::seeded(spec.seed ^ 0xC0DE);
        let (built, kernel_secs) =
            spec.kernel.build_with(ds, &mut rng, strategy, spec.numerics);
        let outcome = run_with_gram(spec, ds, Some(built.provider()), kernel_secs);
        let report = GramReport {
            label: built.provider().label(),
            mode: built.mode(),
            cache: built.cache_stats(),
        };
        (outcome, Some(report))
    } else {
        // Non-kernel algorithms: no gram is ever built (typed, not dummy).
        (run_with_gram(spec, ds, None, 0.0), None)
    }
}

/// How a checkpointed run treats snapshots already in the directory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResumeMode {
    /// Resume from the newest checksum-valid snapshot whose fingerprint
    /// matches, falling back past torn/corrupt files; start fresh if none.
    Auto,
    /// Ignore existing snapshots and start from iteration 0 (new
    /// snapshots still overwrite the directory as training progresses).
    Never,
}

/// [`run_on_dataset`] with durable checkpointing (DESIGN.md §12): the
/// trainer snapshots its full state every `ckpt.every` iterations through
/// [`checkpoint::save_snapshot`], and `ResumeMode::Auto` restarts from the
/// newest valid snapshot, replaying only the iteration suffix. The
/// outcome is **bit-identical** to the plain run — checkpointing only
/// reads trainer state, and a resume restores the RNG mid-stream — which
/// the module tests and the CI chaos job both pin.
///
/// Truncated-algorithm only: it is the one trainer whose complete state
/// (windows + RNG + stopper log) is snapshot-able in `O(k·τ)`.
pub fn run_on_dataset_checkpointed(
    spec: &RunSpec,
    ds: &Dataset,
    strategy: GramStrategy,
    ckpt: &CheckpointConfig,
    resume: ResumeMode,
) -> crate::util::error::Result<(RunOutcome, Option<GramReport>)> {
    let AlgoSpec::TruncKkm(lr) = spec.algo else {
        bail!(
            "--checkpoint-dir supports the truncated algorithm only \
             ([b]trunc-kkm): it is the one trainer whose complete state is \
             snapshot-able in O(k·tau) (got {})",
            spec.algo.name()
        );
    };
    let strategy = strategy.resolve(spec.algo, ds.n);
    let mut krng = Rng::seeded(spec.seed ^ 0xC0DE);
    let (built, kernel_secs) =
        spec.kernel.build_with(ds, &mut krng, strategy, spec.numerics);
    let fp = spec.fingerprint();
    let resume_snap = match resume {
        ResumeMode::Auto => checkpoint::load_latest(&ckpt.dir, &fp, ds.n)?.map(|(snap, path)| {
            eprintln!(
                "mbkk: resuming from checkpoint {} (iteration {})",
                path.display(),
                snap.iterations()
            );
            snap
        }),
        ResumeMode::Never => None,
    };
    let mut rng = Rng::seeded(spec.seed ^ 0x5EED);
    let sw = Stopwatch::start();
    let fit = TruncatedMiniBatchKernelKMeans::new(TruncatedConfig {
        k: spec.k,
        batch_size: spec.batch_size,
        schedule: spec.schedule,
        tau: spec.tau,
        max_iters: spec.max_iters,
        epsilon: spec.epsilon,
        termination: TerminationMode::default(),
        learning_rate: lr,
        init: default_init(ds.n),
        weights: None,
    })
    .fit_with_backend_resumable(
        built.provider(),
        &mut NativeBackend,
        &mut rng,
        resume_snap,
        ckpt.every,
        &mut |snap| checkpoint::save_snapshot(ckpt, snap, &fp, ds.n),
    )?;
    let cluster_secs = sw.secs();
    let res = fit.result;
    let (ari_v, nmi_v) = match &ds.labels {
        Some(truth) => (ari(truth, &res.assignments), nmi(truth, &res.assignments)),
        None => (f64::NAN, f64::NAN),
    };
    let outcome = RunOutcome {
        ari: ari_v,
        nmi: nmi_v,
        objective: res.objective,
        iterations: res.iterations,
        converged: res.converged,
        cluster_secs,
        kernel_secs,
        gamma: built.provider().gamma(),
        decisions: res.decisions,
        profiler: res.profiler,
    };
    let report = GramReport {
        label: built.provider().label(),
        mode: built.mode(),
        cache: built.cache_stats(),
    };
    Ok((outcome, Some(report)))
}

/// A servable fit: the frozen model plus the run metrics and gram report
/// the `run` subcommand would have printed for the same spec.
pub struct ServableFit {
    /// The frozen, persistable model (`KernelKMeansModel::save`).
    pub model: KernelKMeansModel,
    /// Run metrics (identical derivation to [`run_on_dataset`]).
    pub outcome: RunOutcome,
    /// How the training gram was realized.
    pub report: GramReport,
}

/// Train a servable model — the `fit` CLI path of the fit→persist→serve
/// split (DESIGN.md §8).
///
/// Runs the truncated algorithm (the only variant whose centers are
/// sliding windows [`KernelKMeansModel::freeze`] can detach from the
/// training set) against a *feature* kernel, then freezes the final
/// windows into a model. Graph kernels are rejected: they are defined on
/// the training graph only and have no out-of-sample extension to serve.
///
/// RNG derivation (kernel stream `seed ^ 0xC0DE`, fit stream
/// `seed ^ 0x5EED`) and gram realization match [`run_on_dataset`]
/// exactly, so `fit` reproduces the metrics `run` reports for the same
/// spec — pinned by this module's tests.
pub fn fit_servable_model(
    spec: &RunSpec,
    ds: &Dataset,
    strategy: GramStrategy,
) -> crate::util::error::Result<ServableFit> {
    fit_servable_model_impl(spec, ds, strategy, None)
}

/// [`fit_servable_model`] with durable checkpointing — identical metrics
/// and model (the sink only reads trainer state; resume restores the RNG
/// mid-stream), but a killed `fit` restarts from its newest valid
/// snapshot instead of iteration 0.
pub fn fit_servable_model_checkpointed(
    spec: &RunSpec,
    ds: &Dataset,
    strategy: GramStrategy,
    ckpt: &CheckpointConfig,
    resume: ResumeMode,
) -> crate::util::error::Result<ServableFit> {
    fit_servable_model_impl(spec, ds, strategy, Some((ckpt, resume)))
}

fn fit_servable_model_impl(
    spec: &RunSpec,
    ds: &Dataset,
    strategy: GramStrategy,
    ckpt: Option<(&CheckpointConfig, ResumeMode)>,
) -> crate::util::error::Result<ServableFit> {
    let AlgoSpec::TruncKkm(lr) = spec.algo else {
        bail!(
            "fit serves the truncated algorithm only ([b]trunc-kkm): its \
             sliding-window centers are what freeze detaches from the \
             training set (got {})",
            spec.algo.name()
        );
    };
    let KernelSpec::Gaussian { multiplier } = spec.kernel else {
        bail!(
            "fit requires a feature kernel (--kernel gaussian): the {} graph \
             kernel is defined on the training graph only and cannot score \
             unseen points",
            spec.kernel.name()
        );
    };
    let strategy = strategy.resolve(spec.algo, ds.n);
    let mut krng = Rng::seeded(spec.seed ^ 0xC0DE);
    let sw = Stopwatch::start();
    // The same build path `run_on_dataset` reaches through build_with, fed
    // by the same seed derivation — fit and run cannot drift.
    let (built, func) = build_gaussian(ds, &mut krng, multiplier, strategy, spec.numerics);
    let kernel_secs = sw.secs();

    let mut fit_rng = Rng::seeded(spec.seed ^ 0x5EED);
    let sw = Stopwatch::start();
    let algo = TruncatedMiniBatchKernelKMeans::new(TruncatedConfig {
        k: spec.k,
        batch_size: spec.batch_size,
        schedule: spec.schedule,
        tau: spec.tau,
        max_iters: spec.max_iters,
        epsilon: spec.epsilon,
        termination: TerminationMode::default(),
        learning_rate: lr,
        init: default_init(ds.n),
        weights: None,
    });
    let mut fit = match ckpt {
        None => algo.fit_with_backend(built.provider(), &mut NativeBackend, &mut fit_rng),
        Some((cfg, resume)) => {
            let fp = spec.fingerprint();
            let resume_snap = match resume {
                ResumeMode::Auto => {
                    checkpoint::load_latest(&cfg.dir, &fp, ds.n)?.map(|(snap, path)| {
                        eprintln!(
                            "mbkk: resuming from checkpoint {} (iteration {})",
                            path.display(),
                            snap.iterations()
                        );
                        snap
                    })
                }
                ResumeMode::Never => None,
            };
            algo.fit_with_backend_resumable(
                built.provider(),
                &mut NativeBackend,
                &mut fit_rng,
                resume_snap,
                cfg.every,
                &mut |snap| checkpoint::save_snapshot(cfg, snap, &fp, ds.n),
            )?
        }
    };
    let cluster_secs = sw.secs();

    let model = KernelKMeansModel::freeze(ds, func, &mut fit.centers);
    let (ari_v, nmi_v) = match &ds.labels {
        Some(t) => (ari(t, &fit.result.assignments), nmi(t, &fit.result.assignments)),
        None => (f64::NAN, f64::NAN),
    };
    Ok(ServableFit {
        model,
        outcome: RunOutcome {
            ari: ari_v,
            nmi: nmi_v,
            objective: fit.result.objective,
            iterations: fit.result.iterations,
            converged: fit.result.converged,
            cluster_secs,
            kernel_secs,
            gamma: built.provider().gamma(),
            decisions: fit.result.decisions.clone(),
            profiler: fit.result.profiler.clone(),
        },
        report: GramReport {
            label: built.provider().label(),
            mode: built.mode(),
            cache: built.cache_stats(),
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_spec(algo: AlgoSpec) -> RunSpec {
        RunSpec {
            dataset: "blobs".into(),
            scale: 0.05,
            kernel: KernelSpec::Gaussian { multiplier: 1.0 },
            algo,
            k: 5,
            batch_size: 64,
            schedule: ScheduleSpec::Fixed,
            tau: 50,
            max_iters: 20,
            epsilon: None,
            seed: 3,
            numerics: NumericsMode::Deterministic,
        }
    }

    #[test]
    fn every_algorithm_runs_end_to_end() {
        for algo in [
            AlgoSpec::FullKkm,
            AlgoSpec::MbKkm(LearningRate::Beta),
            AlgoSpec::TruncKkm(LearningRate::Beta),
            AlgoSpec::TruncKkm(LearningRate::Sklearn),
            AlgoSpec::MbKm(LearningRate::Beta),
            AlgoSpec::Lloyd,
        ] {
            let out = run_one(&base_spec(algo));
            assert!(out.ari.is_finite(), "{algo:?}");
            assert!(out.objective.is_finite(), "{algo:?}");
            assert!(out.cluster_secs >= 0.0);
            // blobs at separation 3 should cluster reasonably with any algo.
            assert!(out.ari > 0.3, "{algo:?}: ARI={}", out.ari);
        }
    }

    #[test]
    fn kernel_names_roundtrip() {
        for name in ["gaussian", "knn", "heat"] {
            assert_eq!(KernelSpec::from_name(name).name(), name);
        }
    }

    #[test]
    fn algo_names_roundtrip() {
        for name in [
            "full-kkm", "mb-kkm", "bmb-kkm", "trunc-kkm", "btrunc-kkm", "mb-km",
            "bmb-km", "kmeans",
        ] {
            assert_eq!(AlgoSpec::from_name(name).name(), name);
        }
    }

    #[test]
    fn knn_kernel_run_has_small_gamma() {
        let mut spec = base_spec(AlgoSpec::TruncKkm(LearningRate::Beta));
        spec.kernel = KernelSpec::Knn { neighbors: 8 };
        let out = run_one(&spec);
        assert!(out.gamma < 0.5, "knn gamma should be ≪ 1, got {}", out.gamma);
        assert!(out.ari.is_finite());
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = base_spec(AlgoSpec::TruncKkm(LearningRate::Beta));
        let a = run_one(&spec);
        let b = run_one(&spec);
        assert_eq!(a.ari, b.ari);
        assert_eq!(a.objective, b.objective);
    }

    #[test]
    fn auto_policy_thresholds_on_table_bytes() {
        let auto = GramStrategy::default();
        assert!(auto.materializes(1000));
        assert!(auto.materializes(23_000)); // 23k² ×4 ≈ 2.1e9... just below 2^31
        assert!(!auto.materializes(24_000));
        assert!(!auto.materializes(1_000_000));
        assert!(GramStrategy::Materialize.materializes(1_000_000));
        assert!(!GramStrategy::Stream { cache_mb: 8 }.materializes(100));
        assert_eq!(GramStrategy::Stream { cache_mb: 8 }.cache_bytes(), 8 << 20);
    }

    #[test]
    fn streaming_run_matches_materialized_bit_for_bit() {
        // The tentpole contract at coordinator level: forcing the streaming
        // provider must reproduce the materialized run exactly — same
        // assignments drive the same ARI, and the objective bits agree.
        for algo in [
            AlgoSpec::MbKkm(LearningRate::Beta),
            AlgoSpec::TruncKkm(LearningRate::Beta),
        ] {
            let spec = base_spec(algo);
            let mat = run_one_with(&spec, GramStrategy::Materialize);
            let stream = run_one_with(&spec, GramStrategy::Stream { cache_mb: 8 });
            assert_eq!(mat.ari.to_bits(), stream.ari.to_bits(), "{algo:?}");
            assert_eq!(mat.nmi.to_bits(), stream.nmi.to_bits(), "{algo:?}");
            assert_eq!(
                mat.objective.to_bits(),
                stream.objective.to_bits(),
                "{algo:?}"
            );
            assert_eq!(mat.gamma.to_bits(), stream.gamma.to_bits(), "{algo:?}");
        }
    }

    #[test]
    fn auto_policy_streams_when_table_would_not_fit() {
        // Shrinking the table budget to nothing forces the streaming path;
        // the outcome must still be the materialized one, bit for bit.
        let spec = base_spec(AlgoSpec::TruncKkm(LearningRate::Beta));
        let forced = run_one_with(
            &spec,
            GramStrategy::Auto { max_table_bytes: 0, cache_mb: 4 },
        );
        let mat = run_one_with(&spec, GramStrategy::Materialize);
        assert_eq!(forced.objective.to_bits(), mat.objective.to_bits());
        assert_eq!(forced.ari.to_bits(), mat.ari.to_bits());
    }

    #[test]
    fn non_kernel_runs_build_no_gram() {
        // The typed no-kernel path: gamma is NaN (nothing to measure) and
        // kernel_secs is exactly zero because no gram was ever built.
        let out = run_one(&base_spec(AlgoSpec::Lloyd));
        assert!(out.gamma.is_nan());
        assert_eq!(out.kernel_secs, 0.0);
        assert!(out.ari.is_finite());
    }

    #[test]
    #[should_panic(expected = "not supported for the knn kernel")]
    fn stream_strategy_rejects_graph_kernels() {
        let mut spec = base_spec(AlgoSpec::TruncKkm(LearningRate::Beta));
        spec.kernel = KernelSpec::Knn { neighbors: 8 };
        let _ = run_one_with(&spec, GramStrategy::Stream { cache_mb: 8 });
    }

    #[test]
    fn full_batch_always_resolves_to_materialize() {
        let auto = GramStrategy::default();
        assert_eq!(
            auto.resolve(AlgoSpec::FullKkm, 500),
            GramStrategy::Materialize
        );
        // Mini-batch algorithms pass through unchanged.
        assert_eq!(auto.resolve(AlgoSpec::TruncKkm(LearningRate::Beta), 500), auto);
        assert_eq!(auto.resolve(AlgoSpec::MbKkm(LearningRate::Beta), 500), auto);
    }

    #[test]
    #[should_panic(expected = "not supported for full-kkm")]
    fn stream_strategy_rejects_full_batch() {
        let _ = GramStrategy::Stream { cache_mb: 8 }.resolve(AlgoSpec::FullKkm, 500);
    }

    #[test]
    fn fit_servable_model_reproduces_run_metrics_and_assignments() {
        // fit and run share the exact rng derivation and gram realization,
        // so their metrics must agree to the bit; the frozen model must
        // reproduce the training assignments on the training points.
        let spec = base_spec(AlgoSpec::TruncKkm(LearningRate::Beta));
        let ds = registry::load(&spec.dataset, spec.scale, spec.seed);
        let strategy = GramStrategy::default();
        let fit = fit_servable_model(&spec, &ds, strategy).expect("servable fit");
        let (run, _) = run_on_dataset(&spec, &ds, strategy);
        assert_eq!(fit.outcome.ari.to_bits(), run.ari.to_bits());
        assert_eq!(fit.outcome.objective.to_bits(), run.objective.to_bits());
        assert_eq!(fit.outcome.iterations, run.iterations);
        assert_eq!(fit.model.k(), spec.k);
        assert!(fit.model.support_points() > 0);
        let pred = fit.model.predict_all(&ds);
        let score = ari(ds.labels.as_ref().unwrap(), &pred);
        assert!(score > 0.3, "served ARI={score}");
    }

    #[test]
    fn fit_servable_model_rejects_unservable_specs() {
        let ds = registry::load("blobs", 0.05, 3);
        let mut graph_spec = base_spec(AlgoSpec::TruncKkm(LearningRate::Beta));
        graph_spec.kernel = KernelSpec::Knn { neighbors: 8 };
        let err = fit_servable_model(&graph_spec, &ds, GramStrategy::default())
            .unwrap_err();
        assert!(format!("{err}").contains("feature kernel"), "{err}");

        let full_spec = base_spec(AlgoSpec::FullKkm);
        let err =
            fit_servable_model(&full_spec, &ds, GramStrategy::default()).unwrap_err();
        assert!(format!("{err}").contains("truncated algorithm"), "{err}");
    }

    fn ckpt_dir(tag: &str) -> std::path::PathBuf {
        let dir =
            std::env::temp_dir().join(format!("mbkk-exp-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn fingerprints_separate_specs_and_are_stable() {
        let a = base_spec(AlgoSpec::TruncKkm(LearningRate::Beta));
        assert_eq!(a.fingerprint(), a.clone().fingerprint());
        let mut b = a.clone();
        b.seed += 1;
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut c = a.clone();
        c.kernel = KernelSpec::Gaussian { multiplier: 2.0 };
        assert_ne!(a.fingerprint(), c.fingerprint());
        // Fast mode changes gram bits, so it must invalidate checkpoints.
        let mut d = a.clone();
        d.numerics = NumericsMode::Fast;
        assert_ne!(a.fingerprint(), d.fingerprint());
    }

    #[test]
    fn fast_numerics_runs_match_deterministic_closely() {
        // End-to-end at the coordinator layer: a Fast-mode fit must land on
        // the same clustering as the Deterministic one. The materialized
        // table is f32-quantized after the fill, so the ≤4-ulp f64 exp
        // difference almost always rounds away entirely; bound loosely
        // anyway in case a value sits on an f32 rounding boundary.
        let det = base_spec(AlgoSpec::TruncKkm(LearningRate::Beta));
        let mut fast = det.clone();
        fast.numerics = NumericsMode::Fast;
        let a = run_one(&det);
        let b = run_one(&fast);
        assert!(
            (a.objective - b.objective).abs() <= 1e-3 * a.objective.abs(),
            "det={} fast={}",
            a.objective,
            b.objective
        );
        assert!((a.ari - b.ari).abs() < 0.05, "det={} fast={}", a.ari, b.ari);
        assert!(b.ari > 0.3, "fast ARI={}", b.ari);
    }

    #[test]
    fn checkpointed_run_is_bit_identical_and_resumes() {
        let mut spec = base_spec(AlgoSpec::TruncKkm(LearningRate::Beta));
        spec.epsilon = Some(1e-9); // exercise the stopper-replay path too
        let ds = registry::load(&spec.dataset, spec.scale, spec.seed);
        let dir = ckpt_dir("run");
        let ckpt = CheckpointConfig { dir: dir.clone(), every: 5, keep: 2 };
        let (plain, _) = run_on_dataset(&spec, &ds, GramStrategy::default());
        // Checkpointing changes nothing about the outcome.
        let (checked, _) = run_on_dataset_checkpointed(
            &spec, &ds, GramStrategy::default(), &ckpt, ResumeMode::Never,
        )
        .unwrap();
        assert_eq!(plain.objective.to_bits(), checked.objective.to_bits());
        assert_eq!(plain.ari.to_bits(), checked.ari.to_bits());
        assert_eq!(plain.iterations, checked.iterations);
        // Snapshots landed on disk; resuming from the newest one replays
        // only the iteration suffix, bit-identically (this is exactly the
        // crash-recovery path: kill after the last checkpoint, rerun).
        let (resumed, _) = run_on_dataset_checkpointed(
            &spec, &ds, GramStrategy::default(), &ckpt, ResumeMode::Auto,
        )
        .unwrap();
        assert_eq!(plain.objective.to_bits(), resumed.objective.to_bits());
        assert_eq!(plain.ari.to_bits(), resumed.ari.to_bits());
        assert_eq!(plain.iterations, resumed.iterations);
        // A different spec pointed at the same directory is a hard error,
        // never a silent fresh start.
        let mut other = spec.clone();
        other.seed = 999;
        let err = run_on_dataset_checkpointed(
            &other, &ds, GramStrategy::default(), &ckpt, ResumeMode::Auto,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("different run configuration"), "{err}");
        // Non-truncated algorithms are rejected with a clear message.
        let full = base_spec(AlgoSpec::FullKkm);
        let err = run_on_dataset_checkpointed(
            &full, &ds, GramStrategy::default(), &ckpt, ResumeMode::Never,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("truncated algorithm"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpointed_fit_matches_plain_fit() {
        let spec = base_spec(AlgoSpec::TruncKkm(LearningRate::Beta));
        let ds = registry::load(&spec.dataset, spec.scale, spec.seed);
        let dir = ckpt_dir("fit");
        let ckpt = CheckpointConfig::new(dir.clone(), 6);
        let plain = fit_servable_model(&spec, &ds, GramStrategy::default()).unwrap();
        let fresh = fit_servable_model_checkpointed(
            &spec, &ds, GramStrategy::default(), &ckpt, ResumeMode::Never,
        )
        .unwrap();
        let resumed = fit_servable_model_checkpointed(
            &spec, &ds, GramStrategy::default(), &ckpt, ResumeMode::Auto,
        )
        .unwrap();
        for fit in [&fresh, &resumed] {
            assert_eq!(plain.outcome.objective.to_bits(), fit.outcome.objective.to_bits());
            assert_eq!(plain.outcome.ari.to_bits(), fit.outcome.ari.to_bits());
            assert_eq!(plain.outcome.iterations, fit.outcome.iterations);
        }
        // The frozen models serve identical assignments.
        assert_eq!(plain.model.predict_all(&ds), resumed.model.predict_all(&ds));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[should_panic(expected = "cannot stream")]
    fn auto_budget_fails_fast_for_oversized_graph_kernels() {
        // A graph kernel whose dense table blows the Auto budget must error
        // before the O(n²) adjacency build starts, not OOM inside it.
        let mut spec = base_spec(AlgoSpec::TruncKkm(LearningRate::Beta));
        spec.kernel = KernelSpec::Heat { neighbors: 8, t: 10.0 };
        let _ = run_one_with(
            &spec,
            GramStrategy::Auto { max_table_bytes: 0, cache_mb: 4 },
        );
    }
}
