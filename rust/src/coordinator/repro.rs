//! The `repro-speedup` preset: reproduce the paper's headline claim.
//!
//! The paper's Table 1 / Figure 1 story is that mini-batch kernel k-means
//! reaches full-batch clustering quality 10–100× faster, terminating in
//! `O(γ²/ε)` iterations under the ε stopping rule. This module runs that
//! comparison end to end across the registry's paper-proxy datasets:
//! full-batch vs Algorithm 1 and Algorithm 2, each under the fixed-b and
//! nested (geometric-growth) batch schedules, all with the same ε so
//! iterations-to-terminate is comparable.
//!
//! Two artifacts come out of a run:
//!
//! * a **deterministic** table (`repro_speedup.csv`) — ARI, objective,
//!   iterations, convergence flag. Same seed ⇒ byte-identical file, pinned
//!   by `rust/tests/repro_determinism.rs`; this is the committed
//!   reproduction deliverable (`docs/repro/`).
//! * a **timing** table (`repro_speedup_timings.csv` + markdown) —
//!   wall-clock and speedup-vs-full-batch, machine-dependent by nature and
//!   therefore kept out of the deterministic artifact.

use super::experiment::{run_with_gram, AlgoSpec, KernelSpec, RunOutcome, RunSpec};
use crate::data::registry;
use crate::kkmeans::{LearningRate, ScheduleSpec};
use crate::util::error::{Context, Result};
use crate::util::rng::Rng;
use std::path::Path;

/// Knobs for a reproduction run; [`ReproOptions::default`] mirrors the
/// paper's protocol at the repo's default proxy scale.
#[derive(Clone, Debug)]
pub struct ReproOptions {
    /// Registry datasets to sweep (default: the four paper proxies).
    pub datasets: Vec<String>,
    /// Dataset scale factor (DESIGN.md §3 substitution).
    pub scale: f64,
    /// Master seed; every run derives from it.
    pub seed: u64,
    /// Mini-batch size `b` (the nested schedules start here).
    pub batch_size: usize,
    /// Truncation parameter τ for Algorithm 2 rows.
    pub tau: usize,
    /// Iteration ceiling for every run.
    pub max_iters: usize,
    /// ε for the termination rule (shared by every row, so
    /// iterations-to-terminate is comparable).
    pub epsilon: f64,
    /// Growth factor for the nested-schedule rows.
    pub growth: f64,
}

impl Default for ReproOptions {
    fn default() -> Self {
        ReproOptions {
            datasets: registry::PAPER_PROXIES.iter().map(|s| s.to_string()).collect(),
            scale: 0.15,
            seed: 0,
            batch_size: 256,
            tau: 200,
            max_iters: 300,
            epsilon: 1e-3,
            growth: 2.0,
        }
    }
}

/// One row of the reproduction table: one (dataset, algorithm, schedule)
/// cell plus the full-batch baseline it is compared against.
#[derive(Clone, Debug)]
pub struct ReproRow {
    /// Registry dataset name.
    pub dataset: String,
    /// Algorithm short name (`full-kkm`, `bmb-kkm`, `btrunc-kkm`).
    pub algo: String,
    /// Schedule label (`full`, `fixed`, `nested(g=2)`).
    pub schedule: String,
    /// Batch size (0 for full batch — every point, every iteration).
    pub batch_size: usize,
    /// τ (`usize::MAX` — printed as `inf` — when untruncated).
    pub tau: usize,
    /// Adjusted Rand Index against ground truth.
    pub ari: f64,
    /// Final full-dataset objective.
    pub objective: f64,
    /// Iterations until termination (ε rule or ceiling).
    pub iterations: usize,
    /// Whether the ε rule fired before the ceiling.
    pub converged: bool,
    /// Clustering wall-clock seconds (excludes kernel build).
    pub cluster_secs: f64,
    /// Kernel build wall-clock seconds.
    pub kernel_secs: f64,
    /// Full-batch cluster time ÷ this row's cluster time (1.0 for the
    /// baseline row itself).
    pub speedup: f64,
}

fn tau_str(tau: usize) -> String {
    if tau == usize::MAX {
        "inf".into()
    } else {
        tau.to_string()
    }
}

fn spec_for(opts: &ReproOptions, dataset: &str, algo: AlgoSpec, schedule: ScheduleSpec) -> RunSpec {
    RunSpec {
        dataset: dataset.to_string(),
        scale: opts.scale,
        kernel: KernelSpec::Gaussian { multiplier: 1.0 },
        algo,
        k: registry::default_k(dataset),
        batch_size: opts.batch_size,
        schedule,
        tau: opts.tau,
        max_iters: opts.max_iters,
        epsilon: Some(opts.epsilon),
        seed: opts.seed,
        // Repro artifacts are conformance evidence: always deterministic.
        numerics: crate::kernels::NumericsMode::Deterministic,
    }
}

fn row_from(
    dataset: &str,
    algo_name: &str,
    schedule: &str,
    batch_size: usize,
    tau: usize,
    out: &RunOutcome,
    full_secs: f64,
) -> ReproRow {
    ReproRow {
        dataset: dataset.to_string(),
        algo: algo_name.to_string(),
        schedule: schedule.to_string(),
        batch_size,
        tau,
        ari: out.ari,
        objective: out.objective,
        iterations: out.iterations,
        converged: out.converged,
        cluster_secs: out.cluster_secs,
        kernel_secs: out.kernel_secs,
        speedup: full_secs / out.cluster_secs.max(1e-12),
    }
}

/// Run the full reproduction sweep: for each dataset, the gram is built
/// once (materialized — the paper's protocol) and shared by the
/// full-batch baseline and the four mini-batch cells.
pub fn run_repro(opts: &ReproOptions) -> Vec<ReproRow> {
    let mut rows = Vec::new();
    let nested = ScheduleSpec::Nested { growth: opts.growth };
    for dataset in &opts.datasets {
        let ds = registry::load(dataset, opts.scale, opts.seed);
        let mut krng = Rng::seeded(opts.seed ^ 0xC0DE);
        let kernel = KernelSpec::Gaussian { multiplier: 1.0 };
        let (gram, kernel_secs) = kernel.build(&ds, &mut krng);
        eprintln!(
            "[repro] {dataset}: n={} k={} kernel {kernel_secs:.2}s",
            ds.n,
            registry::default_k(dataset)
        );

        let mut full_spec = spec_for(opts, dataset, AlgoSpec::FullKkm, ScheduleSpec::Fixed);
        // Full batch visits every point every iteration; a mini-batch
        // ceiling would be uselessly generous for it, so reuse the same
        // ceiling but let ε (or Lloyd fixed-point) stop it early.
        full_spec.tau = usize::MAX;
        let full = run_with_gram(&full_spec, &ds, Some(&gram), kernel_secs);
        let full_secs = full.cluster_secs;
        rows.push(row_from(dataset, "full-kkm", "full", 0, usize::MAX, &full, full_secs));

        let cells: [(AlgoSpec, ScheduleSpec, usize); 4] = [
            (AlgoSpec::MbKkm(LearningRate::Beta), ScheduleSpec::Fixed, usize::MAX),
            (AlgoSpec::MbKkm(LearningRate::Beta), nested, usize::MAX),
            (AlgoSpec::TruncKkm(LearningRate::Beta), ScheduleSpec::Fixed, opts.tau),
            (AlgoSpec::TruncKkm(LearningRate::Beta), nested, opts.tau),
        ];
        for (algo, schedule, tau) in cells {
            let mut spec = spec_for(opts, dataset, algo, schedule);
            spec.tau = tau;
            let out = run_with_gram(&spec, &ds, Some(&gram), kernel_secs);
            rows.push(row_from(
                dataset,
                algo.name(),
                &schedule.label(),
                opts.batch_size,
                tau,
                &out,
                full_secs,
            ));
            eprintln!(
                "[repro]   {} {:<12} ARI {:.3} obj {:.5} iters {:>4} {:>7.2}s ({:.1}x)",
                algo.name(),
                schedule.label(),
                out.ari,
                out.objective,
                out.iterations,
                out.cluster_secs,
                full_secs / out.cluster_secs.max(1e-12),
            );
        }
    }
    rows
}

/// Header of the deterministic table.
pub const DETERMINISTIC_HEADER: &str =
    "dataset,algo,schedule,b,tau,ari,objective,iterations,converged";

/// The seed-pinned table: metrics only, no timings. Same seed ⇒ identical
/// bytes (pinned by `rust/tests/repro_determinism.rs`).
pub fn deterministic_csv(rows: &[ReproRow]) -> String {
    let mut s = String::from(DETERMINISTIC_HEADER);
    s.push('\n');
    for r in rows {
        s.push_str(&format!(
            "{},{},{},{},{},{:.6},{:.9},{},{}\n",
            r.dataset,
            r.algo,
            r.schedule,
            r.batch_size,
            tau_str(r.tau),
            r.ari,
            r.objective,
            r.iterations,
            r.converged
        ));
    }
    s
}

/// The machine-dependent table: wall-clock and speedups.
pub fn timing_csv(rows: &[ReproRow]) -> String {
    let mut s = String::from(
        "dataset,algo,schedule,b,tau,cluster_secs,kernel_secs,speedup_vs_full\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{},{},{},{},{},{:.4},{:.4},{:.2}\n",
            r.dataset,
            r.algo,
            r.schedule,
            r.batch_size,
            tau_str(r.tau),
            r.cluster_secs,
            r.kernel_secs,
            r.speedup
        ));
    }
    s
}

/// Markdown table mirroring the paper's Table 1 layout (quality, work, and
/// wall-clock side by side).
pub fn to_markdown(rows: &[ReproRow]) -> String {
    let mut s = String::from(
        "# repro-speedup: full-batch vs mini-batch kernel k-means\n\n\
         | dataset | algorithm | schedule | ARI | objective | iters | converged | cluster s | speedup |\n\
         |---|---|---|---|---|---|---|---|---|\n",
    );
    for r in rows {
        s.push_str(&format!(
            "| {} | {} | {} | {:.3} | {:.5} | {} | {} | {:.2} | {:.1}x |\n",
            r.dataset,
            r.algo,
            r.schedule,
            r.ari,
            r.objective,
            r.iterations,
            r.converged,
            r.cluster_secs,
            r.speedup
        ));
    }
    s
}

/// Write all three artifacts under `out_dir`:
/// `repro_speedup.csv` (deterministic), `repro_speedup_timings.csv`, and
/// `repro_speedup.md`.
pub fn write_artifacts(out_dir: &Path, rows: &[ReproRow]) -> Result<()> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    std::fs::write(out_dir.join("repro_speedup.csv"), deterministic_csv(rows))?;
    std::fs::write(out_dir.join("repro_speedup_timings.csv"), timing_csv(rows))?;
    std::fs::write(out_dir.join("repro_speedup.md"), to_markdown(rows))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ReproOptions {
        ReproOptions {
            datasets: vec!["blobs".into()],
            scale: 0.05,
            seed: 3,
            batch_size: 64,
            tau: 50,
            max_iters: 25,
            epsilon: 1e-3,
            growth: 2.0,
        }
    }

    #[test]
    fn preset_produces_one_baseline_and_four_minibatch_rows() {
        let rows = run_repro(&tiny_opts());
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[0].algo, "full-kkm");
        assert!((rows[0].speedup - 1.0).abs() < 1e-9);
        let schedules: Vec<&str> = rows[1..].iter().map(|r| r.schedule.as_str()).collect();
        assert_eq!(schedules, ["fixed", "nested(g=2)", "fixed", "nested(g=2)"]);
        for r in &rows {
            assert!(r.ari.is_finite() && r.objective.is_finite(), "{r:?}");
            assert!(r.iterations >= 1 && r.iterations <= 25);
        }
    }

    #[test]
    fn csv_shapes_are_consistent() {
        let rows = run_repro(&tiny_opts());
        let det = deterministic_csv(&rows);
        let lines: Vec<&str> = det.trim_end().lines().collect();
        assert_eq!(lines[0], DETERMINISTIC_HEADER);
        assert_eq!(lines.len(), rows.len() + 1);
        for line in &lines[1..] {
            assert_eq!(line.split(',').count(), 9, "bad row: {line}");
        }
        let timing = timing_csv(&rows);
        assert_eq!(timing.trim_end().lines().count(), rows.len() + 1);
        let md = to_markdown(&rows);
        // Header row + one row per run (the |---| separator doesn't match).
        assert_eq!(md.lines().filter(|l| l.starts_with("| ")).count(), rows.len() + 1);
    }
}
