//! Aggregation over repeated runs and CSV/markdown report writers.

use super::experiment::RunOutcome;
use crate::util::error::{Context, Result};
use std::path::Path;

/// Mean/std summary of a metric over repeats.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stat {
    /// Mean over finite values.
    pub mean: f64,
    /// Sample standard deviation over finite values.
    pub std: f64,
}

impl Stat {
    /// Summarize a metric's values (NaNs are filtered, not propagated).
    pub fn of(values: &[f64]) -> Stat {
        let vals: Vec<f64> = values.iter().copied().filter(|v| v.is_finite()).collect();
        if vals.is_empty() {
            return Stat { mean: f64::NAN, std: f64::NAN };
        }
        let n = vals.len() as f64;
        let mean = vals.iter().sum::<f64>() / n;
        let var = if vals.len() > 1 {
            vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / (n - 1.0)
        } else {
            0.0
        };
        Stat { mean, std: var.sqrt() }
    }
}

/// One aggregated row of a figure grid.
#[derive(Clone, Debug)]
pub struct Row {
    /// Figure label, e.g. `fig1`.
    pub figure: String,
    /// Registry dataset name.
    pub dataset: String,
    /// Kernel family name.
    pub kernel: String,
    /// Algorithm name (paper convention).
    pub algo: String,
    /// Batch size `b` of the cell (0 for full batch).
    pub batch_size: usize,
    /// Truncation τ of the cell (0 / `usize::MAX` for untruncated).
    pub tau: usize,
    /// Number of seeds aggregated.
    pub repeats: usize,
    /// ARI over repeats.
    pub ari: Stat,
    /// NMI over repeats.
    pub nmi: Stat,
    /// Final objective over repeats.
    pub objective: Stat,
    /// Clustering wall-clock over repeats (excludes kernel build).
    pub cluster_secs: Stat,
    /// Kernel/gram construction wall-clock (shared across repeats).
    pub kernel_secs: f64,
    /// Iterations executed over repeats.
    pub iterations: Stat,
    /// γ of the gram.
    pub gamma: f64,
}

impl Row {
    /// Aggregate repeated outcomes into a row.
    pub fn aggregate(
        figure: &str,
        dataset: &str,
        kernel: &str,
        algo: &str,
        batch_size: usize,
        tau: usize,
        outcomes: &[RunOutcome],
    ) -> Row {
        let pick = |f: fn(&RunOutcome) -> f64| -> Vec<f64> {
            outcomes.iter().map(f).collect()
        };
        Row {
            figure: figure.to_string(),
            dataset: dataset.to_string(),
            kernel: kernel.to_string(),
            algo: algo.to_string(),
            batch_size,
            tau,
            repeats: outcomes.len(),
            ari: Stat::of(&pick(|o| o.ari)),
            nmi: Stat::of(&pick(|o| o.nmi)),
            objective: Stat::of(&pick(|o| o.objective)),
            cluster_secs: Stat::of(&pick(|o| o.cluster_secs)),
            kernel_secs: outcomes.first().map(|o| o.kernel_secs).unwrap_or(0.0),
            iterations: Stat::of(&pick(|o| o.iterations as f64)),
            gamma: outcomes.first().map(|o| o.gamma).unwrap_or(f64::NAN),
        }
    }
}

/// Header row of the figure CSVs ([`to_csv`]).
pub const CSV_HEADER: &str = "figure,dataset,kernel,algo,b,tau,repeats,\
ari_mean,ari_std,nmi_mean,nmi_std,obj_mean,obj_std,\
cluster_secs_mean,cluster_secs_std,kernel_secs,iters_mean,gamma";

/// Render rows as CSV (with header).
pub fn to_csv(rows: &[Row]) -> String {
    let mut out = String::from(CSV_HEADER);
    out.push('\n');
    for r in rows {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{:.4},{:.4},{:.4},{:.4},{:.6},{:.6},{:.4},{:.4},{:.4},{:.1},{:.6}\n",
            r.figure, r.dataset, r.kernel, r.algo, r.batch_size, r.tau, r.repeats,
            r.ari.mean, r.ari.std, r.nmi.mean, r.nmi.std,
            r.objective.mean, r.objective.std,
            r.cluster_secs.mean, r.cluster_secs.std, r.kernel_secs,
            r.iterations.mean, r.gamma,
        ));
    }
    out
}

/// Render rows as a GitHub-flavoured markdown table (the human-readable
/// companion of the CSV).
pub fn to_markdown(rows: &[Row]) -> String {
    let mut out = String::from(
        "| algo | b | τ | ARI | NMI | cluster s | kernel s |\n|---|---|---|---|---|---|---|\n",
    );
    for r in rows {
        out.push_str(&format!(
            "| {} | {} | {} | {:.3}±{:.3} | {:.3}±{:.3} | {:.2} | {:.2} |\n",
            r.algo,
            r.batch_size,
            if r.tau == usize::MAX { "∞".to_string() } else { r.tau.to_string() },
            r.ari.mean, r.ari.std, r.nmi.mean, r.nmi.std,
            r.cluster_secs.mean, r.kernel_secs,
        ));
    }
    out
}

/// Write CSV + markdown next to each other under `out_dir`.
pub fn write_reports(out_dir: &Path, stem: &str, rows: &[Row]) -> Result<()> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    std::fs::write(out_dir.join(format!("{stem}.csv")), to_csv(rows))?;
    std::fs::write(out_dir.join(format!("{stem}.md")), to_markdown(rows))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(ari: f64, secs: f64) -> RunOutcome {
        RunOutcome {
            ari,
            nmi: ari * 0.9,
            objective: 1.0 - ari,
            iterations: 100,
            converged: false,
            cluster_secs: secs,
            kernel_secs: 2.0,
            gamma: 1.0,
            decisions: Vec::new(),
            profiler: Default::default(),
        }
    }

    #[test]
    fn stat_mean_std() {
        let s = Stat::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.mean, 2.0);
        assert!((s.std - 1.0).abs() < 1e-12);
        let single = Stat::of(&[5.0]);
        assert_eq!(single.mean, 5.0);
        assert_eq!(single.std, 0.0);
        assert!(Stat::of(&[]).mean.is_nan());
        // NaNs are filtered, not propagated.
        let with_nan = Stat::of(&[1.0, f64::NAN, 3.0]);
        assert_eq!(with_nan.mean, 2.0);
    }

    #[test]
    fn aggregate_and_render() {
        let rows = vec![Row::aggregate(
            "fig1",
            "synth_har",
            "gaussian",
            "btrunc-kkm",
            1024,
            200,
            &[outcome(0.8, 1.0), outcome(0.9, 2.0)],
        )];
        let csv = to_csv(&rows);
        assert!(csv.starts_with("figure,"));
        assert!(csv.contains("fig1,synth_har,gaussian,btrunc-kkm,1024,200,2"));
        assert!(csv.contains("0.8500")); // ari mean
        let md = to_markdown(&rows);
        assert!(md.contains("btrunc-kkm"));
        assert!(md.contains("0.850±"));
    }

    #[test]
    fn write_reports_creates_files() {
        let dir = std::env::temp_dir().join("mbkk_report_test");
        let rows = vec![Row::aggregate("t", "d", "k", "a", 1, 1, &[outcome(0.5, 0.1)])];
        write_reports(&dir, "sample", &rows).unwrap();
        assert!(dir.join("sample.csv").exists());
        assert!(dir.join("sample.md").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
