//! # mbkk — Mini-Batch Kernel *k*-Means
//!
//! A production reproduction of **"Mini-Batch Kernel k-means"**
//! (Jourdan & Schwartzman, 2024): the first mini-batch algorithm for kernel
//! k-means, with a truncated variant whose per-iteration cost is `Õ(kb²)` —
//! independent of the dataset size `n` — versus `O(n²)` for the full-batch
//! algorithm.
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — a Pallas gram kernel (`python/compile/kernels/gram.py`) computes
//!   the kernel block `K(B, S)` between a batch and the sliding-window support
//!   points, tiled for TPU VMEM/MXU.
//! * **L2** — a JAX graph (`python/compile/model.py`) composes the gram kernel
//!   into the full assignment step of Algorithm 2 and is AOT-lowered to HLO
//!   text at build time (`make artifacts`).
//! * **L3** — this crate: dataset pipelines, kernel substrates (including the
//!   knn and heat graph kernels), k-means++ initialization, the full-batch and
//!   mini-batch algorithms, sliding-window center state, learning-rate
//!   policies, early stopping, metrics (ARI/NMI), the experiment coordinator
//!   that regenerates every table and figure in the paper, and a PJRT runtime
//!   ([`runtime`]) that executes the AOT artifacts from the hot loop. Python
//!   never runs on the request path.
//!
//! See `examples/` for end-to-end drivers and `DESIGN.md` §2 for the
//! experiment index mapping every figure/table in the paper to a command.

#![warn(missing_docs)]

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod kernels;
pub mod kkmeans;
pub mod kmeans;
pub mod linalg;
pub mod metrics;
pub mod runtime;
pub mod serve;
pub mod testutil;
pub mod util;

/// Crate version, re-exported for the CLI banner.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
