//! Micro-benchmark harness (criterion replacement for this offline build).

pub mod harness;

pub use harness::{BenchRunner, Sample};
