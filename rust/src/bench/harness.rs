//! Criterion-style micro-benchmark harness.
//!
//! Each benchmark target (`rust/benches/*.rs`, `harness = false`) builds a
//! [`BenchRunner`], registers closures, and gets warmup, adaptive iteration
//! counts, and a mean/std/median/min/max report. Results can be dumped as
//! CSV rows (per-suite files under `results/bench/`) and merged into the
//! repo-root `BENCH_baseline.json` perf trajectory
//! ([`BenchRunner::write_baseline`]), so every PR can be compared against
//! the previous snapshot by re-running `cargo bench`.

use crate::util::json::Json;
use crate::util::timing::fmt_secs;
use std::time::Instant;

/// Statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Case name as registered with [`BenchRunner::bench`].
    pub name: String,
    /// Number of measured iterations.
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean: f64,
    /// Sample standard deviation (seconds).
    pub std: f64,
    /// Median seconds per iteration.
    pub median: f64,
    /// Fastest iteration (seconds).
    pub min: f64,
    /// Slowest iteration (seconds).
    pub max: f64,
}

impl Sample {
    fn from_times(name: &str, times: &mut [f64]) -> Sample {
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let mean = times.iter().sum::<f64>() / n as f64;
        let var = times.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / (n.max(2) - 1) as f64;
        Sample {
            name: name.to_string(),
            iters: n,
            mean,
            std: var.sqrt(),
            median: times[n / 2],
            min: times[0],
            max: times[n - 1],
        }
    }
}

/// Benchmark registry + runner.
pub struct BenchRunner {
    title: String,
    /// Target wall-clock per case (seconds); adaptive iteration count aims
    /// for this. Override with MBKK_BENCH_SECS.
    target_secs: f64,
    warmup_iters: usize,
    samples: Vec<Sample>,
    /// Optional filter (substring) from argv, mirroring `cargo bench -- foo`.
    filter: Option<String>,
}

impl BenchRunner {
    /// Create a runner for one bench suite; prints the suite banner.
    pub fn new(title: &str) -> BenchRunner {
        let target_secs = std::env::var("MBKK_BENCH_SECS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1.0);
        // cargo bench passes `--bench`; any other bare arg is a filter.
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-'));
        println!("\n== bench: {title} ==");
        BenchRunner {
            title: title.to_string(),
            target_secs,
            warmup_iters: 2,
            samples: Vec::new(),
            filter,
        }
    }

    /// Measure `f`, which performs **one** unit of work per call.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) {
                return;
            }
        }
        // Warmup + estimate cost.
        let mut est = 0.0;
        for _ in 0..self.warmup_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            est = t0.elapsed().as_secs_f64();
        }
        let iters = ((self.target_secs / est.max(1e-9)) as usize).clamp(3, 1000);
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let sample = Sample::from_times(name, &mut times);
        println!(
            "  {:<44} {:>10} ± {:>9}  (median {:>10}, n={})",
            sample.name,
            fmt_secs(sample.mean),
            fmt_secs(sample.std),
            fmt_secs(sample.median),
            sample.iters
        );
        self.samples.push(sample);
    }

    /// Record an externally measured value (e.g. a full run's wall-clock)
    /// without re-running it.
    pub fn record(&mut self, name: &str, secs: f64) {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) {
                return;
            }
        }
        println!("  {:<44} {:>10}  (recorded)", name, fmt_secs(secs));
        self.samples.push(Sample {
            name: name.to_string(),
            iters: 1,
            mean: secs,
            std: 0.0,
            median: secs,
            min: secs,
            max: secs,
        });
    }

    /// All samples collected so far.
    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    /// Ratio between two named samples' means (for speedup rows).
    pub fn ratio(&self, slow: &str, fast: &str) -> Option<f64> {
        let s = self.samples.iter().find(|s| s.name == slow)?.mean;
        let f = self.samples.iter().find(|s| s.name == fast)?.mean;
        Some(s / f)
    }

    /// Default location of the perf-trajectory snapshot: the repository
    /// root, one directory above the crate manifest.
    pub fn baseline_path() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_baseline.json")
    }

    /// Merge this runner's samples into the `BENCH_baseline.json` perf
    /// trajectory at `path` (see [`BenchRunner::baseline_path`]).
    ///
    /// The file maps suite title → case name → timing stats. Fresh samples
    /// overwrite their own case entries and carry `"provenance": "measured"`;
    /// every other case — other suites, and cases this run skipped via an
    /// argv filter — is preserved as-is, so a partial run can neither erase
    /// nor launder the estimated-seed entries the repo ships with. The
    /// top-level `provenance` summarizes the cases: `"measured"` only when
    /// every case in the file is, `"partially-measured"` otherwise.
    pub fn write_baseline(&self, path: &std::path::Path) {
        if self.samples.is_empty() {
            return;
        }
        let root = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .unwrap_or(Json::Null);
        let mut suites = match root.get("suites") {
            Json::Obj(m) => m.clone(),
            _ => Default::default(),
        };
        let mut cases = match suites.get(&self.title) {
            Some(Json::Obj(m)) => m.clone(),
            _ => Default::default(),
        };
        // Threads are recorded per case: suites (and earlier cases of this
        // suite) may have been measured under a different MBKK_THREADS.
        let threads = crate::util::parallel::num_threads();
        for s in &self.samples {
            cases.insert(
                s.name.clone(),
                Json::obj(vec![
                    ("provenance", Json::Str("measured".into())),
                    ("threads", Json::Num(threads as f64)),
                    ("iters", Json::Num(s.iters as f64)),
                    ("mean_s", Json::Num(s.mean)),
                    ("std_s", Json::Num(s.std)),
                    ("median_s", Json::Num(s.median)),
                    ("min_s", Json::Num(s.min)),
                    ("max_s", Json::Num(s.max)),
                ]),
            );
        }
        suites.insert(self.title.clone(), Json::Obj(cases));
        let all_measured = suites.values().all(|suite| match suite {
            Json::Obj(cs) => cs
                .values()
                .all(|c| c.get("provenance").as_str() == Some("measured")),
            _ => false,
        });
        let mut fields = vec![("schema", Json::Num(1.0))];
        // Keep the file's explanatory note (it documents the seed origin).
        if let Some(note) = root.get("note").as_str() {
            fields.push(("note", Json::Str(note.to_string())));
        }
        // Keep the per-suite notes: each states what its suite models and
        // the shared estimated-vs-measured provenance convention. They are
        // authored in the committed file, never machine-written.
        if let Json::Obj(notes) = root.get("suite_notes") {
            fields.push(("suite_notes", Json::Obj(notes.clone())));
        }
        fields.push((
            "provenance",
            Json::Str(
                if all_measured { "measured" } else { "partially-measured" }.into(),
            ),
        ));
        fields.push(("suites", Json::Obj(suites)));
        let root = Json::obj(fields);
        match std::fs::write(path, root.to_pretty()) {
            Ok(()) => println!("  [baseline] {}", path.display()),
            Err(e) => eprintln!("  [baseline] write failed: {e}"),
        }
    }

    /// Emit a CSV file with all samples under `results/bench/`.
    pub fn write_csv(&self) {
        let dir = std::path::Path::new("results/bench");
        if std::fs::create_dir_all(dir).is_err() {
            return;
        }
        let path = dir.join(format!("{}.csv", self.title.replace([' ', '/'], "_")));
        let mut out = String::from("name,iters,mean_s,std_s,median_s,min_s,max_s\n");
        for s in &self.samples {
            out.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                s.name, s.iters, s.mean, s.std, s.median, s.min, s.max
            ));
        }
        let _ = std::fs::write(&path, out);
        println!("  [csv] {}", path.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_statistics() {
        let mut times = vec![3.0, 1.0, 2.0];
        let s = Sample::from_times("t", &mut times);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.std - 1.0).abs() < 1e-12);
    }

    #[test]
    fn baseline_merges_suites() {
        let path = std::env::temp_dir()
            .join(format!("mbkk_baseline_test_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut a = BenchRunner::new("suite-a");
        a.record("case1", 0.5);
        a.write_baseline(&path);
        let mut b = BenchRunner::new("suite-b");
        b.record("case2", 0.25);
        b.write_baseline(&path);
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(root.get("provenance").as_str(), Some("measured"));
        let suites = root.get("suites");
        assert_eq!(
            suites.get("suite-a").get("case1").get("mean_s").as_f64(),
            Some(0.5)
        );
        assert_eq!(
            suites.get("suite-b").get("case2").get("median_s").as_f64(),
            Some(0.25)
        );
        // Re-measuring one case of suite-a overwrites it while keeping both
        // suite-a's other cases and suite-b (a filtered run must not erase
        // what it skipped).
        let mut a2 = BenchRunner::new("suite-a");
        a2.record("case1b", 0.0625);
        a2.write_baseline(&path);
        let mut a3 = BenchRunner::new("suite-a");
        a3.record("case1", 0.125);
        a3.write_baseline(&path);
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(
            root.get("suites").get("suite-a").get("case1").get("mean_s").as_f64(),
            Some(0.125)
        );
        assert_eq!(
            root.get("suites").get("suite-a").get("case1b").get("mean_s").as_f64(),
            Some(0.0625)
        );
        assert!(root.get("suites").get("suite-b").as_obj().is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn baseline_does_not_launder_estimated_cases() {
        let path = std::env::temp_dir()
            .join(format!("mbkk_baseline_prov_test_{}.json", std::process::id()));
        std::fs::write(
            &path,
            r#"{"schema": 1, "note": "seed origin", "provenance": "estimated-seed",
                "suite_notes": {"other": "what the suite models"},
                "suites": {"other": {"guess": {"provenance": "estimated-seed",
                "iters": 0, "mean_s": 0.5}}}}"#,
        )
        .unwrap();
        let mut r = BenchRunner::new("fresh-suite");
        r.record("real", 0.25);
        r.write_baseline(&path);
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        // The estimated case survives untouched, the note is kept, and the
        // top level reports the mix honestly.
        assert_eq!(root.get("provenance").as_str(), Some("partially-measured"));
        assert_eq!(root.get("note").as_str(), Some("seed origin"));
        assert_eq!(
            root.get("suite_notes").get("other").as_str(),
            Some("what the suite models")
        );
        let guess = root.get("suites").get("other").get("guess");
        assert_eq!(guess.get("provenance").as_str(), Some("estimated-seed"));
        assert_eq!(
            root.get("suites")
                .get("fresh-suite")
                .get("real")
                .get("provenance")
                .as_str(),
            Some("measured")
        );
        let _ = std::fs::remove_file(&path);
    }
}
