//! Criterion-style micro-benchmark harness.
//!
//! Each benchmark target (`rust/benches/*.rs`, `harness = false`) builds a
//! [`BenchRunner`], registers closures, and gets warmup, adaptive iteration
//! counts, and a mean/std/median/min/max report. Results can also be dumped
//! as CSV rows so `EXPERIMENTS.md` tables are reproducible by re-running
//! `cargo bench`.

use crate::util::timing::fmt_secs;
use std::time::Instant;

/// Statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub mean: f64,
    pub std: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
}

impl Sample {
    fn from_times(name: &str, times: &mut [f64]) -> Sample {
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let mean = times.iter().sum::<f64>() / n as f64;
        let var = times.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / (n.max(2) - 1) as f64;
        Sample {
            name: name.to_string(),
            iters: n,
            mean,
            std: var.sqrt(),
            median: times[n / 2],
            min: times[0],
            max: times[n - 1],
        }
    }
}

/// Benchmark registry + runner.
pub struct BenchRunner {
    title: String,
    /// Target wall-clock per case (seconds); adaptive iteration count aims
    /// for this. Override with MBKK_BENCH_SECS.
    target_secs: f64,
    warmup_iters: usize,
    samples: Vec<Sample>,
    /// Optional filter (substring) from argv, mirroring `cargo bench -- foo`.
    filter: Option<String>,
}

impl BenchRunner {
    pub fn new(title: &str) -> BenchRunner {
        let target_secs = std::env::var("MBKK_BENCH_SECS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1.0);
        // cargo bench passes `--bench`; any other bare arg is a filter.
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-'));
        println!("\n== bench: {title} ==");
        BenchRunner {
            title: title.to_string(),
            target_secs,
            warmup_iters: 2,
            samples: Vec::new(),
            filter,
        }
    }

    /// Measure `f`, which performs **one** unit of work per call.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) {
                return;
            }
        }
        // Warmup + estimate cost.
        let mut est = 0.0;
        for _ in 0..self.warmup_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            est = t0.elapsed().as_secs_f64();
        }
        let iters = ((self.target_secs / est.max(1e-9)) as usize).clamp(3, 1000);
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let sample = Sample::from_times(name, &mut times);
        println!(
            "  {:<44} {:>10} ± {:>9}  (median {:>10}, n={})",
            sample.name,
            fmt_secs(sample.mean),
            fmt_secs(sample.std),
            fmt_secs(sample.median),
            sample.iters
        );
        self.samples.push(sample);
    }

    /// Record an externally measured value (e.g. a full run's wall-clock)
    /// without re-running it.
    pub fn record(&mut self, name: &str, secs: f64) {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) {
                return;
            }
        }
        println!("  {:<44} {:>10}  (recorded)", name, fmt_secs(secs));
        self.samples.push(Sample {
            name: name.to_string(),
            iters: 1,
            mean: secs,
            std: 0.0,
            median: secs,
            min: secs,
            max: secs,
        });
    }

    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    /// Ratio between two named samples' means (for speedup rows).
    pub fn ratio(&self, slow: &str, fast: &str) -> Option<f64> {
        let s = self.samples.iter().find(|s| s.name == slow)?.mean;
        let f = self.samples.iter().find(|s| s.name == fast)?.mean;
        Some(s / f)
    }

    /// Emit a CSV file with all samples under `results/bench/`.
    pub fn write_csv(&self) {
        let dir = std::path::Path::new("results/bench");
        if std::fs::create_dir_all(dir).is_err() {
            return;
        }
        let path = dir.join(format!("{}.csv", self.title.replace([' ', '/'], "_")));
        let mut out = String::from("name,iters,mean_s,std_s,median_s,min_s,max_s\n");
        for s in &self.samples {
            out.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                s.name, s.iters, s.mean, s.std, s.median, s.min, s.max
            ));
        }
        let _ = std::fs::write(&path, out);
        println!("  [csv] {}", path.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_statistics() {
        let mut times = vec![3.0, 1.0, 2.0];
        let s = Sample::from_times("t", &mut times);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.std - 1.0).abs() < 1e-12);
    }
}
