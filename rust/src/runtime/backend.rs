//! [`XlaBackend`]: the AOT-compiled assignment step as an
//! [`AssignBackend`].
//!
//! For Gaussian feature kernels the backend marshals the batch features,
//! the zero-padded per-center support tensors, and the coefficient matrix
//! for the `assign_gaussian` graph lowered by `python/compile/aot.py`.
//! Batches smaller than the artifact's fixed `b` are padded (extra rows
//! repeat point 0 and are sliced away); windows shorter than `m` are
//! zero-padded (zero weights contribute nothing — verified in
//! `python/tests/test_model.py`).
//!
//! Configurations with no matching artifact (wrong k/d, window larger than
//! every artifact, non-Gaussian or precomputed grams) — and, in this
//! offline build, *every* execution, because [`Engine`] links no PJRT
//! runtime — fall back to the [`NativeBackend`]; `fallback_calls` counts
//! them so benchmarks and tests can assert which path actually ran.

use crate::kernels::{KernelFunction, KernelProvider};
use crate::kkmeans::state::CenterWindow;
use crate::kkmeans::{AssignBackend, NativeBackend};
use crate::runtime::engine::Engine;
use crate::util::error::Result;
use std::path::Path;

/// PJRT-executing assignment backend with native fallback.
pub struct XlaBackend {
    engine: Engine,
    native: NativeBackend,
    /// Calls served by the XLA path.
    pub xla_calls: u64,
    /// Calls that fell back to the native path.
    pub fallback_calls: u64,
}

impl XlaBackend {
    /// Load the artifact manifest and prepare the engine.
    pub fn load(artifact_dir: &Path) -> Result<XlaBackend> {
        Ok(XlaBackend {
            engine: Engine::load(artifact_dir)?,
            native: NativeBackend,
            xla_calls: 0,
            fallback_calls: 0,
        })
    }

    /// Convenience: load from the default `artifacts/` directory.
    pub fn load_default() -> Result<XlaBackend> {
        Self::load(Path::new(super::DEFAULT_ARTIFACT_DIR))
    }

    fn try_xla(
        &mut self,
        gram: &dyn KernelProvider,
        batch: &[usize],
        centers: &mut [CenterWindow],
    ) -> Option<Vec<f64>> {
        // Without a linked PJRT runtime every execution would fail *after*
        // the O(k·m·d) marshaling below; bail before paying it so the
        // fallback path costs nothing extra per iteration.
        if !self.engine.runtime_available() {
            return None;
        }
        // Only the Gaussian feature kernel lowers to the assign_gaussian
        // graph; everything else uses the native path. The provider
        // abstraction exposes exactly what the marshaler needs — raw
        // features + the closed-form kernel — so both the on-the-fly and
        // the streaming tile-LRU providers can route here.
        let (ds, kappa) = match gram.feature_kernel() {
            Some((ds, KernelFunction::Gaussian { kappa })) => (ds, kappa),
            _ => return None,
        };
        let k = centers.len();
        let d = ds.d;
        let needed_m = centers.iter().map(|c| c.support_len()).max().unwrap_or(1);
        let spec = self
            .engine
            .manifest()
            .find_gaussian(batch.len(), k, d, needed_m)?
            .clone();
        let (b_art, m_art) = (spec.b, spec.m);

        // ---- marshal inputs ------------------------------------------------
        // Batch features, padded to b_art rows by repeating row 0.
        let mut bf = vec![0.0f32; b_art * d];
        for (r, &x) in batch.iter().enumerate() {
            bf[r * d..(r + 1) * d].copy_from_slice(ds.row(x));
        }
        for r in batch.len()..b_art {
            let src = ds.row(batch.first().copied().unwrap_or(0)).to_vec();
            bf[r * d..(r + 1) * d].copy_from_slice(&src);
        }
        // Support tensors + weights, zero-padded to m_art slots.
        let mut sf = vec![0.0f32; k * m_art * d];
        let mut wf = vec![0.0f32; k * m_art];
        for (j, c) in centers.iter().enumerate() {
            for (slot, (y, w)) in c.support().enumerate() {
                debug_assert!(slot < m_art);
                let dst = (j * m_art + slot) * d;
                sf[dst..dst + d].copy_from_slice(ds.row(y));
                wf[j * m_art + slot] = w as f32;
            }
        }

        // ---- execute -------------------------------------------------------
        // Errors (in this build: always, since no PJRT runtime is linked)
        // surface as None and route the call to the native fallback.
        let out = self
            .engine
            .run_assign_gaussian(&spec, &bf, &sf, &wf, (1.0 / kappa) as f32)
            .ok()?;
        debug_assert_eq!(out.len(), b_art * k);
        Some(
            out[..batch.len() * k]
                .iter()
                .map(|&v| v as f64)
                .collect(),
        )
    }
}

impl AssignBackend for XlaBackend {
    fn distances(
        &mut self,
        gram: &dyn KernelProvider,
        batch: &[usize],
        centers: &mut [CenterWindow],
    ) -> Vec<f64> {
        match self.try_xla(gram, batch, centers) {
            Some(dist) => {
                self.xla_calls += 1;
                dist
            }
            None => {
                self.fallback_calls += 1;
                self.native.distances(gram, batch, centers)
            }
        }
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::kernels::Gram;
    use crate::util::rng::Rng;

    const SAMPLE: &str = r#"{
        "version": 1,
        "artifacts": [
            {"name": "g1", "file": "g1.hlo.txt", "kind": "assign_gaussian",
             "b": 64, "k": 4, "m": 512, "d": 8}
        ]
    }"#;

    fn temp_manifest_dir(tag: &str) -> std::path::PathBuf {
        // Per-process suffix: concurrent test processes share /tmp.
        let dir = std::env::temp_dir()
            .join(format!("mbkk_xla_backend_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        std::fs::write(dir.join("g1.hlo.txt"), "HloModule stub").unwrap();
        dir
    }

    /// Build a (dataset, centers) fixture matching the (b64, k4, d8)
    /// manifest entry.
    fn fixture(rng: &mut Rng) -> (crate::data::Dataset, Vec<CenterWindow>) {
        let ds = blobs(&SyntheticSpec::new(300, 8, 4), rng);
        let mut centers: Vec<CenterWindow> =
            (0..4).map(|j| CenterWindow::new(j * 40, 40)).collect();
        for c in centers.iter_mut() {
            for _ in 0..4 {
                let pts: Vec<usize> = (0..9).map(|_| rng.below(ds.n)).collect();
                c.apply_update(0.5, &pts, None);
            }
        }
        (ds, centers)
    }

    #[test]
    fn falls_back_to_native_and_matches_it() {
        let dir = temp_manifest_dir("fallback");
        let mut rng = Rng::seeded(1234);
        let (ds, mut centers) = fixture(&mut rng);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 7.0 });
        let batch: Vec<usize> = (0..64).map(|_| rng.below(ds.n)).collect();

        let mut xla = XlaBackend::load(&dir).unwrap();
        let mut centers2 = centers.clone();
        let dx = xla.distances(&gram, &batch, &mut centers);
        // No PJRT runtime in this build: the call must be served natively.
        assert_eq!(xla.xla_calls, 0);
        assert_eq!(xla.fallback_calls, 1);
        let dn = NativeBackend.distances(&gram, &batch, &mut centers2);
        assert_eq!(dx.len(), dn.len());
        for (i, (a, b)) in dx.iter().zip(dn.iter()).enumerate() {
            assert!((a - b).abs() < 1e-12, "idx {i}: xla-path={a} native={b}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unsupported_kernels_also_fall_back() {
        let dir = temp_manifest_dir("unsupported");
        let mut rng = Rng::seeded(5);
        let ds = blobs(&SyntheticSpec::new(100, 8, 3), &mut rng);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Linear);
        let mut centers: Vec<CenterWindow> =
            (0..3).map(|j| CenterWindow::new(j, 20)).collect();
        let batch: Vec<usize> = (0..32).collect();
        let mut xla = XlaBackend::load(&dir).unwrap();
        let _ = xla.distances(&gram, &batch, &mut centers);
        assert_eq!(xla.fallback_calls, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_fails_without_manifest() {
        let dir = std::env::temp_dir().join("mbkk_xla_backend_missing");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(XlaBackend::load(&dir).is_err());
    }
}
