//! PJRT runtime layer: manages the AOT-compiled HLO artifacts produced by
//! `make artifacts` (Layer 1/2 — JAX + Pallas) for execution from the Rust
//! hot path.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` and selects an
//!   artifact for a run configuration.
//! * [`engine`] — the executable cache. In this offline build it is a
//!   graceful shim: no PJRT bindings can be linked (see the module docs of
//!   [`engine`] and DESIGN.md §1), so execution requests error and the
//!   caller falls back to the native path.
//! * [`XlaBackend`] — an [`crate::kkmeans::AssignBackend`] that marshals
//!   the batch/support/weight tensors for the assignment-step graph, with
//!   a counted [`crate::kkmeans::NativeBackend`] fallback.
//!
//! Python is only involved at build time; these modules read text files
//! and never shell out.

pub mod backend;
pub mod engine;
pub mod manifest;

pub use backend::XlaBackend;
pub use engine::Engine;
pub use manifest::{ArtifactSpec, Manifest};

/// Default artifact directory, relative to the repo root / cwd.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// True when an artifact directory with a manifest exists (used by tests
/// and the CLI to decide whether the XLA backend is available).
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.json").exists()
}
