//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `make artifacts` (Layer 1/2 — JAX + Pallas) and executes them from the
//! Rust hot path via the `xla` crate's PJRT CPU client.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` and selects an
//!   artifact for a run configuration.
//! * [`engine`] — PJRT client + lazy executable compilation cache.
//! * [`XlaBackend`] — an [`crate::kkmeans::AssignBackend`] that marshals
//!   the batch/support/weight tensors and runs the assignment-step graph.
//!
//! Python is only involved at build time; these modules read text files and
//! talk to PJRT directly.

pub mod backend;
pub mod engine;
pub mod manifest;

pub use backend::XlaBackend;
pub use engine::Engine;
pub use manifest::{ArtifactSpec, Manifest};

/// Default artifact directory, relative to the repo root / cwd.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// True when an artifact directory with a manifest exists (used by tests
/// and the CLI to decide whether the XLA backend is available).
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.json").exists()
}
