//! Artifact manifest: what `make artifacts` built and how to pick an
//! executable for a run configuration.

use crate::bail;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One AOT-compiled artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    /// Unique artifact name (cache key).
    pub name: String,
    /// HLO text file name, relative to the manifest directory.
    pub file: String,
    /// Graph kind: `assign_gaussian` (feature kernel) or
    /// `assign_precomputed` (graph kernels).
    pub kind: String,
    /// Fixed batch size the graph was lowered for.
    pub b: usize,
    /// Number of centers.
    pub k: usize,
    /// Support capacity per center (zero-padded windows).
    pub m: usize,
    /// Feature dimension (feature-kernel graphs only).
    pub d: Option<usize>,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Directory the manifest was loaded from (artifact paths are relative).
    pub dir: PathBuf,
    /// All artifacts `make artifacts` built.
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load from `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = Json::parse(text).context("manifest.json is not valid JSON")?;
        let version = root.get("version").as_usize().unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut artifacts = Vec::new();
        for a in root
            .get("artifacts")
            .as_arr()
            .context("manifest missing 'artifacts' array")?
        {
            artifacts.push(ArtifactSpec {
                name: a.get("name").as_str().context("artifact missing name")?.to_string(),
                file: a.get("file").as_str().context("artifact missing file")?.to_string(),
                kind: a.get("kind").as_str().context("artifact missing kind")?.to_string(),
                b: a.get("b").as_usize().context("artifact missing b")?,
                k: a.get("k").as_usize().context("artifact missing k")?,
                m: a.get("m").as_usize().context("artifact missing m")?,
                d: a.get("d").as_usize(),
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Pick the best Gaussian assign-step artifact for a run: exact `k` and
    /// `d`, batch capacity ≥ `b`, support capacity ≥ `min_m`; among
    /// candidates prefer the tightest (smallest b, then smallest m) so we
    /// waste the least padding compute.
    pub fn find_gaussian(
        &self,
        b: usize,
        k: usize,
        d: usize,
        min_m: usize,
    ) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == "assign_gaussian"
                    && a.k == k
                    && a.d == Some(d)
                    && a.b >= b
                    && a.m >= min_m
            })
            .min_by_key(|a| (a.b, a.m))
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "artifacts": [
            {"name": "g1", "file": "g1.hlo.txt", "kind": "assign_gaussian",
             "b": 256, "k": 10, "m": 640, "d": 16},
            {"name": "g2", "file": "g2.hlo.txt", "kind": "assign_gaussian",
             "b": 1024, "k": 10, "m": 1408, "d": 16},
            {"name": "p1", "file": "p1.hlo.txt", "kind": "assign_precomputed",
             "b": 64, "k": 4, "m": 192}
        ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.artifacts[0].b, 256);
        assert_eq!(m.artifacts[2].d, None);
        assert_eq!(m.path_of(&m.artifacts[0]), PathBuf::from("/tmp/a/g1.hlo.txt"));
    }

    #[test]
    fn find_gaussian_prefers_tightest_fit() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        // Exact small fit.
        assert_eq!(m.find_gaussian(256, 10, 16, 500).unwrap().name, "g1");
        // Batch too large for g1 → g2.
        assert_eq!(m.find_gaussian(512, 10, 16, 500).unwrap().name, "g2");
        // Window too large for g1 → g2.
        assert_eq!(m.find_gaussian(256, 10, 16, 700).unwrap().name, "g2");
        // No k match.
        assert!(m.find_gaussian(256, 3, 16, 100).is_none());
        // No d match.
        assert!(m.find_gaussian(256, 10, 32, 100).is_none());
    }

    #[test]
    fn rejects_bad_versions_and_shapes() {
        assert!(Manifest::parse(Path::new("."), r#"{"version": 2, "artifacts": []}"#).is_err());
        assert!(Manifest::parse(Path::new("."), r#"{"version": 1}"#).is_err());
        assert!(Manifest::parse(Path::new("."), "not json").is_err());
    }
}
