//! PJRT engine: a CPU PJRT client plus a lazy cache of compiled
//! executables, keyed by artifact name.
//!
//! Compilation happens once per artifact per process (the paper's protocol
//! compiles one executable per model variant); execution is then a plain
//! synchronous PJRT call from the clustering hot loop.

use super::manifest::{ArtifactSpec, Manifest};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// PJRT client + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return the executable for an artifact.
    pub fn executable(&mut self, spec: &ArtifactSpec) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&spec.name) {
            let path = self.manifest.path_of(spec);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", spec.name))?;
            self.cache.insert(spec.name.clone(), exe);
        }
        Ok(&self.cache[&spec.name])
    }

    /// Execute an artifact on f32 input literals; returns the flat f32
    /// vector of the single (tuple-wrapped) output.
    pub fn run_f32(
        &mut self,
        spec: &ArtifactSpec,
        inputs: &[xla::Literal],
    ) -> Result<Vec<f32>> {
        let exe = self.executable(spec)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", spec.name))?[0][0]
            .to_literal_sync()?;
        // Lowered with return_tuple=True → 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Number of executables compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn engine_loads_and_compiles_smallest_artifact() {
        let Some(dir) = artifact_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let mut engine = Engine::load(&dir).unwrap();
        assert!(engine.platform().to_lowercase().contains("cpu")
            || engine.platform().to_lowercase().contains("host"));
        let spec = engine
            .manifest()
            .find_gaussian(64, 4, 8, 100)
            .expect("test artifact (b64,k4,d8) missing — re-run make artifacts")
            .clone();
        // Build zero inputs of the right shapes: batch (b,d), support
        // (k,m,d), weights (k,m), inv_kappa ().
        let (b, k, m, d) = (spec.b, spec.k, spec.m, spec.d.unwrap());
        let batch = xla::Literal::vec1(&vec![0.0f32; b * d])
            .reshape(&[b as i64, d as i64])
            .unwrap();
        let support = xla::Literal::vec1(&vec![0.0f32; k * m * d])
            .reshape(&[k as i64, m as i64, d as i64])
            .unwrap();
        let weights = xla::Literal::vec1(&vec![0.0f32; k * m])
            .reshape(&[k as i64, m as i64])
            .unwrap();
        let inv_kappa = xla::Literal::scalar(1.0f32);
        let out = engine
            .run_f32(&spec, &[batch, support, weights, inv_kappa])
            .unwrap();
        assert_eq!(out.len(), b * k);
        // All-zero weights ⇒ dist = K(x,x) = 1 everywhere.
        for v in out {
            assert!((v - 1.0).abs() < 1e-5, "{v}");
        }
        assert_eq!(engine.compiled_count(), 1);
        // Second call hits the cache.
        let _ = engine.executable(&spec).unwrap();
        assert_eq!(engine.compiled_count(), 1);
    }
}
