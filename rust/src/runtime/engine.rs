//! PJRT engine shim.
//!
//! The production deployment links vendored PJRT bindings (the `xla` crate)
//! and compiles each AOT HLO artifact once per process. This offline build
//! has **no PJRT runtime available** — there is no network to fetch the
//! bindings and no `libxla_extension` on the image — so the engine degrades
//! gracefully instead of poisoning the build:
//!
//! * the artifact manifest is parsed (pure Rust, [`crate::util::json`]),
//! * artifact selection/validation works (paths are checked on "compile"),
//! * every *execution* request returns an error, which
//!   [`crate::runtime::XlaBackend`] translates into a native fallback.
//!
//! The surface mirrors the real engine so that restoring PJRT support only
//! touches this file: `load`, `manifest`, `platform`, `executable`,
//! `run_assign_gaussian`, `compiled_count`.

use super::manifest::{ArtifactSpec, Manifest};
use crate::util::error::Result;
use std::collections::BTreeSet;
use std::path::Path;

/// Artifact registry + (stubbed) executable cache.
pub struct Engine {
    manifest: Manifest,
    /// Names of artifacts whose files were validated ("compiled").
    compiled: BTreeSet<String>,
}

impl Engine {
    /// Load the artifact manifest from `dir`. Succeeds whenever the
    /// manifest parses; *executing* additionally needs a PJRT runtime,
    /// which this build does not link (see [`Engine::runtime_available`]).
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        Ok(Engine { manifest, compiled: BTreeSet::new() })
    }

    /// The parsed artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name; `"unavailable"` when no runtime is linked.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Whether a PJRT runtime is linked into this build. Always `false`
    /// here; the real engine reports the client's liveness.
    pub fn runtime_available(&self) -> bool {
        false
    }

    /// Validate (and in the real engine, compile) an artifact. The shim
    /// checks the HLO file exists and records the artifact as compiled so
    /// cache bookkeeping behaves identically.
    pub fn executable(&mut self, spec: &ArtifactSpec) -> Result<()> {
        if !self.compiled.contains(&spec.name) {
            let path = self.manifest.path_of(spec);
            if !path.exists() {
                crate::bail!("artifact file {} is missing", path.display());
            }
            self.compiled.insert(spec.name.clone());
        }
        Ok(())
    }

    /// Execute the `assign_gaussian` graph on flat f32 buffers:
    /// `batch` is `b×d` row-major, `support` is `k×m×d`, `weights` is
    /// `k×m`, and the scalar is `1/κ`. Returns the flat `b×k` distance
    /// matrix. Always errors in this build — the caller falls back to the
    /// native path.
    pub fn run_assign_gaussian(
        &mut self,
        spec: &ArtifactSpec,
        _batch: &[f32],
        _support: &[f32],
        _weights: &[f32],
        _inv_kappa: f32,
    ) -> Result<Vec<f32>> {
        self.executable(spec)?;
        Err(crate::format_err!(
            "cannot execute artifact {}: this build links no PJRT runtime \
             (see DESIGN.md §1; the native backend serves all traffic)",
            spec.name
        ))
    }

    /// Number of artifacts validated/compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.compiled.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "artifacts": [
            {"name": "g1", "file": "g1.hlo.txt", "kind": "assign_gaussian",
             "b": 64, "k": 4, "m": 256, "d": 8}
        ]
    }"#;

    fn temp_manifest_dir(tag: &str, with_hlo: bool) -> std::path::PathBuf {
        // Per-process suffix: concurrent test processes share /tmp.
        let dir = std::env::temp_dir()
            .join(format!("mbkk_engine_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        if with_hlo {
            std::fs::write(dir.join("g1.hlo.txt"), "HloModule stub").unwrap();
        } else {
            let _ = std::fs::remove_file(dir.join("g1.hlo.txt"));
        }
        dir
    }

    #[test]
    fn loads_manifest_and_reports_no_runtime() {
        let dir = temp_manifest_dir("load", true);
        let engine = Engine::load(&dir).unwrap();
        assert_eq!(engine.manifest().artifacts.len(), 1);
        assert!(!engine.runtime_available());
        assert_eq!(engine.platform(), "unavailable");
        assert_eq!(engine.compiled_count(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_fails_without_manifest() {
        let dir = std::env::temp_dir().join("mbkk_engine_missing_manifest");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(Engine::load(&dir).is_err());
    }

    #[test]
    fn executable_validates_file_and_caches() {
        let dir = temp_manifest_dir("compile", true);
        let mut engine = Engine::load(&dir).unwrap();
        let spec = engine.manifest().artifacts[0].clone();
        engine.executable(&spec).unwrap();
        assert_eq!(engine.compiled_count(), 1);
        engine.executable(&spec).unwrap(); // cache hit
        assert_eq!(engine.compiled_count(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn executable_errors_on_missing_file() {
        let dir = temp_manifest_dir("nofile", false);
        let mut engine = Engine::load(&dir).unwrap();
        let spec = engine.manifest().artifacts[0].clone();
        assert!(engine.executable(&spec).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn execution_always_errors_in_this_build() {
        let dir = temp_manifest_dir("run", true);
        let mut engine = Engine::load(&dir).unwrap();
        let spec = engine.manifest().artifacts[0].clone();
        let err = engine
            .run_assign_gaussian(&spec, &[0.0; 64 * 8], &[0.0; 4 * 256 * 8], &[0.0; 4 * 256], 1.0)
            .unwrap_err();
        assert!(format!("{err}").contains("no PJRT runtime"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
