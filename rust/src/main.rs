//! `mbkk` — the launcher for mini-batch kernel k-means.
//!
//! ```text
//! mbkk quickstart                         # 30-second demo on blobs
//! mbkk run --dataset synth_pendigits --algo btrunc-kkm --batch 1024 --tau 200
//! mbkk fit --dataset blobs --out model.mbkk      # train + persist a model
//! mbkk predict --model model.mbkk --dataset blobs # load + batch-score
//! mbkk serve-bench --model model.mbkk --secs 3   # sustained queries/sec
//! mbkk serve --model model.mbkk --port 8605      # HTTP prediction service
//! mbkk figures --fig 1 --out results/    # regenerate a paper figure
//! mbkk figures --all --quick             # the whole evaluation, reduced grid
//! mbkk repro-speedup                     # reproduce the 10-100x claim (Table 1)
//! mbkk gamma-table                       # paper Table 1
//! mbkk info                              # datasets, artifacts, backends
//! ```

use mbkk::coordinator::{experiment, figures, repro};
use mbkk::data::registry;
use mbkk::kernels::NumericsMode;
use mbkk::kkmeans::{AssignBackend, KernelKMeansModel};
use mbkk::runtime;
use mbkk::serve::PredictEngine;
use mbkk::util::cli::Args;
use mbkk::util::error::{Context, Result};
use mbkk::util::rng::Rng;
use mbkk::util::timing::Stopwatch;
use std::path::Path;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("quickstart") => quickstart(&args),
        Some("run") => run(&args),
        Some("fit") => fit(&args),
        Some("predict") => predict(&args),
        Some("serve-bench") => serve_bench(&args),
        Some("serve") => serve(&args),
        Some("shard-worker") => shard_worker(&args),
        Some("figures") => run_figures(&args),
        Some("repro-speedup") => repro_speedup(&args),
        Some("gamma-table") => gamma_table(&args),
        Some("info") => info(&args),
        _ => {
            eprintln!(
                "mbkk {} — mini-batch kernel k-means (Jourdan & Schwartzman 2024)\n\
                 \n\
                 usage: mbkk <subcommand> [options]\n\
                 \n\
                 subcommands:\n\
                 \x20 quickstart               quick demo on synthetic blobs\n\
                 \x20 run                      run one algorithm on one dataset\n\
                 \x20     --dataset NAME       {:?}\n\
                 \x20     --csv PATH           ... or your own CSV (label column optional)\n\
                 \x20     --algo NAME          full-kkm | [b]mb-kkm | [b]trunc-kkm | [b]mb-km | kmeans\n\
                 \x20     --kernel NAME        gaussian | knn | heat\n\
                 \x20     --k N --batch N --tau N --iters N --epsilon F --seed N\n\
                 \x20     --schedule NAME      fixed | nested (geometric batch growth\n\
                 \x20                          with deterministic sample reuse)\n\
                 \x20     --growth F           nested growth factor >= 1 (default 2)\n\
                 \x20     --scale F            dataset size multiplier (default 0.25)\n\
                 \x20     --backend NAME       native | xla (needs `make artifacts`)\n\
                 \x20     --stream             never materialize the n×n gram: stream kernel\n\
                 \x20                          values through the tile-LRU cache (feature\n\
                 \x20                          kernels; default policy auto-streams above n≈23k)\n\
                 \x20     --cache-mb N         tile-LRU budget in MiB for streaming runs (64)\n\
                 \x20     --materialize        force the dense n×n table at any n\n\
                 \x20     --numerics MODE      det (default; bit-reproducible) | fast\n\
                 \x20                          (runtime-dispatched SIMD exp lanes for the\n\
                 \x20                          gram fills, ≤4 ulp per kernel value)\n\
                 \x20     --profile            print the fit's per-phase timing table\n\
                 \x20                          (init/refresh/assign/moments/update/stopping/\n\
                 \x20                          finalize splits, without a debugger)\n\
                 \x20     --checkpoint-dir DIR durable rotating training checkpoints\n\
                 \x20                          ([b]trunc-kkm only; atomic + checksummed)\n\
                 \x20     --checkpoint-every N snapshot cadence in iterations (10)\n\
                 \x20     --checkpoint-keep N  snapshots retained (3)\n\
                 \x20     --resume MODE        auto (newest valid snapshot) | never\n\
                 \x20 fit                      train + save a servable model artifact\n\
                 \x20     --dataset/--csv/--scale/--k/--batch/--tau/--iters/--seed/\n\
                 \x20     --profile/--checkpoint-dir/--checkpoint-every/\n\
                 \x20     --checkpoint-keep/--resume/--numerics as `run`\n\
                 \x20     --out PATH           artifact path (default model.mbkk)\n\
                 \x20     --shards N           record an N-shard contiguous plan in the\n\
                 \x20                          artifact header for sharded serving\n\
                 \x20 predict                  load a model + batch-score a dataset\n\
                 \x20     --model PATH         artifact from `fit` (default model.mbkk)\n\
                 \x20     --dataset/--csv/--scale/--seed/--numerics as `run`\n\
                 \x20     --chunk N            query rows per engine batch (8192)\n\
                 \x20     --scalar             per-query scalar path (baseline)\n\
                 \x20     --out PATH           write index,assignment CSV\n\
                 \x20 serve-bench              sustained queries/sec loop over a model\n\
                 \x20     --model PATH         artifact (fits one on the fly if omitted)\n\
                 \x20     --secs F --batch-queries N --no-baseline --numerics MODE\n\
                 \x20 serve                    HTTP prediction service (docs/API.md):\n\
                 \x20                          POST /v1/predict, GET /v1/models, GET /healthz\n\
                 \x20     --model PATHS        artifact, or comma list (first = default,\n\
                 \x20                          ?model=PATH routes the rest; fits one on\n\
                 \x20                          the fly if omitted)\n\
                 \x20     --watch              hot-swap a model when its artifact changes\n\
                 \x20     --addr HOST --port N bind address (127.0.0.1:8605; port 0 = any free)\n\
                 \x20     --max-wait-us N      request-coalescing deadline in us (2000)\n\
                 \x20     --max-batch N        coalescing flush threshold in rows (512)\n\
                 \x20     --max-body-mb N      request body cap in MiB (8)\n\
                 \x20     --deadline-ms N      per-request budget; late requests are shed\n\
                 \x20                          with 503 + Retry-After (5000)\n\
                 \x20     --degraded-window-s N how long /healthz keeps reporting a\n\
                 \x20                          contained fault's cause code (30)\n\
                 \x20     --shards N           split scoring into N contiguous center\n\
                 \x20                          shards (a plan recorded by fit --shards\n\
                 \x20                          activates this automatically)\n\
                 \x20     --shard-replicas N   in-process replicas per shard (1)\n\
                 \x20     --shard-workers LIST remote shard-worker addresses, one per\n\
                 \x20                          shard in shard order (locals fail over)\n\
                 \x20     --partial-results    answer from covered shards (marked\n\
                 \x20                          \"partial\") instead of 503 shard_unavailable\n\
                 \x20     --shard-attempts N --shard-backoff-ms N --shard-deadline-ms N\n\
                 \x20     --probe-interval-ms N dispatch retry + replica re-probe knobs\n\
                 \x20     --numerics MODE      det | fast serving numerics as `run`\n\
                 \x20 shard-worker             serve one shard of a model for a sharded\n\
                 \x20                          coordinator (POST /v1/shard-distances)\n\
                 \x20     --model PATH --shard I --shards N --addr HOST --port N (8620)\n\
                 \x20 figures                  regenerate paper figures (CSV+md under --out)\n\
                 \x20     --fig N | --all      figure id 1..13\n\
                 \x20     --scale F --repeats N --iters N --quick --out DIR\n\
                 \x20 repro-speedup            reproduce the paper's 10-100x speedup claim:\n\
                 \x20                          full-batch vs mini-batch (fixed + nested\n\
                 \x20                          schedules) under a shared epsilon; writes the\n\
                 \x20                          deterministic table + timings under --out\n\
                 \x20     --datasets LIST      registry names (default: paper proxies)\n\
                 \x20     --scale F --seed N --batch N --tau N --iters N\n\
                 \x20     --epsilon F --growth F --out DIR (default results/repro)\n\
                 \x20 gamma-table              paper Table 1 (γ per dataset × kernel)\n\
                 \x20 info                     environment, datasets, artifacts\n",
                mbkk::VERSION,
                registry::ALL,
            );
            std::process::exit(2);
        }
    }
}

fn quickstart(args: &Args) -> Result<()> {
    let seed = args.get_parse_or("seed", 7u64);
    args.finish();
    println!("== mbkk quickstart: truncated mini-batch kernel k-means on blobs ==");
    let spec = experiment::RunSpec {
        dataset: "blobs".into(),
        scale: 0.5,
        kernel: experiment::KernelSpec::Gaussian { multiplier: 1.0 },
        algo: experiment::AlgoSpec::TruncKkm(mbkk::kkmeans::LearningRate::Beta),
        k: 5,
        batch_size: 256,
        schedule: mbkk::kkmeans::ScheduleSpec::Fixed,
        tau: 100,
        max_iters: 100,
        epsilon: Some(1e-3),
        seed,
        numerics: NumericsMode::Deterministic,
    };
    let out = experiment::run_one(&spec);
    println!("dataset:   blobs (n≈2500, d=8, k=5)");
    println!("ARI:       {:.3}", out.ari);
    println!("NMI:       {:.3}", out.nmi);
    println!("objective: {:.4}", out.objective);
    println!(
        "iterations: {}{}",
        out.iterations,
        if out.converged { " (early-stopped)" } else { "" }
    );
    println!("kernel build: {:.3}s, clustering: {:.3}s", out.kernel_secs, out.cluster_secs);
    println!("\nNext: `mbkk figures --fig 1` or see examples/.");
    Ok(())
}

/// Parse the shared `--stream` / `--materialize` / `--cache-mb` gram flags
/// (used by `run` and `fit`); the bool reports whether any was passed, for
/// the contextual errors below.
fn gram_strategy(args: &Args) -> Result<(experiment::GramStrategy, bool)> {
    let cache_mb = args.get_parse_or("cache-mb", experiment::DEFAULT_CACHE_MB);
    let set = args.flag("stream")
        || args.flag("materialize")
        || args.get("cache-mb").is_some();
    let strategy = match (args.flag("stream"), args.flag("materialize")) {
        (true, true) => mbkk::bail!("--stream and --materialize are mutually exclusive"),
        (true, false) => experiment::GramStrategy::Stream { cache_mb },
        (false, true) => experiment::GramStrategy::Materialize,
        (false, false) => experiment::GramStrategy::Auto {
            max_table_bytes: experiment::DEFAULT_MAX_TABLE_BYTES,
            cache_mb,
        },
    };
    Ok((strategy, set))
}

/// Parse the shared `--numerics det|fast` flag (used by `run`, `fit`,
/// `predict`, `serve-bench`, and `serve`). Deterministic is the default;
/// Fast routes kernel fills through the runtime-dispatched SIMD exp lanes
/// (DESIGN.md §13 — dot kernels stay bit-identical, exp within 4 ulp).
fn numerics_from_args(args: &Args) -> Result<NumericsMode> {
    let name = args.get_or("numerics", "deterministic");
    NumericsMode::from_name(&name)
        .ok_or_else(|| mbkk::format_err!("unknown --numerics mode {name:?} (det|fast)"))
}

/// Resolve `--csv PATH` or a registry dataset name.
fn resolve_dataset(
    csv: &Option<String>,
    dataset: &str,
    scale: f64,
    seed: u64,
) -> Result<mbkk::data::Dataset> {
    match csv {
        Some(path) => mbkk::data::csvio::load_csv(Path::new(path)),
        None => Ok(registry::load(dataset, scale, seed)),
    }
}

/// Parse the shared `--schedule` / `--growth` flags (used by `run` and
/// `fit`).
fn schedule_from_args(args: &Args) -> mbkk::kkmeans::ScheduleSpec {
    let growth = args.get_parse_or("growth", 2.0f64);
    mbkk::kkmeans::ScheduleSpec::from_name(&args.get_or("schedule", "fixed"), growth)
}

/// Parse the shared `--checkpoint-dir` / `--checkpoint-every` /
/// `--checkpoint-keep` / `--resume` flags (used by `run` and `fit`).
/// Returns `None` when checkpointing is off (no `--checkpoint-dir`);
/// the companion flags are rejected without it so a typo'd dir flag
/// can't silently disable durability.
fn checkpoint_from_args(
    args: &Args,
) -> Result<Option<(mbkk::coordinator::CheckpointConfig, experiment::ResumeMode)>> {
    let dir = args.get("checkpoint-dir").map(|s| s.to_string());
    let every = args.get_parse_or("checkpoint-every", 10usize);
    let keep = args.get_parse_or("checkpoint-keep", mbkk::coordinator::checkpoint::DEFAULT_KEEP);
    let resume = args.get_or("resume", "auto");
    let Some(dir) = dir else {
        if args.get("checkpoint-every").is_some()
            || args.get("checkpoint-keep").is_some()
            || args.get("resume").is_some()
        {
            mbkk::bail!(
                "--checkpoint-every/--checkpoint-keep/--resume require \
                 --checkpoint-dir DIR"
            );
        }
        return Ok(None);
    };
    let resume = match resume.as_str() {
        "auto" => experiment::ResumeMode::Auto,
        "never" => experiment::ResumeMode::Never,
        other => mbkk::bail!("unknown --resume mode {other:?} (auto|never)"),
    };
    if every == 0 {
        mbkk::bail!("--checkpoint-every must be >= 1");
    }
    let cfg = mbkk::coordinator::CheckpointConfig {
        dir: std::path::PathBuf::from(dir),
        every,
        keep: keep.max(1),
    };
    Ok(Some((cfg, resume)))
}

fn run(args: &Args) -> Result<()> {
    let algo = experiment::AlgoSpec::from_name(&args.get_or("algo", "btrunc-kkm"));
    let kernel = experiment::KernelSpec::from_name(&args.get_or("kernel", "gaussian"));
    let dataset = args.get_or("dataset", "synth_pendigits");
    let scale = args.get_parse_or("scale", 0.25f64);
    let seed = args.get_parse_or("seed", 7u64);
    let backend = args.get_or("backend", "native");
    let csv = args.get("csv").map(|s| s.to_string());
    let k_opt = args.get("k").map(|s| s.parse::<usize>().expect("--k"));
    let show_profile = args.flag("profile");
    let (strategy, gram_flags_set) = gram_strategy(args)?;
    let checkpointing = checkpoint_from_args(args)?;
    let spec = experiment::RunSpec {
        dataset: dataset.clone(),
        scale,
        kernel,
        algo,
        k: k_opt.unwrap_or(0), // filled below
        batch_size: args.get_parse_or("batch", 1024usize),
        schedule: schedule_from_args(args),
        tau: args.get_parse_or("tau", 200usize),
        max_iters: args.get_parse_or("iters", 200usize),
        epsilon: args.get("epsilon").map(|e| e.parse().expect("--epsilon")),
        seed,
        numerics: numerics_from_args(args)?,
    };
    args.finish();

    // Resolve the dataset: registry name or user CSV.
    let ds = resolve_dataset(&csv, &dataset, scale, seed)?;
    let mut spec = spec;
    spec.k = k_opt
        .or_else(|| (ds.num_classes() > 0).then(|| ds.num_classes()))
        .expect("--k required for unlabeled CSV data");

    if gram_flags_set && !spec.algo.is_kernelized() {
        mbkk::bail!(
            "--stream/--materialize/--cache-mb apply to kernelized algorithms \
             only ({} runs on raw features, no gram is built)",
            spec.algo.name()
        );
    }

    println!(
        "run: {} on {} (n={}, d={}, k={})",
        spec.algo.name(),
        ds.name,
        ds.n,
        ds.d,
        spec.k
    );
    let outcome = match backend.as_str() {
        "native" => {
            let (out, report) = match &checkpointing {
                None => experiment::run_on_dataset(&spec, &ds, strategy),
                Some((ckpt, resume)) => {
                    println!(
                        "checkpoint: {} (every {} iters, keep {}, resume {})",
                        ckpt.dir.display(),
                        ckpt.every,
                        ckpt.keep,
                        match resume {
                            experiment::ResumeMode::Auto => "auto",
                            experiment::ResumeMode::Never => "never",
                        }
                    );
                    experiment::run_on_dataset_checkpointed(&spec, &ds, strategy, ckpt, *resume)?
                }
            };
            if let Some(report) = report {
                println!("gram:       {} ({})", report.label, report.mode);
                if let Some(stats) = report.cache {
                    println!("cache:      {}", stats.summary());
                }
            }
            out
        }
        "xla" => {
            if checkpointing.is_some() {
                mbkk::bail!(
                    "--checkpoint-dir applies to the native backend only"
                );
            }
            if gram_flags_set {
                mbkk::bail!(
                    "--stream/--materialize/--cache-mb apply to the native backend \
                     only: the xla backend always evaluates the feature kernel on \
                     the fly through the AOT graph"
                );
            }
            run_with_xla_backend(&spec, &ds)?
        }
        other => mbkk::bail!("unknown backend {other:?} (native|xla)"),
    };
    println!("ARI:        {:.4}", outcome.ari);
    println!("NMI:        {:.4}", outcome.nmi);
    println!("objective:  {:.6}", outcome.objective);
    println!("gamma:      {:.4}", outcome.gamma);
    println!(
        "iterations: {}{}",
        outcome.iterations,
        if outcome.converged { " (early-stopped)" } else { "" }
    );
    println!("kernel:     {:.3}s", outcome.kernel_secs);
    println!("clustering: {:.3}s", outcome.cluster_secs);
    if show_profile {
        print!("\nphase timings:\n{}", outcome.profiler.report());
    }
    Ok(())
}

/// The XLA path runs the truncated algorithm against the *feature* kernel
/// (the AOT graph evaluates the Gaussian kernel itself — no materialized
/// gram, no Python).
fn run_with_xla_backend(
    spec: &experiment::RunSpec,
    ds: &mbkk::data::Dataset,
) -> Result<experiment::RunOutcome> {
    use mbkk::kernels::{Gram, KernelFunction};
    use mbkk::kkmeans::{TruncatedConfig, TruncatedMiniBatchKernelKMeans};
    let experiment::AlgoSpec::TruncKkm(lr) = spec.algo else {
        mbkk::bail!("--backend xla supports the truncated algorithm ([b]trunc-kkm) only");
    };
    let mut rng = Rng::seeded(spec.seed ^ 0xC0DE);
    let kappa = spec
        .kernel
        .gaussian_kappa(ds, &mut rng)
        .ok_or_else(|| mbkk::format_err!("--backend xla requires --kernel gaussian"))?;
    let gram = Gram::on_the_fly_with(ds, KernelFunction::Gaussian { kappa }, spec.numerics);
    let mut backend = runtime::XlaBackend::load_default()?;
    let cfg = TruncatedConfig {
        k: spec.k,
        batch_size: spec.batch_size,
        schedule: spec.schedule,
        tau: spec.tau,
        max_iters: spec.max_iters,
        epsilon: spec.epsilon,
        termination: mbkk::kkmeans::TerminationMode::default(),
        learning_rate: lr,
        init: mbkk::kkmeans::Init::KMeansPlusPlus,
        weights: None,
    };
    let mut fit_rng = Rng::seeded(spec.seed ^ 0x5EED);
    let sw = mbkk::util::timing::Stopwatch::start();
    let fit = TruncatedMiniBatchKernelKMeans::new(cfg)
        .fit_with_backend(&gram, &mut backend, &mut fit_rng);
    let cluster_secs = sw.secs();
    println!(
        "[xla] calls: {} xla / {} native-fallback",
        backend.xla_calls, backend.fallback_calls
    );
    let (ari_v, nmi_v) = match &ds.labels {
        Some(t) => (
            mbkk::metrics::ari(t, &fit.result.assignments),
            mbkk::metrics::nmi(t, &fit.result.assignments),
        ),
        None => (f64::NAN, f64::NAN),
    };
    Ok(experiment::RunOutcome {
        ari: ari_v,
        nmi: nmi_v,
        objective: fit.result.objective,
        iterations: fit.result.iterations,
        converged: fit.result.converged,
        cluster_secs,
        kernel_secs: 0.0,
        gamma: gram.gamma(),
        decisions: fit.result.decisions,
        profiler: fit.result.profiler,
    })
}

/// `fit`: train the truncated algorithm and persist the frozen model as a
/// versioned artifact — the first half of the fit→persist→serve split.
fn fit(args: &Args) -> Result<()> {
    let algo = experiment::AlgoSpec::from_name(&args.get_or("algo", "btrunc-kkm"));
    let kernel = experiment::KernelSpec::from_name(&args.get_or("kernel", "gaussian"));
    let dataset = args.get_or("dataset", "blobs");
    let scale = args.get_parse_or("scale", 0.25f64);
    let seed = args.get_parse_or("seed", 7u64);
    let out = args.get_or("out", "model.mbkk");
    let csv = args.get("csv").map(|s| s.to_string());
    let k_opt = args.get("k").map(|s| s.parse::<usize>().expect("--k"));
    let shards = args.get_parse_or("shards", 0usize);
    let show_profile = args.flag("profile");
    let (strategy, _) = gram_strategy(args)?;
    let checkpointing = checkpoint_from_args(args)?;
    let mut spec = experiment::RunSpec {
        dataset: dataset.clone(),
        scale,
        kernel,
        algo,
        k: 0, // filled below
        batch_size: args.get_parse_or("batch", 1024usize),
        schedule: schedule_from_args(args),
        tau: args.get_parse_or("tau", 200usize),
        max_iters: args.get_parse_or("iters", 200usize),
        epsilon: args.get("epsilon").map(|e| e.parse().expect("--epsilon")),
        seed,
        numerics: numerics_from_args(args)?,
    };
    args.finish();

    let ds = resolve_dataset(&csv, &dataset, scale, seed)?;
    spec.k = k_opt
        .or_else(|| (ds.num_classes() > 0).then(|| ds.num_classes()))
        .expect("--k required for unlabeled CSV data");
    println!(
        "fit: {} on {} (n={}, d={}, k={})",
        spec.algo.name(),
        ds.name,
        ds.n,
        ds.d,
        spec.k
    );
    let fit = match &checkpointing {
        None => experiment::fit_servable_model(&spec, &ds, strategy)?,
        Some((ckpt, resume)) => {
            println!(
                "checkpoint: {} (every {} iters, keep {}, resume {})",
                ckpt.dir.display(),
                ckpt.every,
                ckpt.keep,
                match resume {
                    experiment::ResumeMode::Auto => "auto",
                    experiment::ResumeMode::Never => "never",
                }
            );
            experiment::fit_servable_model_checkpointed(&spec, &ds, strategy, ckpt, *resume)?
        }
    };
    println!("gram:       {} ({})", fit.report.label, fit.report.mode);
    if let Some(stats) = fit.report.cache {
        println!("cache:      {}", stats.summary());
    }
    println!("ARI:        {:.4}", fit.outcome.ari);
    println!("objective:  {:.6}", fit.outcome.objective);
    println!(
        "iterations: {}{}",
        fit.outcome.iterations,
        if fit.outcome.converged { " (early-stopped)" } else { "" }
    );
    println!("kernel:     {:.3}s", fit.outcome.kernel_secs);
    println!("clustering: {:.3}s", fit.outcome.cluster_secs);
    if show_profile {
        print!("\nphase timings:\n{}", fit.outcome.profiler.report());
    }
    // Atomic (temp + fsync + rename) so a crash mid-write can never leave
    // a torn artifact at the published path (DESIGN.md §12).
    // --shards N records a deterministic contiguous shard plan in the
    // header: `mbkk serve`/`mbkk shard-worker` pick it up, and loaders
    // that don't shard ignore the key (DESIGN.md §14).
    let bytes = if shards > 0 {
        let plan = mbkk::serve::shard::ShardPlan::contiguous(fit.model.k(), shards);
        println!("shard plan: {:?} ({} shards, recorded in the artifact)", plan.bounds(), plan.shards());
        mbkk::serve::format::model_to_bytes_with_plan(&fit.model, Some(plan.bounds()))
    } else {
        fit.model.to_bytes()
    };
    mbkk::serve::format::atomic_write(Path::new(&out), &bytes)
        .with_context(|| format!("writing model artifact {out}"))?;
    println!(
        "model:      {out} ({} centers, {} support points, {} bytes)",
        fit.model.k(),
        fit.model.support_points(),
        bytes.len()
    );
    Ok(())
}

/// `predict`: load a model artifact and batch-score a dataset through the
/// [`PredictEngine`], reporting throughput (and ARI when labels exist).
fn predict(args: &Args) -> Result<()> {
    let model_path = args.get_or("model", "model.mbkk");
    let dataset = args.get_or("dataset", "blobs");
    let scale = args.get_parse_or("scale", 0.25f64);
    let seed = args.get_parse_or("seed", 7u64);
    let csv = args.get("csv").map(|s| s.to_string());
    let chunk = args.get_parse_or("chunk", 8192usize).max(1);
    let scalar = args.flag("scalar");
    let numerics = numerics_from_args(args)?;
    let out_csv = args.get("out").map(|s| s.to_string());
    args.finish();

    let model = KernelKMeansModel::load(Path::new(&model_path))?;
    let ds = resolve_dataset(&csv, &dataset, scale, seed)?;
    if ds.d != model.d {
        mbkk::bail!(
            "dataset {} has d={} but the model was trained with d={}",
            ds.name,
            ds.d,
            model.d
        );
    }
    println!(
        "model:      {model_path} (k={}, d={}, {} support points, {} kernel)",
        model.k(),
        model.d,
        model.support_points(),
        model.kernel.name()
    );
    let engine = PredictEngine::with_mode(&model, numerics);
    let sw = Stopwatch::start();
    let assignments = if scalar {
        model.predict_all(&ds)
    } else {
        let mut assignments = vec![0usize; ds.n];
        let mut q0 = 0;
        while q0 < ds.n {
            let q1 = (q0 + chunk).min(ds.n);
            engine.predict_into(
                &ds.features[q0 * ds.d..q1 * ds.d],
                &mut assignments[q0..q1],
            );
            q0 = q1;
        }
        assignments
    };
    let secs = sw.secs();
    println!("queries:    {}", ds.n);
    println!(
        "throughput: {:.0} queries/s ({} path, chunk {chunk})",
        ds.n as f64 / secs.max(1e-12),
        if scalar { "scalar" } else { "batched" }
    );
    if let Some(truth) = &ds.labels {
        println!("ARI:        {:.4}", mbkk::metrics::ari(truth, &assignments));
    }
    let mut sizes = vec![0usize; model.k()];
    for &a in &assignments {
        sizes[a] += 1;
    }
    println!("clusters:   {sizes:?}");
    if let Some(path) = out_csv {
        let mut text = String::from("index,assignment\n");
        for (i, a) in assignments.iter().enumerate() {
            text.push_str(&format!("{i},{a}\n"));
        }
        std::fs::write(Path::new(&path), text)
            .with_context(|| format!("writing assignments {path}"))?;
        println!("wrote:      {path}");
    }
    Ok(())
}

/// `serve-bench`: drive a sustained query loop against a model for
/// `--secs` seconds and report queries/sec; the measurement is merged into
/// the `prediction service` suite of `BENCH_baseline.json` (alongside
/// `cargo bench --bench bench_predict`) unless `--no-baseline` is given.
fn serve_bench(args: &Args) -> Result<()> {
    let model_path = args.get("model").map(|s| s.to_string());
    let dataset = args.get_or("dataset", "blobs");
    let scale = args.get_parse_or("scale", 0.25f64);
    let seed = args.get_parse_or("seed", 7u64);
    let secs_budget = args.get_parse_or("secs", 3.0f64);
    let qbatch = args.get_parse_or("batch-queries", 512usize).max(1);
    let no_baseline = args.flag("no-baseline");
    let numerics = numerics_from_args(args)?;
    args.finish();

    let ds = registry::load(&dataset, scale, seed);
    let model = match &model_path {
        Some(p) => KernelKMeansModel::load(Path::new(p))?,
        None => {
            println!("no --model given: fitting a fresh model on {} first", ds.name);
            let spec = experiment::RunSpec {
                dataset: dataset.clone(),
                scale,
                kernel: experiment::KernelSpec::Gaussian { multiplier: 1.0 },
                algo: experiment::AlgoSpec::TruncKkm(mbkk::kkmeans::LearningRate::Beta),
                k: ds.num_classes().max(2),
                batch_size: 256,
                schedule: mbkk::kkmeans::ScheduleSpec::Fixed,
                tau: 100,
                max_iters: 60,
                epsilon: None,
                seed,
                // The throwaway model trains deterministically; only the
                // serving engine below honours --numerics.
                numerics: NumericsMode::Deterministic,
            };
            experiment::fit_servable_model(&spec, &ds, experiment::GramStrategy::default())?
                .model
        }
    };
    if ds.d != model.d {
        mbkk::bail!(
            "query dataset {} has d={} but the model was trained with d={}",
            ds.name,
            ds.d,
            model.d
        );
    }
    let engine = PredictEngine::with_mode(&model, numerics);
    let qbatch = qbatch.min(ds.n.max(1));
    let mut out = vec![0usize; qbatch];
    // Warm the pool and the engine before the measured window.
    engine.predict_into(&ds.features[..qbatch * ds.d], &mut out);
    let sw = Stopwatch::start();
    let mut served = 0u64;
    let mut batches = 0u64;
    let mut start = 0usize;
    while sw.secs() < secs_budget {
        if start + qbatch > ds.n {
            start = 0;
        }
        engine.predict_into(
            &ds.features[start * ds.d..(start + qbatch) * ds.d],
            &mut out,
        );
        start += qbatch;
        served += qbatch as u64;
        batches += 1;
    }
    let secs = sw.secs();
    let qps = served as f64 / secs.max(1e-12);
    println!(
        "sustained:  {qps:.0} queries/s ({batches} batches of {qbatch} over {secs:.2}s)"
    );
    if !no_baseline {
        let mut runner = mbkk::bench::BenchRunner::new("prediction service");
        // Fast-mode runs land under their own case name so they never
        // overwrite the deterministic baseline entry.
        let case = match numerics {
            NumericsMode::Deterministic => "serve-bench seconds/query",
            NumericsMode::Fast => "serve-bench seconds/query [fast]",
        };
        runner.record(case, 1.0 / qps.max(1e-12));
        runner.write_baseline(&mbkk::bench::BenchRunner::baseline_path());
    }
    Ok(())
}

/// `serve`: the zero-dependency HTTP prediction service over one or more
/// fitted models (docs/API.md; DESIGN.md §11/§14). `--model` takes a
/// comma-separated list (first = default, `?model=` routes the rest);
/// `--watch` hot-swaps a model when its artifact changes on disk;
/// `--shards`/`--shard-workers` turn on fault-tolerant sharded scoring.
/// SIGINT/SIGTERM set the shutdown flag; the accept loop drains in-flight
/// connections and exits 0.
fn serve(args: &Args) -> Result<()> {
    let model_paths: Vec<String> = args
        .get("model")
        .map(|s| s.split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect())
        .unwrap_or_default();
    let dataset = args.get_or("dataset", "blobs");
    let scale = args.get_parse_or("scale", 0.25f64);
    let seed = args.get_parse_or("seed", 7u64);
    let addr = args.get_or("addr", "127.0.0.1");
    let port = args.get_parse_or("port", 8605u16);
    let max_wait_us = args.get_parse_or("max-wait-us", 2000u64);
    let max_batch = args.get_parse_or("max-batch", 512usize);
    let max_body_mb = args.get_parse_or("max-body-mb", 8usize);
    let deadline_ms = args.get_parse_or("deadline-ms", 5000u64);
    let watch = args.flag("watch");
    let shards_given = args.get("shards").is_some();
    let shards = args.get_parse_or("shards", 0usize);
    let shard_replicas = args.get_parse_or("shard-replicas", 1usize);
    let shard_workers: Vec<String> = args
        .get("shard-workers")
        .map(|s| s.split(',').map(|w| w.trim().to_string()).filter(|w| !w.is_empty()).collect())
        .unwrap_or_default();
    let partial_results = args.flag("partial-results");
    let degraded_window_s = args.get_parse_or("degraded-window-s", 30u64);
    let shard_attempts = args.get_parse_or("shard-attempts", 2u32);
    let shard_backoff_ms = args.get_parse_or("shard-backoff-ms", 5u64);
    let shard_deadline_ms = args.get_parse_or("shard-deadline-ms", 2000u64);
    let probe_interval_ms = args.get_parse_or("probe-interval-ms", 250u64);
    let numerics = numerics_from_args(args)?;
    args.finish();

    let mut specs: Vec<mbkk::serve::http::ModelSpec> = Vec::new();
    let mut recorded_plan: Option<Vec<usize>> = None;
    if model_paths.is_empty() {
        let ds = registry::load(&dataset, scale, seed);
        println!("no --model given: fitting a fresh model on {} first", ds.name);
        let spec = experiment::RunSpec {
            dataset: dataset.clone(),
            scale,
            kernel: experiment::KernelSpec::Gaussian { multiplier: 1.0 },
            algo: experiment::AlgoSpec::TruncKkm(mbkk::kkmeans::LearningRate::Beta),
            k: ds.num_classes().max(2),
            batch_size: 256,
            schedule: mbkk::kkmeans::ScheduleSpec::Fixed,
            tau: 100,
            max_iters: 60,
            epsilon: None,
            seed,
            // The throwaway model trains deterministically; only the
            // serving engine honours --numerics.
            numerics: NumericsMode::Deterministic,
        };
        let fitted =
            experiment::fit_servable_model(&spec, &ds, experiment::GramStrategy::default())?;
        specs.push(mbkk::serve::http::ModelSpec {
            name: format!("fit:{}", ds.name),
            model: fitted.model,
            watch: None,
        });
    } else {
        for p in &model_paths {
            // ArtifactWatch::new both reads the bytes and fingerprints
            // them, so --watch and plain loading share one read.
            let (w, bytes) = mbkk::serve::replicate::ArtifactWatch::new(Path::new(p))?;
            let model = mbkk::serve::format::model_from_bytes(&bytes)
                .with_context(|| format!("loading model artifact {p}"))?;
            // A shard plan recorded at fit time activates sharded serving
            // automatically — but only for single-model serving (the plan
            // is center-count specific), and an explicit --shards wins.
            if model_paths.len() == 1 && !shards_given && recorded_plan.is_none() {
                recorded_plan = mbkk::serve::format::model_shard_plan(&bytes)?;
            }
            specs.push(mbkk::serve::http::ModelSpec {
                name: p.clone(),
                model,
                watch: watch.then_some(w),
            });
        }
    }
    for spec in &specs {
        println!(
            "model:      {} (k={}, d={}, {} support points{})",
            spec.name,
            spec.model.k(),
            spec.model.d,
            spec.model.support_points(),
            if spec.watch.is_some() { ", watched" } else { "" }
        );
    }

    let cfg = mbkk::serve::http::ServeConfig {
        addr: format!("{addr}:{port}"),
        max_wait: std::time::Duration::from_micros(max_wait_us),
        max_batch_rows: max_batch.max(1),
        max_body_bytes: max_body_mb.max(1) * 1024 * 1024,
        request_deadline: std::time::Duration::from_millis(deadline_ms.max(1)),
        numerics,
        degraded_window: std::time::Duration::from_secs(degraded_window_s.max(1)),
        shards,
        shard_plan: recorded_plan,
        shard_replicas,
        shard_workers,
        partial_results,
        shard_attempts: shard_attempts.max(1),
        shard_backoff: std::time::Duration::from_millis(shard_backoff_ms),
        shard_deadline: std::time::Duration::from_millis(shard_deadline_ms.max(1)),
        probe_interval: std::time::Duration::from_millis(probe_interval_ms.max(1)),
        ..Default::default()
    };
    let sharded = cfg.shards > 0 || cfg.shard_plan.is_some() || !cfg.shard_workers.is_empty();
    let server = mbkk::serve::http::Server::bind_registry(specs, &cfg)?;
    let bound = server.local_addr()?;
    println!("listening:  http://{bound} (POST /v1/predict, GET /v1/models, GET /healthz)");
    println!("coalesce:   max-wait {max_wait_us}us, max-batch {} rows", cfg.max_batch_rows);
    if sharded {
        println!(
            "sharding:   {} merge, {} attempt(s), {}ms base backoff{}",
            if cfg.partial_results { "partial-results" } else { "strict" },
            cfg.shard_attempts,
            cfg.shard_backoff.as_millis(),
            if cfg.shard_workers.is_empty() {
                format!(", {} in-process replica(s)/shard", cfg.shard_replicas.max(1))
            } else {
                format!(", workers {:?}", cfg.shard_workers)
            }
        );
    }
    install_shutdown_handlers(server.shutdown_flag());
    let stats = server.run()?;
    println!(
        "shutdown:   served {} requests in {} batches ({} rows, {} coalesced batches, {} aborted)",
        stats.requests, stats.batches, stats.rows, stats.coalesced_batches,
        stats.aborted_requests
    );
    Ok(())
}

/// `shard-worker`: serve one shard of a model's support set over the
/// binary shard protocol (`POST /v1/shard-distances`) for a sharded
/// `mbkk serve` coordinator to dispatch to (DESIGN.md §14). The shard
/// plan comes from the artifact header (recorded by `fit --shards`)
/// unless `--shards` overrides it with an even split.
fn shard_worker(args: &Args) -> Result<()> {
    let model_path = args.get_or("model", "model.mbkk");
    let shard = args.get_parse_or("shard", 0usize);
    let shards = args.get_parse_or("shards", 0usize);
    let addr = args.get_or("addr", "127.0.0.1");
    let port = args.get_parse_or("port", 8620u16);
    let numerics = numerics_from_args(args)?;
    args.finish();

    let bytes = std::fs::read(Path::new(&model_path))
        .with_context(|| format!("reading model artifact {model_path}"))?;
    let model = mbkk::serve::format::model_from_bytes(&bytes)
        .with_context(|| format!("loading model artifact {model_path}"))?;
    let plan = match mbkk::serve::format::model_shard_plan(&bytes)? {
        Some(bounds) if shards == 0 => {
            mbkk::serve::shard::ShardPlan::from_bounds(bounds, model.k())?
        }
        None if shards == 0 => mbkk::bail!(
            "{model_path} records no shard plan; pass --shards N (and give the \
             coordinator the same split)"
        ),
        _ => mbkk::serve::shard::ShardPlan::contiguous(model.k(), shards),
    };
    let server = mbkk::serve::shard::ShardWorkerServer::bind(
        &model,
        &plan,
        shard,
        &format!("{addr}:{port}"),
        numerics,
    )?;
    let bound = server.local_addr()?;
    let (lo, hi) = plan.range(shard);
    println!(
        "shard:      {shard}/{} (centers {lo}..{hi} of k={}, plan {:?})",
        plan.shards(),
        model.k(),
        plan.bounds()
    );
    println!("listening:  http://{bound} (POST /v1/shard-distances, GET /healthz)");
    install_shutdown_handlers(server.shutdown_flag());
    let requests = server.run()?;
    println!("shutdown:   served {requests} shard requests");
    Ok(())
}

/// Route SIGINT/SIGTERM to the server's shutdown flag so `mbkk serve`
/// drains and exits cleanly (CI's `e2e-http` job sends SIGTERM and
/// asserts exit status 0). Calls the C `signal` entry point directly —
/// there is no libc crate in a zero-dependency build — and the handler
/// body only stores to an atomic, which is async-signal-safe.
#[cfg(unix)]
fn install_shutdown_handlers(flag: std::sync::Arc<std::sync::atomic::AtomicBool>) {
    use std::os::raw::c_int;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, OnceLock};

    static FLAG: OnceLock<Arc<AtomicBool>> = OnceLock::new();

    extern "C" fn on_signal(_sig: c_int) {
        if let Some(f) = FLAG.get() {
            f.store(true, Ordering::SeqCst);
        }
    }

    extern "C" {
        fn signal(signum: c_int, handler: extern "C" fn(c_int)) -> usize;
    }

    const SIGINT: c_int = 2;
    const SIGTERM: c_int = 15;
    let _ = FLAG.set(flag);
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

#[cfg(not(unix))]
fn install_shutdown_handlers(_flag: std::sync::Arc<std::sync::atomic::AtomicBool>) {}

fn run_figures(args: &Args) -> Result<()> {
    let opts = figures::FigureOptions {
        scale: args.get_parse_or("scale", 0.25f64),
        repeats: args.get_parse_or("repeats", 3usize),
        max_iters: args.get_parse_or("iters", 200usize),
        quick: args.flag("quick"),
        seed: args.get_parse_or("seed", 7u64),
    };
    let out_dir = args.get_or("out", "results");
    let all = args.flag("all");
    let fig: Option<usize> = args.get("fig").map(|f| f.parse().expect("--fig"));
    args.finish();
    let ids: Vec<usize> = if all {
        figures::figure_ids()
    } else {
        vec![fig.expect("pass --fig N or --all")]
    };
    for id in ids {
        let rows = figures::run_figure(id, &opts, Some(Path::new(&out_dir)))?;
        println!("figure {id}: {} rows -> {out_dir}/", rows.len());
    }
    Ok(())
}

fn gamma_table(args: &Args) -> Result<()> {
    let scale = args.get_parse_or("scale", 0.1f64);
    let seed = args.get_parse_or("seed", 7u64);
    let out_dir = args.get_or("out", "results");
    args.finish();
    let md = figures::run_gamma_table(scale, seed, Some(Path::new(&out_dir)))?;
    println!("{md}");
    Ok(())
}

/// `repro-speedup`: the paper-reproduction preset. Runs full-batch vs
/// mini-batch (fixed and nested schedules) across the registry proxies
/// under a shared ε and writes the Table-1-style artifacts.
fn repro_speedup(args: &Args) -> Result<()> {
    let mut opts = repro::ReproOptions::default();
    opts.datasets = args.get_list("datasets", &opts.datasets);
    opts.scale = args.get_parse_or("scale", opts.scale);
    opts.seed = args.get_parse_or("seed", opts.seed);
    opts.batch_size = args.get_parse_or("batch", opts.batch_size);
    opts.tau = args.get_parse_or("tau", opts.tau);
    opts.max_iters = args.get_parse_or("iters", opts.max_iters);
    opts.epsilon = args.get_parse_or("epsilon", opts.epsilon);
    opts.growth = args.get_parse_or("growth", opts.growth);
    let out_dir = args.get_or("out", "results/repro");
    args.finish();

    let rows = repro::run_repro(&opts);
    repro::write_artifacts(Path::new(&out_dir), &rows)?;
    println!("{}", repro::to_markdown(&rows));
    println!(
        "wrote {out_dir}/repro_speedup.csv (deterministic), \
         repro_speedup_timings.csv, repro_speedup.md"
    );
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    args.finish();
    println!("mbkk {}", mbkk::VERSION);
    println!("threads: {}", mbkk::util::parallel::num_threads());
    println!("datasets: {:?}", registry::ALL);
    let dir = runtime::DEFAULT_ARTIFACT_DIR;
    if runtime::artifacts_available(dir) {
        let manifest = runtime::Manifest::load(Path::new(dir))?;
        println!("artifacts ({}):", manifest.artifacts.len());
        for a in &manifest.artifacts {
            println!(
                "  {} (b={}, k={}, m={}, d={:?})",
                a.name, a.b, a.k, a.m, a.d
            );
        }
        let backend = runtime::XlaBackend::load(Path::new(dir))?;
        println!("xla backend: available ({})", backend.name());
    } else {
        println!("artifacts: none (run `make artifacts`)");
    }
    Ok(())
}
