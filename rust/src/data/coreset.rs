//! Coreset composition (paper §2: "our results are *complementary* to
//! coresets … we can compose our method with these techniques").
//!
//! A coreset is a weighted subset of X whose clustering cost approximates
//! the full dataset's. This module provides the two standard lightweight
//! constructions and the plumbing to run any of the (weighted) kernel
//! k-means algorithms on top:
//!
//! * [`uniform_coreset`] — m uniform points, each weighted n/m. Unbiased
//!   for every fixed center set; the baseline construction.
//! * [`sensitivity_coreset`] — importance sampling à la Feldman et al.:
//!   points are sampled proportionally to their distance to a rough
//!   solution (a k-means++ seeding) plus a uniform floor, and weighted by
//!   inverse probability. Sharper on imbalanced data.

use super::Dataset;
use crate::util::rng::Rng;

/// Uniform coreset: `m` points sampled without replacement, weight `n/m`
/// each (existing weights are scaled, preserving total mass).
pub fn uniform_coreset(ds: &Dataset, m: usize, rng: &mut Rng) -> Dataset {
    let m = m.clamp(1, ds.n);
    let idx = rng.sample_without_replacement(ds.n, m);
    let mut out = ds.subset(&idx);
    let scale = ds.n as f64 / m as f64;
    let weights = match &out.weights {
        Some(w) => w.iter().map(|&x| x * scale).collect(),
        None => vec![scale; m],
    };
    out.weights = Some(weights);
    out.name = format!("{}:coreset{m}", ds.name);
    out
}

/// Sensitivity-sampling coreset: sample `m` points with replacement with
/// probability `p_i ∝ d²(x_i, S) + mean`, where S is a k-means++ seeding of
/// size `k`; weight each sampled point `1/(m·p_i)` (duplicates merge by
/// accumulating weight).
pub fn sensitivity_coreset(ds: &Dataset, m: usize, k: usize, rng: &mut Rng) -> Dataset {
    assert!(k >= 1 && ds.n >= 1);
    let m = m.clamp(1, ds.n * 4);
    // Rough solution: k-means++ seeds on raw features.
    let seeds = crate::kmeans::kmeanspp_features(ds, k.min(ds.n), rng);
    let d = ds.d;
    let k_eff = seeds.len() / d;
    let mut dist2 = vec![0.0f64; ds.n];
    for i in 0..ds.n {
        let mut best = f64::INFINITY;
        for j in 0..k_eff {
            let mut s = 0.0;
            for (x, c) in ds.row(i).iter().zip(&seeds[j * d..(j + 1) * d]) {
                let diff = *x as f64 - c;
                s += diff * diff;
            }
            best = best.min(s);
        }
        dist2[i] = best;
    }
    let mean = dist2.iter().sum::<f64>() / ds.n as f64;
    let sens: Vec<f64> = dist2.iter().map(|&v| v + mean.max(1e-12)).collect();
    let total: f64 = sens.iter().sum();

    // Sample with replacement; merge duplicates by weight accumulation.
    let mut weight_of: std::collections::BTreeMap<usize, f64> = Default::default();
    for _ in 0..m {
        let i = rng.weighted_choice(&sens);
        let p = sens[i] / total;
        *weight_of.entry(i).or_insert(0.0) += ds.weight(i) / (m as f64 * p);
    }
    let idx: Vec<usize> = weight_of.keys().copied().collect();
    let mut out = ds.subset(&idx);
    out.weights = Some(weight_of.values().copied().collect());
    out.name = format!("{}:scoreset{m}", ds.name);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::kernels::{Gram, KernelFunction};
    use crate::kkmeans::{TruncatedConfig, TruncatedMiniBatchKernelKMeans};
    use crate::metrics::ari;

    fn fixture() -> Dataset {
        let mut rng = Rng::seeded(61);
        blobs(
            &SyntheticSpec::new(2000, 6, 4).with_std(0.4).with_separation(6.0),
            &mut rng,
        )
    }

    #[test]
    fn uniform_coreset_preserves_mass() {
        let ds = fixture();
        let mut rng = Rng::seeded(1);
        let cs = uniform_coreset(&ds, 200, &mut rng);
        assert_eq!(cs.n, 200);
        let mass: f64 = cs.weights.as_ref().unwrap().iter().sum();
        assert!((mass - ds.n as f64).abs() < 1e-6);
    }

    #[test]
    fn sensitivity_coreset_unbiased_mass() {
        let ds = fixture();
        let mut rng = Rng::seeded(2);
        let cs = sensitivity_coreset(&ds, 400, 4, &mut rng);
        assert!(cs.n <= 400);
        let mass: f64 = cs.weights.as_ref().unwrap().iter().sum();
        // E[mass] = n; inverse-probability weights have heavy tails, so the
        // tolerance is loose.
        let rel = (mass - ds.n as f64).abs() / (ds.n as f64);
        assert!(rel < 0.5, "mass={mass} vs n={}", ds.n);
    }

    #[test]
    fn clustering_composes_with_coreset() {
        // Cluster the coreset with weighted Algorithm 2, then judge the
        // *coreset* labels against ground truth restricted to the coreset.
        let ds = fixture();
        let mut rng = Rng::seeded(3);
        let cs = uniform_coreset(&ds, 400, &mut rng);
        let gram = Gram::on_the_fly(&cs, KernelFunction::Gaussian { kappa: 12.0 });
        let cfg = TruncatedConfig {
            k: 4,
            batch_size: 128,
            tau: 100,
            max_iters: 60,
            weights: cs.weights.clone(),
            ..Default::default()
        };
        let res = TruncatedMiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        let truth = cs.labels.as_ref().unwrap();
        let score = ari(truth, &res.assignments);
        assert!(score > 0.85, "coreset clustering ARI={score}");
    }

    #[test]
    fn coreset_of_everything_is_identity_weighted() {
        let ds = fixture();
        let mut rng = Rng::seeded(4);
        let cs = uniform_coreset(&ds, ds.n, &mut rng);
        assert_eq!(cs.n, ds.n);
        assert!(cs.weights.as_ref().unwrap().iter().all(|&w| (w - 1.0).abs() < 1e-9));
    }
}
