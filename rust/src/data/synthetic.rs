//! Synthetic dataset generators.
//!
//! These stand in for the paper's UCI datasets (no network in this build)
//! and additionally provide the *non-linearly-separable* workloads that the
//! paper's introduction motivates kernel k-means with: concentric rings and
//! interleaved moons, where plain k-means fails but a Gaussian-kernel
//! feature space separates the classes.

use super::Dataset;
use crate::util::rng::Rng;

/// Parameters for the Gaussian-blob generator.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    /// Number of points.
    pub n: usize,
    /// Feature dimension.
    pub d: usize,
    /// Number of clusters.
    pub k: usize,
    /// Within-cluster standard deviation.
    pub cluster_std: f64,
    /// Distance scale between cluster centers.
    pub separation: f64,
    /// Fraction of points whose label is resampled uniformly (label noise),
    /// which caps achievable ARI like real data does.
    pub label_noise: f64,
}

impl SyntheticSpec {
    /// Spec with default geometry (std 1.0, separation 4.0, no noise).
    pub fn new(n: usize, d: usize, k: usize) -> SyntheticSpec {
        SyntheticSpec { n, d, k, cluster_std: 1.0, separation: 4.0, label_noise: 0.0 }
    }

    /// Set the within-cluster standard deviation.
    pub fn with_std(mut self, s: f64) -> Self {
        self.cluster_std = s;
        self
    }

    /// Set the center-separation scale.
    pub fn with_separation(mut self, s: f64) -> Self {
        self.separation = s;
        self
    }

    /// Set the label-noise fraction.
    pub fn with_label_noise(mut self, p: f64) -> Self {
        self.label_noise = p;
        self
    }
}

/// Isotropic Gaussian blobs: k centers drawn from N(0, separation²·I),
/// points N(center, cluster_std²·I), cluster sizes multinomial-uniform.
pub fn blobs(spec: &SyntheticSpec, rng: &mut Rng) -> Dataset {
    let SyntheticSpec { n, d, k, cluster_std, separation, label_noise } = *spec;
    assert!(k >= 1 && n >= k);
    let mut centers = vec![0.0f64; k * d];
    for c in centers.iter_mut() {
        *c = rng.normal() * separation;
    }
    let mut features = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(k);
        for j in 0..d {
            features.push((centers[c * d + j] + rng.normal() * cluster_std) as f32);
        }
        let lab = if label_noise > 0.0 && rng.f64() < label_noise {
            rng.below(k)
        } else {
            c
        };
        labels.push(lab);
    }
    Dataset::new("blobs", features, n, d).with_labels(labels)
}

/// Concentric rings in the first two dimensions (remaining dimensions are
/// small-noise): k rings with radii 1, 2, ..., k. Not linearly separable —
/// the motivating case for kernel k-means.
pub fn rings(n: usize, d: usize, k: usize, noise: f64, rng: &mut Rng) -> Dataset {
    assert!(d >= 2 && k >= 1);
    let mut features = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(k);
        let radius = (c + 1) as f64;
        let theta = rng.f64() * std::f64::consts::TAU;
        features.push((radius * theta.cos() + rng.normal() * noise) as f32);
        features.push((radius * theta.sin() + rng.normal() * noise) as f32);
        for _ in 2..d {
            features.push((rng.normal() * noise) as f32);
        }
        labels.push(c);
    }
    Dataset::new("rings", features, n, d).with_labels(labels)
}

/// Two interleaved half-moons (k is fixed at 2), the classic sklearn
/// `make_moons` workload. Extra dimensions are noise.
pub fn moons(n: usize, d: usize, noise: f64, rng: &mut Rng) -> Dataset {
    assert!(d >= 2);
    let mut features = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(2);
        let t = rng.f64() * std::f64::consts::PI;
        let (x, y) = if c == 0 {
            (t.cos(), t.sin())
        } else {
            (1.0 - t.cos(), 0.5 - t.sin())
        };
        features.push((x + rng.normal() * noise) as f32);
        features.push((y + rng.normal() * noise) as f32);
        for _ in 2..d {
            features.push((rng.normal() * noise) as f32);
        }
        labels.push(c);
    }
    Dataset::new("moons", features, n, d).with_labels(labels)
}

/// "Manifold blobs": Gaussian blobs in a low-dimensional latent space pushed
/// through a random nonlinear map (tanh of a random projection plus a
/// quadratic warp) into `d` dimensions. This mimics image-like data (MNIST):
/// clusters live on curved manifolds and are *not* linearly separable in the
/// ambient space, so kernel methods gain a margin over plain k-means.
pub fn manifold_blobs(
    n: usize,
    latent_d: usize,
    ambient_d: usize,
    k: usize,
    rng: &mut Rng,
) -> Dataset {
    assert!(latent_d >= 1 && ambient_d >= latent_d);
    let latent = blobs(
        &SyntheticSpec::new(n, latent_d, k)
            .with_std(0.7)
            .with_separation(2.0),
        rng,
    );
    // Random projection W: latent_d → ambient_d and quadratic mixing.
    let mut w = vec![0.0f64; latent_d * ambient_d];
    for v in w.iter_mut() {
        *v = rng.normal() / (latent_d as f64).sqrt();
    }
    let mut w2 = vec![0.0f64; latent_d * ambient_d];
    for v in w2.iter_mut() {
        *v = rng.normal() / latent_d as f64;
    }
    let mut features = Vec::with_capacity(n * ambient_d);
    for i in 0..n {
        let z = latent.row(i);
        for j in 0..ambient_d {
            let mut lin = 0.0f64;
            let mut quad = 0.0f64;
            for (l, &zl) in z.iter().enumerate() {
                lin += w[l * ambient_d + j] * zl as f64;
                quad += w2[l * ambient_d + j] * (zl as f64) * (zl as f64);
            }
            features.push((lin.tanh() + 0.5 * quad.tanh() + rng.normal() * 0.05) as f32);
        }
    }
    Dataset::new("manifold_blobs", features, n, ambient_d)
        .with_labels(latent.labels.unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blobs_shapes_and_labels() {
        let mut rng = Rng::seeded(1);
        let ds = blobs(&SyntheticSpec::new(500, 4, 3), &mut rng);
        assert_eq!((ds.n, ds.d), (500, 4));
        let labels = ds.labels.as_ref().unwrap();
        assert_eq!(labels.len(), 500);
        assert!(labels.iter().all(|&l| l < 3));
        // All three clusters represented.
        assert_eq!(ds.num_classes(), 3);
    }

    #[test]
    fn blobs_are_separated_when_asked() {
        let mut rng = Rng::seeded(2);
        let ds = blobs(
            &SyntheticSpec::new(600, 8, 3).with_std(0.2).with_separation(10.0),
            &mut rng,
        );
        let labels = ds.labels.as_ref().unwrap();
        // Within-cluster distances should be far below between-cluster ones.
        let mut within = 0.0;
        let mut wcount = 0.0;
        let mut between = 0.0;
        let mut bcount = 0.0;
        for i in (0..ds.n).step_by(7) {
            for j in (i + 1..ds.n).step_by(11) {
                let d2 = ds.sqdist(i, j);
                if labels[i] == labels[j] {
                    within += d2;
                    wcount += 1.0;
                } else {
                    between += d2;
                    bcount += 1.0;
                }
            }
        }
        assert!(within / wcount < between / bcount / 10.0);
    }

    #[test]
    fn rings_have_correct_radii() {
        let mut rng = Rng::seeded(3);
        let ds = rings(900, 2, 3, 0.0, &mut rng);
        let labels = ds.labels.as_ref().unwrap();
        for i in 0..ds.n {
            let r = ((ds.row(i)[0] as f64).powi(2) + (ds.row(i)[1] as f64).powi(2)).sqrt();
            assert!((r - (labels[i] + 1) as f64).abs() < 1e-5);
        }
    }

    #[test]
    fn moons_two_classes() {
        let mut rng = Rng::seeded(4);
        let ds = moons(400, 3, 0.05, &mut rng);
        assert_eq!(ds.num_classes(), 2);
        assert_eq!(ds.d, 3);
    }

    #[test]
    fn manifold_blobs_bounded_features() {
        let mut rng = Rng::seeded(5);
        let ds = manifold_blobs(300, 4, 32, 5, &mut rng);
        assert_eq!((ds.n, ds.d), (300, 32));
        // tanh-based map keeps features bounded.
        assert!(ds.features.iter().all(|v| v.abs() < 2.5));
    }

    #[test]
    fn label_noise_flips_some_labels() {
        let mut rng = Rng::seeded(6);
        let clean = blobs(&SyntheticSpec::new(2000, 2, 4).with_separation(50.0), &mut rng);
        let mut rng2 = Rng::seeded(6);
        let noisy = blobs(
            &SyntheticSpec::new(2000, 2, 4).with_separation(50.0).with_label_noise(0.3),
            &mut rng2,
        );
        let same = clean
            .labels
            .unwrap()
            .iter()
            .zip(noisy.labels.unwrap().iter())
            .filter(|(a, b)| a == b)
            .count();
        assert!(same < 2000);
    }
}
