//! Registry of the paper-proxy datasets.
//!
//! The paper evaluates on MNIST (70000×784, k=10), PenDigits (10992×16,
//! k=10), Letters (20000×16, k=26) and HAR (10299×561, k=6). This build has
//! no network access, so each is replaced by a synthetic proxy with matched
//! `(n, d, k)` shape and a geometry that exercises the same algorithmic
//! behaviour (see DESIGN.md §3):
//!
//! * `synth_pendigits` — 10992×16, k=10: manifold blobs (pen trajectories
//!   are low-dimensional curves embedded in R¹⁶).
//! * `synth_letters`   — 20000×16, k=26: Gaussian blobs with heavy overlap
//!   (letters have the lowest ARI in the paper).
//! * `synth_har`       — 10299×64, k=6: manifold blobs, few clusters,
//!   moderately separated (sensor data; d reduced 561→64 to keep the O(n²)
//!   full-batch baseline within the time budget — documented substitution).
//! * `synth_mnist`     — 10000×128, k=10: manifold blobs from a 16-d latent
//!   space (images on a low-dimensional manifold; n reduced 70000→10000 so
//!   the full-batch baseline is feasible; d reduced 784→128).
//! * `rings` / `moons` — the non-linearly-separable motivating workloads.
//! * `blobs_1m`        — 1,000,000×16, k=10: the million-point scale
//!   scenario; only tractable through the streaming kernel provider
//!   (a dense gram would be 4 TB — see DESIGN.md §6).
//!
//! All proxies are deterministic in the seed, standardized, and sized by a
//! global `scale` factor so CI-time runs can shrink the grid uniformly.

use super::scaling::standardize;
use super::synthetic::{self, SyntheticSpec};
use super::Dataset;
use crate::util::rng::Rng;

/// Names accepted by [`load`].
pub const ALL: &[&str] = &[
    "synth_pendigits",
    "synth_letters",
    "synth_har",
    "synth_mnist",
    "rings",
    "moons",
    "blobs",
    "blobs_1m",
];

/// The four paper-figure proxies in the paper's plotting order.
pub const PAPER_PROXIES: &[&str] =
    &["synth_mnist", "synth_har", "synth_letters", "synth_pendigits"];

/// Ground-truth k for each registry dataset.
pub fn default_k(name: &str) -> usize {
    match name {
        "synth_pendigits" => 10,
        "synth_letters" => 26,
        "synth_har" => 6,
        "synth_mnist" => 10,
        "rings" => 3,
        "moons" => 2,
        "blobs" => 5,
        "blobs_1m" => 10,
        _ => panic!("unknown dataset {name:?}"),
    }
}

/// Build a registry dataset. `scale` multiplies n (clamped to ≥ 50·k so
/// every cluster stays populated); `seed` drives the generator.
pub fn load(name: &str, scale: f64, seed: u64) -> Dataset {
    let mut rng = Rng::seeded(seed ^ 0xDA7A_5E7);
    let scaled = |n: usize, k: usize| ((n as f64 * scale) as usize).max(50 * k);
    let mut ds = match name {
        "synth_pendigits" => {
            let n = scaled(10992, 10);
            let mut d = synthetic::manifold_blobs(n, 4, 16, 10, &mut rng);
            d.name = name.into();
            d
        }
        "synth_letters" => {
            let n = scaled(20000, 26);
            let mut d = synthetic::blobs(
                &SyntheticSpec::new(n, 16, 26).with_std(1.0).with_separation(1.6),
                &mut rng,
            );
            d.name = name.into();
            d
        }
        "synth_har" => {
            let n = scaled(10299, 6);
            let mut d = synthetic::manifold_blobs(n, 6, 64, 6, &mut rng);
            d.name = name.into();
            d
        }
        "synth_mnist" => {
            let n = scaled(10000, 10);
            let mut d = synthetic::manifold_blobs(n, 16, 128, 10, &mut rng);
            d.name = name.into();
            d
        }
        "rings" => {
            let n = scaled(6000, 3);
            synthetic::rings(n, 2, 3, 0.11, &mut rng)
        }
        "moons" => {
            let n = scaled(4000, 2);
            synthetic::moons(n, 2, 0.08, &mut rng)
        }
        "blobs" => {
            let n = scaled(5000, 5);
            synthetic::blobs(&SyntheticSpec::new(n, 8, 5).with_separation(3.0), &mut rng)
        }
        "blobs_1m" => {
            // The million-point scale scenario (ISSUE 2): a dense n×n gram
            // would be 4 TB, so this dataset is only tractable through the
            // streaming provider. Generation is O(n·d) and deterministic.
            let n = scaled(1_000_000, 10);
            let mut d = synthetic::blobs(
                &SyntheticSpec::new(n, 16, 10).with_separation(3.0),
                &mut rng,
            );
            d.name = name.into();
            d
        }
        other => panic!("unknown dataset {other:?} (known: {ALL:?})"),
    };
    standardize(&mut ds);
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_registry_datasets_load_at_small_scale() {
        for &name in ALL {
            let ds = load(name, 0.02, 7);
            assert!(ds.n >= 50, "{name}: n={}", ds.n);
            assert!(ds.d >= 2);
            assert_eq!(ds.name, name);
            let k = default_k(name);
            assert_eq!(ds.num_classes(), k, "{name}");
            assert!(ds.features.iter().all(|v| v.is_finite()), "{name}");
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = load("rings", 0.05, 3);
        let b = load("rings", 0.05, 3);
        assert_eq!(a.features, b.features);
        let c = load("rings", 0.05, 4);
        assert_ne!(a.features, c.features);
    }

    #[test]
    fn scale_changes_n() {
        let small = load("blobs", 0.05, 1);
        let big = load("blobs", 0.2, 1);
        assert!(big.n > small.n);
    }

    #[test]
    #[should_panic(expected = "unknown dataset")]
    fn unknown_name_panics() {
        let _ = load("nope", 1.0, 0);
    }
}
