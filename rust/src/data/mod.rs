//! Dataset pipeline: the [`Dataset`] type, CSV I/O, feature scaling,
//! synthetic generators, and the registry of paper-proxy datasets.
//!
//! The paper evaluates on MNIST, PenDigits, Letters, and HAR (UCI
//! downloads). This environment has no network, so [`registry`] provides
//! synthetic proxies with matched `(n, d, k)` and controlled cluster
//! geometry — see DESIGN.md §3 for the substitution argument.

mod dataset;
pub mod coreset;
pub mod csvio;
pub mod registry;
pub mod scaling;
pub mod synthetic;

pub use dataset::Dataset;
