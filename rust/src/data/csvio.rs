//! CSV load/save for datasets.
//!
//! Format: one row per point; numeric feature columns; an optional final
//! `label` column (detected via header or `label_col`). This lets users run
//! the CLI on their own data (`mbkk run --csv path.csv`), and lets the
//! figure pipeline persist generated datasets for inspection.

use super::Dataset;
use crate::bail;
use crate::util::error::{Context, Result};
use std::path::Path;

/// Load a dataset from a CSV file. If the first line is non-numeric it is
/// treated as a header; a column named `label` (case-insensitive) becomes
/// the ground-truth labels.
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "csv".to_string());
    parse_csv(&name, &text)
}

/// Parse CSV text (exposed for tests).
pub fn parse_csv(name: &str, text: &str) -> Result<Dataset> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty()).peekable();
    let first = match lines.peek() {
        Some(l) => *l,
        None => bail!("empty csv"),
    };
    let first_fields: Vec<&str> = first.split(',').map(str::trim).collect();
    let has_header = first_fields.iter().any(|f| f.parse::<f64>().is_err());

    let mut label_col: Option<usize> = None;
    if has_header {
        for (i, f) in first_fields.iter().enumerate() {
            if f.eq_ignore_ascii_case("label") || f.eq_ignore_ascii_case("class") {
                label_col = Some(i);
            }
        }
        lines.next();
    }

    let mut features: Vec<f32> = Vec::new();
    let mut labels: Vec<usize> = Vec::new();
    let mut d: Option<usize> = None;
    let mut n = 0usize;
    for (lineno, line) in lines.enumerate() {
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        let width = fields.len();
        match d {
            None => d = Some(width),
            Some(w) if w != width => {
                bail!("row {} has {} fields, expected {}", lineno + 1, width, w)
            }
            _ => {}
        }
        for (i, f) in fields.iter().enumerate() {
            if Some(i) == label_col {
                let lab: f64 = f.parse().with_context(|| format!("bad label {f:?}"))?;
                labels.push(lab as usize);
            } else {
                let v: f32 = f
                    .parse()
                    .with_context(|| format!("row {} col {i}: bad number {f:?}", lineno + 1))?;
                features.push(v);
            }
        }
        n += 1;
    }
    let width = d.context("csv has no data rows")?;
    let feat_d = width - usize::from(label_col.is_some());
    let mut ds = Dataset::new(name, features, n, feat_d);
    if label_col.is_some() {
        ds = ds.with_labels(labels);
    }
    Ok(ds)
}

/// Write a dataset (features + optional label column) to CSV.
pub fn save_csv(ds: &Dataset, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = String::new();
    for j in 0..ds.d {
        if j > 0 {
            out.push(',');
        }
        out.push_str(&format!("f{j}"));
    }
    if ds.labels.is_some() {
        out.push_str(",label");
    }
    out.push('\n');
    for i in 0..ds.n {
        for (j, v) in ds.row(i).iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push_str(&format!("{v}"));
        }
        if let Some(ls) = &ds.labels {
            out.push_str(&format!(",{}", ls[i]));
        }
        out.push('\n');
    }
    std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_with_header_and_labels() {
        let ds = parse_csv("t", "f0,f1,label\n1,2,0\n3,4,1\n").unwrap();
        assert_eq!((ds.n, ds.d), (2, 2));
        assert_eq!(ds.row(1), &[3.0, 4.0]);
        assert_eq!(ds.labels.as_ref().unwrap(), &vec![0, 1]);
    }

    #[test]
    fn parse_headerless() {
        let ds = parse_csv("t", "1.5,2.5\n3.5,4.5\n").unwrap();
        assert_eq!((ds.n, ds.d), (2, 2));
        assert!(ds.labels.is_none());
    }

    #[test]
    fn ragged_rows_error() {
        assert!(parse_csv("t", "1,2\n3\n").is_err());
    }

    #[test]
    fn garbage_errors() {
        assert!(parse_csv("t", "1,foo\n").is_err());
        assert!(parse_csv("t", "").is_err());
        assert!(parse_csv("t", "a,b\n").is_err()); // header but no rows
    }

    #[test]
    fn roundtrip_via_tempfile() {
        let dir = std::env::temp_dir().join("mbkk_csv_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("roundtrip.csv");
        let ds = Dataset::new("rt", vec![1.0, 2.0, 3.0, 4.0], 2, 2).with_labels(vec![1, 0]);
        save_csv(&ds, &path).unwrap();
        let back = load_csv(&path).unwrap();
        assert_eq!(back.n, 2);
        assert_eq!(back.d, 2);
        assert_eq!(back.row(0), ds.row(0));
        assert_eq!(back.labels, ds.labels);
        let _ = std::fs::remove_file(&path);
    }
}
