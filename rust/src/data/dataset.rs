//! The core dataset container.

use crate::util::fmath;
use std::sync::OnceLock;

/// A dataset of `n` points with `d` features each, stored row-major in f32
/// (matching the compute path), plus optional integer ground-truth labels
/// (used only for ARI/NMI evaluation, never by the clustering algorithms)
/// and optional per-point weights (the paper's weighted variant).
///
/// The per-row squared norms `‖x_i‖²` are computed once on first use and
/// cached ([`Dataset::sq_norms`]): the panel kernel engine, the σ/κ
/// bandwidth heuristic, and k-means++ D² sampling all expand squared
/// distances as `‖x‖² + ‖y‖² − 2⟨x,y⟩` against this cache instead of
/// re-deriving differences per pair. Code that mutates `features` in place
/// after construction must call [`Dataset::invalidate_caches`] (the
/// standard scaler does).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Display name for reports.
    pub name: String,
    /// Row-major features, length `n * d`.
    pub features: Vec<f32>,
    /// Number of points.
    pub n: usize,
    /// Feature dimension.
    pub d: usize,
    /// Ground-truth cluster labels (evaluation only).
    pub labels: Option<Vec<usize>>,
    /// Optional per-point weights for the weighted kernel k-means variant;
    /// `None` means uniform weight 1.
    pub weights: Option<Vec<f64>>,
    /// Lazily computed per-row squared norms (see [`Dataset::sq_norms`]).
    sq_norms: OnceLock<Vec<f64>>,
}

impl Dataset {
    /// Wrap row-major features into a dataset (panics on shape mismatch).
    pub fn new(name: &str, features: Vec<f32>, n: usize, d: usize) -> Dataset {
        assert_eq!(features.len(), n * d, "features length != n*d");
        Dataset {
            name: name.to_string(),
            features,
            n,
            d,
            labels: None,
            weights: None,
            sq_norms: OnceLock::new(),
        }
    }

    /// Attach ground-truth labels (evaluation only).
    pub fn with_labels(mut self, labels: Vec<usize>) -> Dataset {
        assert_eq!(labels.len(), self.n, "labels length != n");
        self.labels = Some(labels);
        self
    }

    /// Attach positive per-point weights (the weighted variant).
    pub fn with_weights(mut self, weights: Vec<f64>) -> Dataset {
        assert_eq!(weights.len(), self.n, "weights length != n");
        assert!(weights.iter().all(|&w| w > 0.0), "weights must be positive");
        self.weights = Some(weights);
        self
    }

    /// Row `i` as a feature slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.n);
        &self.features[i * self.d..(i + 1) * self.d]
    }

    /// Weight of point `i` (1.0 when unweighted).
    #[inline]
    pub fn weight(&self, i: usize) -> f64 {
        self.weights.as_ref().map(|w| w[i]).unwrap_or(1.0)
    }

    /// Number of distinct ground-truth labels (0 when unlabeled).
    pub fn num_classes(&self) -> usize {
        self.labels
            .as_ref()
            .map(|ls| ls.iter().copied().max().map(|m| m + 1).unwrap_or(0))
            .unwrap_or(0)
    }

    /// Per-row squared norms `‖x_i‖²`, computed once (in parallel) and
    /// cached. Each entry is one sequential f64 chain over the row — the
    /// exact reduction [`crate::util::fmath::dot_f64`] performs — so the
    /// panel engine's norms-expansion distances are deterministic.
    pub fn sq_norms(&self) -> &[f64] {
        let norms = self.sq_norms.get_or_init(|| {
            let mut norms = vec![0.0f64; self.n];
            crate::util::parallel::par_chunks_mut(&mut norms, |start, chunk| {
                for (i, out) in chunk.iter_mut().enumerate() {
                    *out = fmath::sq_norm_f64(self.row(start + i));
                }
            });
            norms
        });
        debug_assert_eq!(
            norms.len(),
            self.n,
            "stale sq_norms: features were resized without invalidate_caches"
        );
        norms
    }

    /// Drop the cached squared norms. Must be called by anything that
    /// mutates `features` in place after the cache may have been built.
    pub fn invalidate_caches(&mut self) {
        self.sq_norms = OnceLock::new();
    }

    /// Squared Euclidean distance between rows `i` and `j`, via the cached
    /// norms: `(‖x_i‖² + ‖x_j‖²) − 2⟨x_i, x_j⟩`, clamped at 0.
    #[inline]
    pub fn sqdist(&self, i: usize, j: usize) -> f64 {
        let norms = self.sq_norms();
        fmath::sqdist_from_norms(norms[i], norms[j], fmath::dot_f64(self.row(i), self.row(j)))
    }

    /// Subsample the first `m` points of a deterministic permutation given by
    /// `order` (callers pass an RNG-shuffled index vector). Keeps labels and
    /// weights aligned.
    pub fn subset(&self, order: &[usize]) -> Dataset {
        let mut features = Vec::with_capacity(order.len() * self.d);
        for &i in order {
            features.extend_from_slice(self.row(i));
        }
        let labels = self
            .labels
            .as_ref()
            .map(|ls| order.iter().map(|&i| ls[i]).collect());
        let weights = self
            .weights
            .as_ref()
            .map(|ws| order.iter().map(|&i| ws[i]).collect::<Vec<_>>());
        let mut out = Dataset::new(&self.name, features, order.len(), self.d);
        out.labels = labels;
        out.weights = weights;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new("t", vec![0.0, 0.0, 3.0, 4.0, 1.0, 1.0], 3, 2)
            .with_labels(vec![0, 1, 0])
    }

    #[test]
    fn row_access() {
        let ds = tiny();
        assert_eq!(ds.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn sqdist_euclidean() {
        let ds = tiny();
        assert_eq!(ds.sqdist(0, 1), 25.0);
        assert_eq!(ds.sqdist(0, 0), 0.0);
        assert_eq!(ds.sqdist(0, 2), 2.0);
    }

    #[test]
    fn num_classes_counts_from_labels() {
        let ds = tiny();
        assert_eq!(ds.num_classes(), 2);
        let un = Dataset::new("u", vec![0.0], 1, 1);
        assert_eq!(un.num_classes(), 0);
    }

    #[test]
    fn subset_keeps_alignment() {
        let ds = tiny().with_weights(vec![1.0, 2.0, 3.0]);
        let sub = ds.subset(&[2, 0]);
        assert_eq!(sub.n, 2);
        assert_eq!(sub.row(0), &[1.0, 1.0]);
        assert_eq!(sub.labels.as_ref().unwrap(), &vec![0, 0]);
        assert_eq!(sub.weights.as_ref().unwrap(), &vec![3.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "features length")]
    fn shape_mismatch_panics() {
        let _ = Dataset::new("bad", vec![1.0; 5], 2, 3);
    }

    #[test]
    fn default_weight_is_one() {
        let ds = tiny();
        assert_eq!(ds.weight(0), 1.0);
    }

    #[test]
    fn sq_norms_cached_and_invalidated() {
        let mut ds = tiny();
        assert_eq!(ds.sq_norms(), &[0.0, 25.0, 2.0][..]);
        // Mutating features without invalidation would serve stale norms;
        // invalidate_caches recomputes.
        ds.features[0] = 2.0;
        ds.invalidate_caches();
        assert_eq!(ds.sq_norms()[0], 4.0);
        assert_eq!(ds.sqdist(0, 2), 2.0); // (2−1)² + (0−1)²
    }
}
