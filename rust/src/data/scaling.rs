//! Feature scaling: per-feature standardization (zero mean, unit variance),
//! matching the preprocessing used for the paper's datasets before kernel
//! computation.

use super::Dataset;

/// Per-feature mean/std learned from a dataset.
#[derive(Clone, Debug)]
pub struct StandardScaler {
    /// Per-feature mean.
    pub mean: Vec<f64>,
    /// Per-feature standard deviation (1.0 for constant features).
    pub std: Vec<f64>,
}

impl StandardScaler {
    /// Fit on a dataset. Features with zero variance get std 1 (no-op).
    pub fn fit(ds: &Dataset) -> StandardScaler {
        let d = ds.d;
        let mut mean = vec![0.0f64; d];
        for i in 0..ds.n {
            for (m, v) in mean.iter_mut().zip(ds.row(i)) {
                *m += *v as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= ds.n.max(1) as f64;
        }
        let mut var = vec![0.0f64; d];
        for i in 0..ds.n {
            for ((s, v), m) in var.iter_mut().zip(ds.row(i)).zip(mean.iter()) {
                let diff = *v as f64 - m;
                *s += diff * diff;
            }
        }
        let std = var
            .into_iter()
            .map(|s| {
                let sd = (s / ds.n.max(1) as f64).sqrt();
                if sd > 1e-12 {
                    sd
                } else {
                    1.0
                }
            })
            .collect();
        StandardScaler { mean, std }
    }

    /// Apply in place (drops the dataset's cached squared norms, which the
    /// rewrite invalidates).
    pub fn transform(&self, ds: &mut Dataset) {
        assert_eq!(ds.d, self.mean.len());
        for i in 0..ds.n {
            let row = &mut ds.features[i * ds.d..(i + 1) * ds.d];
            for (j, v) in row.iter_mut().enumerate() {
                *v = ((*v as f64 - self.mean[j]) / self.std[j]) as f32;
            }
        }
        ds.invalidate_caches();
    }
}

/// Fit + transform convenience.
pub fn standardize(ds: &mut Dataset) {
    StandardScaler::fit(ds).transform(ds);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut ds = Dataset::new("t", vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0], 3, 2);
        standardize(&mut ds);
        for j in 0..2 {
            let mut m = 0.0;
            let mut v = 0.0;
            for i in 0..3 {
                m += ds.row(i)[j] as f64;
            }
            m /= 3.0;
            for i in 0..3 {
                v += (ds.row(i)[j] as f64 - m).powi(2);
            }
            v /= 3.0;
            assert!(m.abs() < 1e-6, "mean={m}");
            assert!((v - 1.0).abs() < 1e-5, "var={v}");
        }
    }

    #[test]
    fn constant_feature_is_noop() {
        let mut ds = Dataset::new("t", vec![5.0, 1.0, 5.0, 2.0], 2, 2);
        standardize(&mut ds);
        // Constant column becomes exactly zero (x - mean = 0), no NaN.
        assert_eq!(ds.row(0)[0], 0.0);
        assert!(ds.features.iter().all(|v| v.is_finite()));
    }
}
