//! Row-major dense f64 matrix with blocked, parallel multiplication and the
//! factorizations `expm` needs.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major values, length `rows * cols`.
    pub data: Vec<f64>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// The n×n identity.
    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a list of equal-length rows.
    pub fn from_rows(rows: Vec<Vec<f64>>) -> Matrix {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Matrix { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    /// Element (i, j).
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Mutable element (i, j).
    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// The transposed matrix.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Minimum fused multiply-adds a worker must have before another
    /// thread pays off (≈ a few hundred µs of GEMM work).
    const MIN_FLOPS_PER_WORKER: usize = 64 * 64 * 64;

    /// C = A·B — parallel over row blocks of C through the worker pool.
    /// The i-k-j loop order keeps the inner loop a contiguous FMA over B's
    /// row, which the compiler auto-vectorizes; the k loop is blocked so
    /// the touched rows of B stay L2-resident across the block's C rows
    /// (the expm Padé ladder multiplies 768×768 and larger, where B no
    /// longer fits in cache). Worker count comes from the *per-worker*
    /// flop estimate `m·k·n / workers`, not from a flat total threshold —
    /// the old check went parallel whenever the total crossed 64³, which
    /// for wide-thread machines handed each worker far less work than the
    /// dispatch cost.
    ///
    /// Zero entries of A are skipped only when A is actually sparse
    /// (≥ 1/8 zeros, as in the identity-plus-perturbation Padé terms); the
    /// dense variant runs branch-free in the inner loops.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut c = Matrix::zeros(m, n);
        if m == 0 || k == 0 || n == 0 {
            return c;
        }
        let a_data = &self.data;
        let b_data = &b.data;
        // One O(m·k) scan decides the kernel variant; trivial next to the
        // O(m·k·n) multiply it specializes.
        let zeros = a_data.iter().filter(|v| **v == 0.0).count();
        let sparse = zeros * 8 >= a_data.len();
        // Block the k loop so each block's rows of B (kc·n f64) fit in
        // ~128 KiB of L2 alongside the C rows being accumulated.
        let kc = (16 * 1024 / n.max(1)).max(16).min(k);
        let kernel = move |row0: usize, cblock: &mut [f64]| {
            let nrows = cblock.len() / n;
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + kc).min(k);
                for ir in 0..nrows {
                    let i = row0 + ir;
                    let crow = &mut cblock[ir * n..(ir + 1) * n];
                    let ablock = &a_data[i * k + k0..i * k + k1];
                    if sparse {
                        for (off, &aik) in ablock.iter().enumerate() {
                            if aik == 0.0 {
                                continue;
                            }
                            let brow = &b_data[(k0 + off) * n..(k0 + off + 1) * n];
                            for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                                *cv += aik * bv;
                            }
                        }
                    } else {
                        for (off, &aik) in ablock.iter().enumerate() {
                            let brow = &b_data[(k0 + off) * n..(k0 + off + 1) * n];
                            for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                                *cv += aik * bv;
                            }
                        }
                    }
                }
                k0 = k1;
            }
        };
        let workers = crate::util::parallel::num_threads()
            .min((m * k * n) / Self::MIN_FLOPS_PER_WORKER)
            .min(m)
            .max(1);
        crate::util::parallel::par_rows_mut_workers(&mut c.data, n, workers, kernel);
        c
    }

    /// Elementwise sum `self + B`.
    pub fn add(&self, b: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let mut out = self.clone();
        for (o, x) in out.data.iter_mut().zip(b.data.iter()) {
            *o += x;
        }
        out
    }

    /// Elementwise difference `self − B`.
    pub fn sub(&self, b: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let mut out = self.clone();
        for (o, x) in out.data.iter_mut().zip(b.data.iter()) {
            *o -= x;
        }
        out
    }

    /// Scalar multiple `s·self`.
    pub fn scale(&self, s: f64) -> Matrix {
        let mut out = self.clone();
        for o in out.data.iter_mut() {
            *o *= s;
        }
        out
    }

    /// In-place axpy: self += s·B.
    pub fn axpy(&mut self, s: f64, b: &Matrix) {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        for (o, x) in self.data.iter_mut().zip(b.data.iter()) {
            *o += s * x;
        }
    }

    /// Max column-sum norm (induced 1-norm) — used to pick the expm scaling.
    pub fn norm_1(&self) -> f64 {
        let mut sums = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for j in 0..self.cols {
                sums[j] += self.at(i, j).abs();
            }
        }
        sums.into_iter().fold(0.0, f64::max)
    }

    /// Frobenius norm.
    pub fn norm_fro(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Largest absolute elementwise difference to `b`.
    pub fn max_abs_diff(&self, b: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        self.data
            .iter()
            .zip(b.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Solve A·X = B via LU with partial pivoting (consumes a copy of A).
    /// Used by the Padé-13 expm rational solve. Panics on exactly singular
    /// pivots (cannot happen for the diagonally-dominant Padé denominators).
    pub fn solve(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.rows, self.cols, "solve: A must be square");
        assert_eq!(self.rows, b.rows, "solve: dimension mismatch");
        let n = self.rows;
        let mut lu = self.data.clone();
        let mut x = b.clone();
        let nb = b.cols;
        let mut piv: Vec<usize> = (0..n).collect();
        for col in 0..n {
            // pivot
            let mut best = col;
            let mut best_abs = lu[piv[col] * n + col].abs();
            for r in col + 1..n {
                let v = lu[piv[r] * n + col].abs();
                if v > best_abs {
                    best = r;
                    best_abs = v;
                }
            }
            piv.swap(col, best);
            let p = piv[col];
            let pivot = lu[p * n + col];
            assert!(pivot != 0.0, "solve: singular matrix at column {col}");
            for r in col + 1..n {
                let pr = piv[r];
                let factor = lu[pr * n + col] / pivot;
                lu[pr * n + col] = factor;
                for c in col + 1..n {
                    lu[pr * n + c] -= factor * lu[p * n + c];
                }
            }
        }
        // forward substitution (apply pivots to rows of B lazily via piv)
        let xin = x.data.clone();
        for (r, &pr) in piv.iter().enumerate() {
            x.data[r * nb..(r + 1) * nb].copy_from_slice(&xin[pr * nb..(pr + 1) * nb]);
        }
        for col in 0..n {
            for r in col + 1..n {
                let factor = lu[piv[r] * n + col];
                if factor == 0.0 {
                    continue;
                }
                let (top, bottom) = x.data.split_at_mut(r * nb);
                let src = &top[col * nb..(col + 1) * nb];
                let dst = &mut bottom[..nb];
                for (d, s) in dst.iter_mut().zip(src.iter()) {
                    *d -= factor * s;
                }
            }
        }
        // back substitution
        for col in (0..n).rev() {
            let pivot = lu[piv[col] * n + col];
            for c in 0..nb {
                x.data[col * nb + c] /= pivot;
            }
            for r in 0..col {
                let factor = lu[piv[r] * n + col];
                if factor == 0.0 {
                    continue;
                }
                let (top, bottom) = x.data.split_at_mut(col * nb);
                let src = &bottom[..nb];
                let dst = &mut top[r * nb..(r + 1) * nb];
                for (d, s) in dst.iter_mut().zip(src.iter()) {
                    *d -= factor * s;
                }
            }
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::seeded(3);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 9, 23), (64, 64, 64)] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let fast = a.matmul(&b);
            let slow = naive_matmul(&a, &b);
            assert!(fast.max_abs_diff(&slow) < 1e-10, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_matches_naive_across_k_blocks() {
        // n = 300 gives kc = max(16, 16384/300) = 54, so k = 130 crosses
        // several k-blocks; results must be bit-compatible with the naive
        // ascending-k accumulation (blocking preserves the order).
        let mut rng = Rng::seeded(8);
        let a = random(&mut rng, 20, 130);
        let b = random(&mut rng, 130, 300);
        assert!(a.matmul(&b).max_abs_diff(&naive_matmul(&a, &b)) < 1e-10);
    }

    #[test]
    fn matmul_sparse_variant_matches() {
        // > 1/8 zeros flips the skip-zero kernel on.
        let mut rng = Rng::seeded(9);
        let mut a = random(&mut rng, 33, 70);
        for (i, v) in a.data.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        let b = random(&mut rng, 70, 41);
        assert!(a.matmul(&b).max_abs_diff(&naive_matmul(&a, &b)) < 1e-10);
    }

    #[test]
    fn matmul_empty_dims() {
        let a = Matrix::zeros(0, 5);
        let b = Matrix::zeros(5, 4);
        let c = a.matmul(&b);
        assert_eq!((c.rows, c.cols), (0, 4));
        let a = Matrix::zeros(3, 0);
        let b = Matrix::zeros(0, 2);
        let c = a.matmul(&b);
        assert_eq!((c.rows, c.cols), (3, 2));
        assert!(c.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::seeded(4);
        let a = random(&mut rng, 12, 12);
        let i = Matrix::identity(12);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-12);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::seeded(5);
        let a = random(&mut rng, 7, 13);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn norms() {
        let m = Matrix::from_rows(vec![vec![1.0, -2.0], vec![3.0, 4.0]]);
        assert_eq!(m.norm_1(), 6.0); // max column abs-sum = |−2|+|4| = 6
        assert!((m.norm_fro() - (30.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn solve_recovers_solution() {
        let mut rng = Rng::seeded(6);
        let n = 20;
        // Diagonally dominant → well conditioned.
        let mut a = random(&mut rng, n, n);
        for i in 0..n {
            *a.at_mut(i, i) += n as f64;
        }
        let x_true = random(&mut rng, n, 3);
        let b = a.matmul(&x_true);
        let x = a.solve(&b);
        assert!(x.max_abs_diff(&x_true) < 1e-8);
    }

    #[test]
    fn solve_with_pivoting_handles_zero_diagonal() {
        // A = [[0,1],[1,0]] needs a row swap.
        let a = Matrix::from_rows(vec![vec![0.0, 1.0], vec![1.0, 0.0]]);
        let b = Matrix::from_rows(vec![vec![2.0], vec![3.0]]);
        let x = a.solve(&b);
        assert!((x.at(0, 0) - 3.0).abs() < 1e-12);
        assert!((x.at(1, 0) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn matmul_shape_check() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
