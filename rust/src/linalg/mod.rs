//! Dense linear-algebra substrate.
//!
//! The heat-kernel construction (`exp(−t·D^{−1/2} A D^{−1/2})`, Chung 1997)
//! needs a dense matrix type, a fast GEMM, matrix norms, an LU solver, and a
//! scaling-and-squaring matrix exponential. No linear-algebra crate is
//! available offline, so this module implements exactly that surface with
//! blocked, thread-parallel kernels.

mod matrix;
mod expm;

pub use expm::expm;
pub use matrix::Matrix;
