//! Matrix exponential via Padé-13 approximation with scaling and squaring
//! (Higham 2005, "The Scaling and Squaring Method for the Matrix Exponential
//! Revisited" — the same algorithm scipy.linalg.expm uses).
//!
//! This powers the heat-kernel construction `exp(−t·D^{−1/2} A D^{−1/2})`
//! from the paper's Appendix C. The normalized adjacency has spectrum in
//! [−1, 1], so the argument norm is ≤ t and small scaling exponents suffice.

use super::Matrix;

/// Padé-13 numerator coefficients (Higham 2005, Table 10.4).
const B13: [f64; 14] = [
    64764752532480000.0,
    32382376266240000.0,
    7771770303897600.0,
    1187353796428800.0,
    129060195264000.0,
    10559470521600.0,
    670442572800.0,
    33522128640.0,
    1323241920.0,
    40840800.0,
    960960.0,
    16380.0,
    182.0,
    1.0,
];

/// θ_13: the 1-norm threshold under which the Padé-13 approximant reaches
/// double-precision accuracy without scaling.
const THETA_13: f64 = 5.371920351148152;

/// Compute exp(A) for a square matrix.
///
/// Uses the [13/13] Padé approximant `r(A) = q(A)⁻¹ p(A)` on `A / 2^s`
/// followed by `s` repeated squarings, with `s = max(0, ⌈log2(‖A‖₁/θ₁₃)⌉)`.
pub fn expm(a: &Matrix) -> Matrix {
    assert_eq!(a.rows, a.cols, "expm: matrix must be square");
    let n = a.rows;
    if n == 0 {
        return Matrix::zeros(0, 0);
    }

    let norm = a.norm_1();
    let s = if norm > THETA_13 {
        (norm / THETA_13).log2().ceil().max(0.0) as u32
    } else {
        0
    };
    let a_scaled = a.scale(1.0 / f64::powi(2.0, s as i32));

    // Powers of the scaled matrix.
    let a2 = a_scaled.matmul(&a_scaled);
    let a4 = a2.matmul(&a2);
    let a6 = a4.matmul(&a2);

    // u = A·(A6·(b13·A6 + b11·A4 + b9·A2) + b7·A6 + b5·A4 + b3·A2 + b1·I)
    let mut w1 = a6.scale(B13[13]);
    w1.axpy(B13[11], &a4);
    w1.axpy(B13[9], &a2);
    let mut w2 = a6.scale(B13[7]);
    w2.axpy(B13[5], &a4);
    w2.axpy(B13[3], &a2);
    for i in 0..n {
        *w2.at_mut(i, i) += B13[1];
    }
    let u = a_scaled.matmul(&a6.matmul(&w1).add(&w2));

    // v = A6·(b12·A6 + b10·A4 + b8·A2) + b6·A6 + b4·A4 + b2·A2 + b0·I
    let mut z1 = a6.scale(B13[12]);
    z1.axpy(B13[10], &a4);
    z1.axpy(B13[8], &a2);
    let mut v = a6.matmul(&z1);
    v.axpy(B13[6], &a6);
    v.axpy(B13[4], &a4);
    v.axpy(B13[2], &a2);
    for i in 0..n {
        *v.at_mut(i, i) += B13[0];
    }

    // r = (v − u)⁻¹ (v + u)
    let denom = v.sub(&u);
    let numer = v.add(&u);
    let mut r = denom.solve(&numer);

    for _ in 0..s {
        r = r.matmul(&r);
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Reference: Taylor series with scaling-and-squaring at high term count.
    fn expm_taylor(a: &Matrix, terms: usize) -> Matrix {
        let norm = a.norm_1();
        let s = if norm > 0.5 { (norm / 0.5).log2().ceil() as u32 } else { 0 };
        let x = a.scale(1.0 / f64::powi(2.0, s as i32));
        let n = a.rows;
        let mut acc = Matrix::identity(n);
        let mut term = Matrix::identity(n);
        for t in 1..=terms {
            term = term.matmul(&x).scale(1.0 / t as f64);
            acc = acc.add(&term);
        }
        for _ in 0..s {
            acc = acc.matmul(&acc);
        }
        acc
    }

    #[test]
    fn expm_zero_is_identity() {
        let z = Matrix::zeros(5, 5);
        assert!(expm(&z).max_abs_diff(&Matrix::identity(5)) < 1e-14);
    }

    #[test]
    fn expm_diagonal() {
        let mut d = Matrix::zeros(3, 3);
        *d.at_mut(0, 0) = 1.0;
        *d.at_mut(1, 1) = -2.0;
        *d.at_mut(2, 2) = 0.5;
        let e = expm(&d);
        assert!((e.at(0, 0) - 1f64.exp()).abs() < 1e-12);
        assert!((e.at(1, 1) - (-2f64).exp()).abs() < 1e-12);
        assert!((e.at(2, 2) - 0.5f64.exp()).abs() < 1e-12);
        assert!(e.at(0, 1).abs() < 1e-14);
    }

    #[test]
    fn expm_nilpotent_exact() {
        // N = [[0,1],[0,0]] → exp(N) = I + N.
        let n = Matrix::from_rows(vec![vec![0.0, 1.0], vec![0.0, 0.0]]);
        let e = expm(&n);
        let want = Matrix::from_rows(vec![vec![1.0, 1.0], vec![0.0, 1.0]]);
        assert!(e.max_abs_diff(&want) < 1e-13);
    }

    #[test]
    fn expm_matches_taylor_on_random_symmetric() {
        let mut rng = Rng::seeded(31);
        for &n in &[4usize, 16, 40] {
            let mut a = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..=i {
                    let v = rng.normal() * 0.3;
                    *a.at_mut(i, j) = v;
                    *a.at_mut(j, i) = v;
                }
            }
            let fast = expm(&a);
            let slow = expm_taylor(&a, 40);
            assert!(
                fast.max_abs_diff(&slow) < 1e-9,
                "n={n} diff={}",
                fast.max_abs_diff(&slow)
            );
        }
    }

    #[test]
    fn expm_handles_large_norm_via_scaling() {
        let mut rng = Rng::seeded(37);
        let n = 10;
        let mut a = Matrix::zeros(n, n);
        for v in a.data.iter_mut() {
            *v = rng.normal() * 3.0; // ‖A‖₁ well above θ₁₃
        }
        let fast = expm(&a);
        let slow = expm_taylor(&a, 80);
        let rel = fast.max_abs_diff(&slow) / slow.norm_fro().max(1.0);
        assert!(rel < 1e-9, "rel={rel}");
    }

    #[test]
    fn expm_group_property() {
        // exp(A)·exp(−A) = I for any A.
        let mut rng = Rng::seeded(41);
        let n = 8;
        let mut a = Matrix::zeros(n, n);
        for v in a.data.iter_mut() {
            *v = rng.normal();
        }
        let prod = expm(&a).matmul(&expm(&a.scale(-1.0)));
        assert!(prod.max_abs_diff(&Matrix::identity(n)) < 1e-9);
    }
}
