//! Gram providers: uniform access to kernel values `K(i, j)` over a dataset,
//! either evaluated on the fly from features or read from a precomputed
//! matrix (required for the graph kernels, optional as a cache elsewhere).

use super::KernelFunction;
use crate::data::Dataset;
use crate::util::parallel::{par_chunks_mut, par_map_indexed};

/// Access to the (implicit) kernel matrix of a dataset.
pub enum Gram<'a> {
    /// Evaluate `K(x_i, x_j)` from features on demand.
    OnTheFly { ds: &'a Dataset, func: KernelFunction, diag: Vec<f64> },
    /// Dense precomputed matrix (row-major, f32 storage to halve memory;
    /// kernel values are O(1)-scaled so f32 is ample).
    Precomputed { name: String, n: usize, data: Vec<f32>, diag: Vec<f64> },
}

impl<'a> Gram<'a> {
    /// Wrap a dataset + kernel function.
    pub fn on_the_fly(ds: &'a Dataset, func: KernelFunction) -> Gram<'a> {
        let diag = if func.is_normalized() {
            vec![1.0; ds.n]
        } else {
            (0..ds.n).map(|i| func.eval_self(ds.row(i))).collect()
        };
        Gram::OnTheFly { ds, func, diag }
    }

    /// Wrap an explicit kernel matrix (row-major, length n²).
    pub fn precomputed(name: &str, n: usize, data: Vec<f32>) -> Gram<'static> {
        assert_eq!(data.len(), n * n, "kernel matrix must be n×n");
        let diag = (0..n).map(|i| data[i * n + i] as f64).collect();
        Gram::Precomputed { name: name.to_string(), n, data, diag }
    }

    /// Materialize an on-the-fly gram into a dense matrix (used by the
    /// full-batch baseline, which touches all n² entries every iteration).
    /// Computed in parallel over rows, exploiting symmetry.
    pub fn materialize(&self) -> Gram<'static> {
        let n = self.n();
        let mut data = vec![0.0f32; n * n];
        match self {
            Gram::Precomputed { name, data: src, .. } => {
                data.copy_from_slice(src);
                Gram::precomputed(name, n, data)
            }
            Gram::OnTheFly { ds, func, .. } => {
                par_chunks_mut(&mut data, |start, chunk| {
                    // chunks are element-aligned; recover (row, col) spans.
                    let mut idx = start;
                    for v in chunk.iter_mut() {
                        let (i, j) = (idx / n, idx % n);
                        *v = func.eval(ds.row(i), ds.row(j)) as f32;
                        idx += 1;
                    }
                });
                Gram::precomputed(&format!("{}:{}", ds.name, func.name()), n, data)
            }
        }
    }

    /// Number of points.
    pub fn n(&self) -> usize {
        match self {
            Gram::OnTheFly { ds, .. } => ds.n,
            Gram::Precomputed { n, .. } => *n,
        }
    }

    /// Kernel value `K(x_i, x_j)`.
    #[inline]
    pub fn eval(&self, i: usize, j: usize) -> f64 {
        match self {
            Gram::OnTheFly { ds, func, .. } => func.eval(ds.row(i), ds.row(j)),
            Gram::Precomputed { n, data, .. } => data[i * n + j] as f64,
        }
    }

    /// `K(x_i, x_i)` (cached).
    #[inline]
    pub fn self_k(&self, i: usize) -> f64 {
        match self {
            Gram::OnTheFly { diag, .. } | Gram::Precomputed { diag, .. } => diag[i],
        }
    }

    /// γ = max_i ‖φ(x_i)‖ = max_i √K(x_i,x_i) — the parameter of Theorem 1.
    pub fn gamma(&self) -> f64 {
        let diag = match self {
            Gram::OnTheFly { diag, .. } | Gram::Precomputed { diag, .. } => diag,
        };
        diag.iter().cloned().fold(0.0f64, f64::max).max(0.0).sqrt()
    }

    /// Dense block `K(rows, cols)` in row-major order (len = rows·cols),
    /// computed in parallel. This is the native-backend analogue of the L1
    /// Pallas gram kernel.
    pub fn block(&self, rows: &[usize], cols: &[usize]) -> Vec<f64> {
        let nc = cols.len();
        if rows.len() * nc == 0 {
            return Vec::new();
        }
        let out = par_map_indexed(rows.len(), |r| {
            let i = rows[r];
            let mut row = Vec::with_capacity(nc);
            match self {
                Gram::OnTheFly { ds, func, .. } => {
                    let xi = ds.row(i);
                    for &j in cols {
                        row.push(func.eval(xi, ds.row(j)));
                    }
                }
                Gram::Precomputed { n, data, .. } => {
                    let base = i * n;
                    for &j in cols {
                        row.push(data[base + j] as f64);
                    }
                }
            }
            row
        });
        out.into_iter().flatten().collect()
    }

    /// Fast path: the full i-th row of a *materialized* gram as an f32
    /// slice (`None` for on-the-fly grams). Hot loops hoist this outside
    /// their inner loop to skip per-element enum dispatch.
    #[inline]
    pub fn row_slice(&self, i: usize) -> Option<&[f32]> {
        match self {
            Gram::Precomputed { n, data, .. } => Some(&data[i * n..(i + 1) * n]),
            Gram::OnTheFly { .. } => None,
        }
    }

    /// Display name for reports.
    pub fn label(&self) -> String {
        match self {
            Gram::OnTheFly { ds, func, .. } => format!("{}:{}", ds.name, func.name()),
            Gram::Precomputed { name, .. } => name.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::util::rng::Rng;

    fn fixture() -> (Dataset, KernelFunction) {
        let mut rng = Rng::seeded(11);
        let ds = blobs(&SyntheticSpec::new(40, 3, 2), &mut rng);
        (ds, KernelFunction::Gaussian { kappa: 4.0 })
    }

    #[test]
    fn on_the_fly_matches_direct_eval() {
        let (ds, f) = fixture();
        let g = Gram::on_the_fly(&ds, f);
        assert_eq!(g.n(), 40);
        assert!((g.eval(3, 7) - f.eval(ds.row(3), ds.row(7))).abs() < 1e-15);
        assert_eq!(g.self_k(5), 1.0);
        assert!((g.gamma() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn materialize_agrees_with_on_the_fly() {
        let (ds, f) = fixture();
        let g = Gram::on_the_fly(&ds, f);
        let m = g.materialize();
        for i in (0..40).step_by(3) {
            for j in (0..40).step_by(5) {
                assert!((g.eval(i, j) - m.eval(i, j)).abs() < 1e-6, "({i},{j})");
            }
        }
        assert!((m.gamma() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn block_matches_pointwise() {
        let (ds, f) = fixture();
        let g = Gram::on_the_fly(&ds, f);
        let rows = [0, 5, 9];
        let cols = [1, 2, 3, 4];
        let blk = g.block(&rows, &cols);
        assert_eq!(blk.len(), 12);
        for (r, &i) in rows.iter().enumerate() {
            for (c, &j) in cols.iter().enumerate() {
                assert!((blk[r * 4 + c] - g.eval(i, j)).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn precomputed_gamma_from_diag() {
        let data = vec![4.0f32, 0.5, 0.5, 9.0];
        let g = Gram::precomputed("t", 2, data);
        assert_eq!(g.self_k(1), 9.0);
        assert!((g.gamma() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn gram_is_symmetric() {
        let (ds, f) = fixture();
        let g = Gram::on_the_fly(&ds, f);
        for i in 0..10 {
            for j in 0..10 {
                assert!((g.eval(i, j) - g.eval(j, i)).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn empty_block() {
        let (ds, f) = fixture();
        let g = Gram::on_the_fly(&ds, f);
        assert!(g.block(&[], &[1, 2]).is_empty());
    }
}
