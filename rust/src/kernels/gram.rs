//! Gram providers: uniform access to kernel values `K(i, j)` over a dataset,
//! either evaluated on the fly from features or read from a precomputed
//! matrix (required for the graph kernels, optional as a cache elsewhere).
//!
//! The block operations ([`Gram::materialize`], [`Gram::block`],
//! [`Gram::weighted_cross_into`]) run through the panel micro-kernel
//! engine ([`super::panel::KernelPanel`], DESIGN.md §7): kernel blocks are
//! computed as register-tiled inner-product panels against cached row
//! norms, walked in column tiles sized by [`super::tile::tile_cols`] so a
//! packed tile of feature columns stays L1/L2-resident across the whole
//! batch chunk, and materialization exploits symmetry by computing only
//! the tiles of the upper triangle and mirroring each value. This is the
//! native-backend analogue of the L1 Pallas gram kernel.

use super::panel::KernelPanel;
use super::tile;
use super::KernelFunction;
use crate::data::Dataset;
use crate::util::parallel::{par_dynamic, par_rows_mut, SharedSlice};
use crate::util::simd::NumericsMode;

/// Access to the (implicit) kernel matrix of a dataset.
pub enum Gram<'a> {
    /// Evaluate `K(x_i, x_j)` from features on demand.
    OnTheFly {
        /// The dataset whose rows feed the kernel function.
        ds: &'a Dataset,
        /// The closed-form kernel.
        func: KernelFunction,
        /// Cached diagonal `K(x_i, x_i)`.
        diag: Vec<f64>,
        /// Numerics mode for the block fills (DESIGN.md §13).
        /// [`Gram::eval`] stays the deterministic scalar reference.
        mode: NumericsMode,
    },
    /// Dense precomputed matrix (row-major, f32 storage to halve memory;
    /// kernel values are O(1)-scaled so f32 is ample).
    Precomputed {
        /// Display name for reports.
        name: String,
        /// Number of points.
        n: usize,
        /// Row-major n×n kernel values.
        data: Vec<f32>,
        /// Cached diagonal `K(x_i, x_i)`.
        diag: Vec<f64>,
    },
}

impl<'a> Gram<'a> {
    /// Wrap a dataset + kernel function in
    /// [`NumericsMode::Deterministic`].
    pub fn on_the_fly(ds: &'a Dataset, func: KernelFunction) -> Gram<'a> {
        Self::on_the_fly_with(ds, func, NumericsMode::Deterministic)
    }

    /// [`Gram::on_the_fly`] with an explicit numerics mode for the block
    /// engines ([`Gram::block_into`], [`Gram::weighted_cross_into`],
    /// [`Gram::materialize`], row gathers). The diagonal is always
    /// computed by the deterministic scalar chain.
    pub fn on_the_fly_with(
        ds: &'a Dataset,
        func: KernelFunction,
        mode: NumericsMode,
    ) -> Gram<'a> {
        let diag = if func.is_normalized() {
            vec![1.0; ds.n]
        } else {
            (0..ds.n).map(|i| func.eval_self(ds.row(i))).collect()
        };
        Gram::OnTheFly { ds, func, diag, mode }
    }

    /// The numerics mode of the block engines. Precomputed tables store
    /// frozen values, so reads are deterministic by construction.
    pub fn mode(&self) -> NumericsMode {
        match self {
            Gram::OnTheFly { mode, .. } => *mode,
            Gram::Precomputed { .. } => NumericsMode::Deterministic,
        }
    }

    /// Wrap an explicit kernel matrix (row-major, length n²).
    pub fn precomputed(name: &str, n: usize, data: Vec<f32>) -> Gram<'static> {
        assert_eq!(data.len(), n * n, "kernel matrix must be n×n");
        let diag = (0..n).map(|i| data[i * n + i] as f64).collect();
        Gram::Precomputed { name: name.to_string(), n, data, diag }
    }

    /// Materialize an on-the-fly gram into a dense matrix (used by the
    /// full-batch baseline, which touches all n² entries every iteration).
    ///
    /// Tiled and symmetric: the upper triangle is partitioned into square
    /// tiles, a dynamic worker pool computes each tile (diagonal tiles
    /// carry half the work of off-diagonal ones, so dynamic scheduling
    /// beats contiguous row chunks), and every value is mirrored into the
    /// lower triangle as it is produced — n(n+1)/2 kernel evaluations
    /// instead of n².
    pub fn materialize(&self) -> Gram<'static> {
        let tile_len = match self {
            // Square tiles: capped at 256 so one tile's panel staging
            // buffers stay well under a megabyte per worker while the tile
            // count still saturates the pool.
            Gram::OnTheFly { ds, .. } => tile::tile_cols(ds.d).min(256).min(ds.n.max(1)),
            Gram::Precomputed { .. } => 1, // ignored: materialize_tiled clones
        };
        self.materialize_tiled(tile_len)
    }

    /// [`Gram::materialize`] with an explicit tile edge length (exposed so
    /// tests can force tile boundaries on small inputs; `materialize` picks
    /// the L2-sized default).
    pub fn materialize_tiled(&self, tile_len: usize) -> Gram<'static> {
        let n = self.n();
        match self {
            Gram::Precomputed { name, data, .. } => {
                Gram::precomputed(name, n, data.clone())
            }
            Gram::OnTheFly { ds, func, mode, .. } => {
                let t = tile_len.clamp(1, n.max(1));
                let mut data = vec![0.0f32; n * n];
                let nblocks = n.div_ceil(t.max(1)).max(1);
                // Upper-triangle tile list: block (bi, bj) with bi ≤ bj owns
                // every unordered index pair {i, j} with i in bi's rows and
                // j in bj's columns.
                let mut tiles = Vec::with_capacity(nblocks * (nblocks + 1) / 2);
                for bi in 0..nblocks {
                    for bj in bi..nblocks {
                        tiles.push((bi * t, bj * t));
                    }
                }
                let panel = KernelPanel::new_with(ds, *func, *mode);
                {
                    let shared = SharedSlice::new(&mut data);
                    let shared = &shared;
                    let panel = &panel;
                    par_dynamic(tiles.len(), |ti| {
                        let (r0, c0) = tiles[ti];
                        let rows: Vec<usize> = (r0..(r0 + t).min(n)).collect();
                        let cols: Vec<usize> = (c0..(c0 + t).min(n)).collect();
                        let mut scratch = vec![0.0f64; rows.len() * cols.len()];
                        // The full rectangular tile through the panel engine;
                        // diagonal tiles redo their lower half, which is a
                        // 1/nblocks fraction of the work and cheaper than a
                        // triangular micro-kernel. Per-pair arithmetic is
                        // commutative at the bit level (see KernelPanel), so
                        // a diagonal tile's (i,j) and (j,i) agree exactly.
                        panel.fill_f64(&rows, &cols, &mut scratch);
                        for (r, &i) in rows.iter().enumerate() {
                            for (c, &j) in cols.iter().enumerate() {
                                if c0 == r0 && j < i {
                                    continue; // lower half of a diagonal tile
                                }
                                // Quantize at the storage boundary — the same
                                // `as f32` every other engine applies.
                                let v = scratch[r * cols.len() + c] as f32;
                                // SAFETY: each unordered pair {i, j} belongs
                                // to exactly one upper tile, so the writes to
                                // (i,j) and its mirror (j,i) are disjoint
                                // across tiles; within a tile they run on one
                                // thread.
                                unsafe {
                                    shared.write(i * n + j, v);
                                    shared.write(j * n + i, v);
                                }
                            }
                        }
                    });
                }
                Gram::precomputed(&format!("{}:{}", ds.name, func.name()), n, data)
            }
        }
    }

    /// Number of points.
    pub fn n(&self) -> usize {
        match self {
            Gram::OnTheFly { ds, .. } => ds.n,
            Gram::Precomputed { n, .. } => *n,
        }
    }

    /// Kernel value `K(x_i, x_j)`. On-the-fly evaluation goes through the
    /// panel arithmetic with the dataset's cached norms — bit-identical to
    /// what the block engines compute and the materialized table stores
    /// (before the table's f32 quantization).
    #[inline]
    pub fn eval(&self, i: usize, j: usize) -> f64 {
        match self {
            Gram::OnTheFly { ds, func, .. } => KernelPanel::new(ds, *func).eval_idx(i, j),
            Gram::Precomputed { n, data, .. } => data[i * n + j] as f64,
        }
    }

    /// Gather `out[m] = K(x_i, cols[m]) as f32` — the streaming tile
    /// cache's batched miss fill. On-the-fly grams run one panel row
    /// (values identical to `eval(i, ·) as f32`); materialized grams
    /// gather from the dense row.
    pub fn eval_cols_f32(&self, i: usize, cols: &[u32], out: &mut [f32]) {
        assert_eq!(cols.len(), out.len(), "eval_cols_f32: bad shape");
        match self {
            Gram::Precomputed { n, data, .. } => {
                let row = &data[i * n..(i + 1) * n];
                for (o, &j) in out.iter_mut().zip(cols.iter()) {
                    *o = row[j as usize];
                }
            }
            Gram::OnTheFly { ds, func, mode, .. } => {
                KernelPanel::new_with(ds, *func, *mode).fill_row_f32_u32(i, cols, out);
            }
        }
    }

    /// Gather `out[m] = K(x_i, cols[m])` as unquantized f64, in column
    /// order — values bitwise identical to per-element [`Gram::eval`].
    /// Materialized grams load from the dense row; on-the-fly grams run the
    /// row through the panel engine in 32-column chunks, which Algorithm
    /// 1's lazy replay uses to rebuild a stale point against its whole
    /// update log in one call instead of per-element enum dispatch.
    pub fn row_gather_cols(&self, i: usize, cols: &[u32], out: &mut [f64]) {
        assert_eq!(cols.len(), out.len(), "row_gather_cols: bad shape");
        match self {
            Gram::Precomputed { n, data, .. } => {
                let row = &data[i * n..(i + 1) * n];
                for (o, &j) in out.iter_mut().zip(cols.iter()) {
                    *o = row[j as usize] as f64;
                }
            }
            Gram::OnTheFly { ds, func, mode, .. } => {
                KernelPanel::new_with(ds, *func, *mode).fill_row_f64_u32(i, cols, out);
            }
        }
    }

    /// `K(x_i, x_i)` (cached).
    #[inline]
    pub fn self_k(&self, i: usize) -> f64 {
        match self {
            Gram::OnTheFly { diag, .. } | Gram::Precomputed { diag, .. } => diag[i],
        }
    }

    /// γ = max_i ‖φ(x_i)‖ = max_i √K(x_i,x_i) — the parameter of Theorem 1.
    pub fn gamma(&self) -> f64 {
        let diag = match self {
            Gram::OnTheFly { diag, .. } | Gram::Precomputed { diag, .. } => diag,
        };
        diag.iter().cloned().fold(0.0f64, f64::max).max(0.0).sqrt()
    }

    /// Dense block `K(rows, cols)` in row-major order (len = rows·cols),
    /// computed in parallel through the tiled engine.
    pub fn block(&self, rows: &[usize], cols: &[usize]) -> Vec<f64> {
        let mut out = vec![0.0f64; rows.len() * cols.len()];
        self.block_into(rows, cols, &mut out);
        out
    }

    /// Fill `out` (row-major, `rows.len() × cols.len()`) with the dense
    /// block `K(rows, cols)` without allocating — the hot-loop entry point.
    pub fn block_into(&self, rows: &[usize], cols: &[usize], out: &mut [f64]) {
        self.block_into_tiled(rows, cols, self.default_tile(), out);
    }

    /// [`Gram::block_into`] with an explicit column-tile width (exposed so
    /// tests can force tile boundaries on small inputs; values are
    /// independent of the tile width by the panel bit-identity contract).
    pub fn block_into_tiled(
        &self,
        rows: &[usize],
        cols: &[usize],
        tile_len: usize,
        out: &mut [f64],
    ) {
        let nc = cols.len();
        assert_eq!(out.len(), rows.len() * nc, "block_into: bad output shape");
        if out.is_empty() {
            return;
        }
        let t = tile_len.max(1);
        match self {
            Gram::Precomputed { n, data, .. } => {
                let n = *n;
                par_rows_mut(out, nc, |r0, chunk| {
                    for (r, orow) in chunk.chunks_mut(nc).enumerate() {
                        let base = rows[r0 + r] * n;
                        for (o, &j) in orow.iter_mut().zip(cols.iter()) {
                            *o = data[base + j] as f64;
                        }
                    }
                });
            }
            Gram::OnTheFly { ds, func, mode, .. } => {
                let panel = KernelPanel::new_with(ds, *func, *mode);
                let panel = &panel;
                par_rows_mut(out, nc, |r0, chunk| {
                    let nrows = chunk.len() / nc;
                    let mut c0 = 0;
                    // Column-tile outer loop: each packed tile of feature
                    // columns is reused across every batch row in this
                    // chunk while hot (the panel re-packs per NR-block,
                    // amortized over the chunk's rows).
                    for ctile in cols.chunks(t) {
                        panel.fill_f64_strided(
                            &rows[r0..r0 + nrows],
                            ctile,
                            nc,
                            &mut chunk[c0..],
                        );
                        c0 += ctile.len();
                    }
                });
            }
        }
    }

    /// Fused weighted cross-term engine for the assignment step.
    ///
    /// Given the concatenated support of `k` centers — dataset indices
    /// `sup_idx` with coefficients `sup_w`, center `j` owning the slice
    /// `ranges[j] = (start, end)` — fills
    /// `out[r·k + j] = Σ_{m ∈ ranges[j]} w_m · K(batch[r], sup_idx[m])`.
    ///
    /// This is the `K(B, S)·w` contraction of Algorithm 2's distance
    /// formula, computed without materializing the `b × |S|` block: kernel
    /// values are consumed the moment they are produced, tiled over support
    /// columns so each tile's features stay cache-resident across the whole
    /// batch chunk.
    pub fn weighted_cross_into(
        &self,
        batch: &[usize],
        sup_idx: &[u32],
        sup_w: &[f64],
        ranges: &[(usize, usize)],
        out: &mut [f64],
    ) {
        let k = ranges.len();
        assert_eq!(sup_idx.len(), sup_w.len(), "support index/weight mismatch");
        assert_eq!(out.len(), batch.len() * k, "weighted_cross_into: bad shape");
        if out.is_empty() {
            return;
        }
        match self {
            Gram::Precomputed { n, data, .. } => {
                let n = *n;
                par_rows_mut(out, k, |r0, chunk| {
                    for (r, orow) in chunk.chunks_mut(k).enumerate() {
                        // Materialized fast path: one contiguous gram row per
                        // batch point, gathered per support entry.
                        let g = &data[batch[r0 + r] * n..(batch[r0 + r] + 1) * n];
                        for (o, &(s, e)) in orow.iter_mut().zip(ranges.iter()) {
                            let mut acc = 0.0;
                            for (&y, &w) in sup_idx[s..e].iter().zip(&sup_w[s..e]) {
                                acc += w * g[y as usize] as f64;
                            }
                            *o = acc;
                        }
                    }
                });
            }
            Gram::OnTheFly { ds, func, mode, .. } => {
                let t = tile::tile_cols(ds.d);
                let panel = KernelPanel::new_with(ds, *func, *mode);
                let panel = &panel;
                par_rows_mut(out, k, |r0, chunk| {
                    for v in chunk.iter_mut() {
                        *v = 0.0;
                    }
                    let nrows = chunk.len() / k;
                    let brows = &batch[r0..r0 + nrows];
                    // Reusable per-chunk buffers: the support tile's column
                    // indices (usize view of sup_idx) and the K(B, tile)
                    // staging the contraction consumes — zeroed once here;
                    // fill_f64 fully overwrites the slice it is given, so
                    // the tile loop never re-initializes.
                    let mut tcols: Vec<usize> = Vec::with_capacity(t);
                    let mut kvals: Vec<f64> = vec![0.0; nrows * t];
                    for (j, &(s, e)) in ranges.iter().enumerate() {
                        let mut m0 = s;
                        while m0 < e {
                            let m1 = (m0 + t).min(e);
                            tcols.clear();
                            tcols.extend(sup_idx[m0..m1].iter().map(|&y| y as usize));
                            let kv = &mut kvals[..nrows * tcols.len()];
                            // Panel-fill K(batch rows, support tile), then
                            // contract with the weights in support order —
                            // the same per-(r, j) accumulation order as the
                            // scalar engine and the naive oracle.
                            panel.fill_f64(brows, &tcols, kv);
                            for (r, krow) in kv.chunks(tcols.len()).enumerate() {
                                let mut acc = 0.0;
                                for (&kval, &w) in krow.iter().zip(&sup_w[m0..m1]) {
                                    acc += w * kval;
                                }
                                chunk[r * k + j] += acc;
                            }
                            m0 = m1;
                        }
                    }
                });
            }
        }
    }

    /// Fast path: the full i-th row of a *materialized* gram as an f32
    /// slice (`None` for on-the-fly grams). Hot loops hoist this outside
    /// their inner loop to skip per-element enum dispatch.
    #[inline]
    pub fn row_slice(&self, i: usize) -> Option<&[f32]> {
        match self {
            Gram::Precomputed { n, data, .. } => Some(&data[i * n..(i + 1) * n]),
            Gram::OnTheFly { .. } => None,
        }
    }

    /// Display name for reports.
    pub fn label(&self) -> String {
        match self {
            Gram::OnTheFly { ds, func, .. } => format!("{}:{}", ds.name, func.name()),
            Gram::Precomputed { name, .. } => name.clone(),
        }
    }

    /// The underlying (dataset, closed-form kernel) pair for feature
    /// kernels; `None` for precomputed tables. The XLA backend uses this to
    /// marshal raw features into the AOT graph without matching on the
    /// concrete provider type.
    pub fn feature_kernel(&self) -> Option<(&Dataset, KernelFunction)> {
        match self {
            Gram::OnTheFly { ds, func, .. } => Some((ds, *func)),
            Gram::Precomputed { .. } => None,
        }
    }

    /// Default column-tile width for this provider.
    fn default_tile(&self) -> usize {
        match self {
            Gram::OnTheFly { ds, .. } => tile::tile_cols(ds.d),
            Gram::Precomputed { .. } => tile::MAX_TILE_COLS,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::util::rng::Rng;

    fn fixture() -> (Dataset, KernelFunction) {
        let mut rng = Rng::seeded(11);
        let ds = blobs(&SyntheticSpec::new(40, 3, 2), &mut rng);
        (ds, KernelFunction::Gaussian { kappa: 4.0 })
    }

    #[test]
    fn on_the_fly_matches_direct_eval() {
        let (ds, f) = fixture();
        let g = Gram::on_the_fly(&ds, f);
        assert_eq!(g.n(), 40);
        assert!((g.eval(3, 7) - f.eval(ds.row(3), ds.row(7))).abs() < 1e-15);
        assert_eq!(g.self_k(5), 1.0);
        assert!((g.gamma() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn materialize_agrees_with_on_the_fly() {
        let (ds, f) = fixture();
        let g = Gram::on_the_fly(&ds, f);
        let m = g.materialize();
        for i in (0..40).step_by(3) {
            for j in (0..40).step_by(5) {
                assert!((g.eval(i, j) - m.eval(i, j)).abs() < 1e-6, "({i},{j})");
            }
        }
        assert!((m.gamma() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn materialize_tiled_any_tile_size() {
        // Tile edges of 1, a non-divisor, and > n must all produce the same
        // full matrix as direct evaluation.
        let (ds, f) = fixture();
        let g = Gram::on_the_fly(&ds, f);
        for t in [1usize, 7, 40, 64] {
            let m = g.materialize_tiled(t);
            for i in 0..ds.n {
                for j in 0..ds.n {
                    assert!(
                        (g.eval(i, j) - m.eval(i, j)).abs() < 1e-6,
                        "tile={t} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn materialized_is_exactly_symmetric() {
        // Mirroring writes the identical f32, so symmetry is bit-exact.
        let (ds, f) = fixture();
        let m = Gram::on_the_fly(&ds, f).materialize_tiled(7);
        for i in 0..ds.n {
            for j in 0..ds.n {
                assert_eq!(m.eval(i, j), m.eval(j, i), "({i},{j})");
            }
        }
    }

    #[test]
    fn block_matches_pointwise() {
        let (ds, f) = fixture();
        let g = Gram::on_the_fly(&ds, f);
        let rows = [0, 5, 9];
        let cols = [1, 2, 3, 4];
        let blk = g.block(&rows, &cols);
        assert_eq!(blk.len(), 12);
        for (r, &i) in rows.iter().enumerate() {
            for (c, &j) in cols.iter().enumerate() {
                assert!((blk[r * 4 + c] - g.eval(i, j)).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn block_tiled_matches_naive_across_tile_edges() {
        let (ds, f) = fixture();
        let g = Gram::on_the_fly(&ds, f);
        let mat = g.materialize();
        let rows: Vec<usize> = (0..ds.n).step_by(2).collect();
        let cols: Vec<usize> = (0..ds.n).rev().collect(); // unsorted, full width
        for grm in [&g, &mat] {
            for t in [1usize, 3, 5, 100] {
                let mut out = vec![0.0f64; rows.len() * cols.len()];
                grm.block_into_tiled(&rows, &cols, t, &mut out);
                for (r, &i) in rows.iter().enumerate() {
                    for (c, &j) in cols.iter().enumerate() {
                        assert!(
                            (out[r * cols.len() + c] - g.eval(i, j)).abs() < 1e-6,
                            "tile={t} ({i},{j})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn weighted_cross_matches_naive() {
        let (ds, f) = fixture();
        let g = Gram::on_the_fly(&ds, f);
        let mat = g.materialize();
        let mut rng = Rng::seeded(5);
        let batch: Vec<usize> = (0..17).map(|_| rng.below(ds.n)).collect();
        // Three centers with supports of different sizes (one empty).
        let sup_idx: Vec<u32> = (0..30).map(|_| rng.below(ds.n) as u32).collect();
        let sup_w: Vec<f64> = (0..30).map(|_| rng.f64()).collect();
        let ranges = [(0usize, 12usize), (12, 12), (12, 30)];
        for grm in [&g, &mat] {
            let mut out = vec![f64::NAN; batch.len() * ranges.len()];
            grm.weighted_cross_into(&batch, &sup_idx, &sup_w, &ranges, &mut out);
            for (r, &x) in batch.iter().enumerate() {
                for (j, &(s, e)) in ranges.iter().enumerate() {
                    let want: f64 = (s..e)
                        .map(|m| sup_w[m] * g.eval(x, sup_idx[m] as usize))
                        .sum();
                    let got = out[r * ranges.len() + j];
                    // 1e-5: the materialized path reads f32-stored values.
                    assert!((got - want).abs() < 1e-5, "r={r} j={j}: {got} vs {want}");
                }
            }
        }
    }

    #[test]
    fn precomputed_gamma_from_diag() {
        let data = vec![4.0f32, 0.5, 0.5, 9.0];
        let g = Gram::precomputed("t", 2, data);
        assert_eq!(g.self_k(1), 9.0);
        assert!((g.gamma() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn gram_is_symmetric() {
        let (ds, f) = fixture();
        let g = Gram::on_the_fly(&ds, f);
        for i in 0..10 {
            for j in 0..10 {
                assert!((g.eval(i, j) - g.eval(j, i)).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn empty_block() {
        let (ds, f) = fixture();
        let g = Gram::on_the_fly(&ds, f);
        assert!(g.block(&[], &[1, 2]).is_empty());
        assert!(g.block(&[1, 2], &[]).is_empty());
    }

    #[test]
    fn fast_mode_blocks_stay_within_ulp_contract() {
        use crate::util::simd::{ulp_distance, EXP_ULP_BUDGET};
        let (ds, f) = fixture();
        let det = Gram::on_the_fly(&ds, f);
        let fast = Gram::on_the_fly_with(&ds, f, NumericsMode::Fast);
        assert_eq!(det.mode(), NumericsMode::Deterministic);
        assert_eq!(fast.mode(), NumericsMode::Fast);
        let rows: Vec<usize> = (0..ds.n).step_by(3).collect();
        let cols: Vec<usize> = (0..ds.n).rev().collect();
        let (a, b) = (det.block(&rows, &cols), fast.block(&rows, &cols));
        for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
            // Gaussian: dots/args bitwise across arms, exp within budget.
            let ud = ulp_distance(x, y).unwrap();
            assert!(ud <= EXP_ULP_BUDGET, "i={i}: {x} vs {y} ({ud} ulp)");
        }
        // Linear kernel: no exp in the chain → Fast is bitwise identical
        // on every dispatch arm.
        let lin_det = Gram::on_the_fly(&ds, KernelFunction::Linear);
        let lin_fast = Gram::on_the_fly_with(&ds, KernelFunction::Linear, NumericsMode::Fast);
        let (a, b) = (lin_det.block(&rows, &cols), lin_fast.block(&rows, &cols));
        for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "linear i={i}");
        }
        // eval stays the scalar reference regardless of mode.
        assert_eq!(det.eval(3, 7).to_bits(), fast.eval(3, 7).to_bits());
    }
}
