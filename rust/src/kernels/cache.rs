//! The streaming kernel provider: a bounded, sharded tile-LRU cache over an
//! on-demand kernel (DESIGN.md §6).
//!
//! [`CachedGram`] wraps a base [`Gram`] and serves every access through
//! [`TileCache`], which memoizes kernel values in fixed-width **tiles**: the
//! slots `K(i, t·W .. (t+1)·W)` of one row, with `W =` [`CACHE_TILE_COLS`].
//! Tiles are filled *lazily* (only requested slots are computed; empty
//! slots carry a NaN sentinel), so scattered support lookups never pay for
//! unrequested columns, while dense sweeps amortize one map entry over up
//! to `W` values.
//!
//! Eviction is a sharded **two-generation LRU approximation**: each shard
//! keeps a `hot` and a `cold` hash map, each bounded to half the shard's
//! tile budget. Fresh tiles enter `cold`; a tile touched a second time is
//! promoted to `hot`. When `cold` fills it is dropped wholesale; when `hot`
//! fills it is demoted to `cold` (displacing the previous `cold`). One-touch
//! scan traffic — e.g. Algorithm 1's full-dataset sweep — therefore churns
//! only `cold` and can never wash the recurring `K(B, S)` tiles out of
//! `hot`, which is exactly the reuse pattern the mini-batch algorithms
//! exhibit (support sets overlap heavily between consecutive batches).
//!
//! **Numerical contract.** `CachedGram` quantizes every kernel value to f32
//! — the same rounding [`Gram::materialize`] applies when it stores the
//! dense table — and performs its block reductions in the same order as
//! the materialized fast path. Miss batches are filled through the same
//! panel engine (`Gram::eval_cols_f32` → [`super::panel::KernelPanel`])
//! that fills the dense table, so a cache hit returns bit-for-bit the
//! value a miss would compute, results never depend on cache state,
//! budget, or eviction history, and streaming runs are *bit-identical* to
//! materialized runs (pinned by `tests/prop_stream_equivalence.rs`).

use super::provider::{GatherPlan, KernelProvider};
use super::{Gram, KernelFunction};
use crate::data::Dataset;
use crate::util::parallel::par_rows_mut;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Columns per cached tile. Small enough that scattered support lookups
/// waste little memory on unfilled slots (slots are lazily computed
/// anyway), large enough that dense row sweeps amortize the map overhead.
pub const CACHE_TILE_COLS: usize = 32;

/// Number of independently locked shards; keys hash-distribute across them
/// so the parallel assignment sweep rarely contends on one mutex.
const NSHARDS: usize = 64;

/// Estimated per-tile bookkeeping bytes (hash-map entry + box header),
/// added to the payload when converting a byte budget into a tile budget.
const TILE_OVERHEAD_BYTES: usize = 48;

/// One lazily-filled tile: `CACHE_TILE_COLS` f32 slots, NaN = not computed.
type Tile = Box<[f32]>;

/// Counters describing a [`TileCache`]'s behaviour so far.
#[derive(Clone, Copy, Debug)]
pub struct CacheStats {
    /// Values served from a cached slot.
    pub hits: u64,
    /// Values computed (and then cached).
    pub misses: u64,
    /// Tiles dropped by generation eviction.
    pub evictions: u64,
    /// Tiles currently resident across all shards.
    pub resident_tiles: usize,
    /// Hard ceiling on resident tiles (2 generations × shard budget).
    pub max_tiles: usize,
}

impl CacheStats {
    /// Fraction of lookups served from cache (0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// One-line human summary for CLI/bench output.
    pub fn summary(&self) -> String {
        format!(
            "{} hits / {} misses ({:.1}% hit rate), {} / {} tiles resident, {} evicted",
            self.hits,
            self.misses,
            100.0 * self.hit_rate(),
            self.resident_tiles,
            self.max_tiles,
            self.evictions
        )
    }
}

struct Shard {
    hot: HashMap<u64, Tile>,
    cold: HashMap<u64, Tile>,
}

impl Shard {
    /// Find `key`, promoting a `cold` hit into `hot` (second-touch
    /// admission). Returns the tile and the number of tiles evicted by any
    /// generation rotation the promotion triggered.
    fn lookup(&mut self, key: u64, cap: usize) -> (Option<&mut Tile>, usize) {
        if self.hot.contains_key(&key) {
            return (self.hot.get_mut(&key), 0);
        }
        if let Some(tile) = self.cold.remove(&key) {
            let mut evicted = 0;
            if self.hot.len() >= cap {
                // Hot generation full: demote it wholesale; the previous
                // cold generation (minus the tile being promoted) is gone.
                evicted = self.cold.len();
                self.cold = std::mem::take(&mut self.hot);
            }
            self.hot.insert(key, tile);
            return (self.hot.get_mut(&key), evicted);
        }
        (None, 0)
    }

    /// Find `key` without promoting (used by the write-back phase so that a
    /// freshly inserted tile still needs a genuine second touch to reach
    /// `hot`).
    fn peek_mut(&mut self, key: u64) -> Option<&mut Tile> {
        if let Some(t) = self.hot.get_mut(&key) {
            return Some(t);
        }
        self.cold.get_mut(&key)
    }

    /// Insert a fresh all-NaN tile into `cold`, clearing the generation
    /// first if it is full. Returns the tile and the evicted count.
    fn insert_fresh(&mut self, key: u64, cap: usize) -> (&mut Tile, usize) {
        let mut evicted = 0;
        if self.cold.len() >= cap {
            evicted = self.cold.len();
            self.cold.clear();
        }
        let tile: Tile = vec![f32::NAN; CACHE_TILE_COLS].into_boxed_slice();
        self.cold.insert(key, tile);
        (self.cold.get_mut(&key).expect("just inserted"), evicted)
    }
}

/// Sharded, budget-bounded tile cache (see the module docs for the design).
pub struct TileCache {
    shards: Vec<Mutex<Shard>>,
    /// Per-shard, per-generation tile budget.
    cap_per_generation: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl TileCache {
    /// Cache bounded to roughly `budget_bytes` of tile payload + overhead.
    /// The budget is clamped so every shard can hold at least one tile per
    /// generation (a zero budget still yields a tiny working cache).
    pub fn new(budget_bytes: usize) -> TileCache {
        let tile_bytes = CACHE_TILE_COLS * 4 + TILE_OVERHEAD_BYTES;
        let budget_tiles = budget_bytes / tile_bytes;
        let cap_per_generation = (budget_tiles / (2 * NSHARDS)).max(1);
        TileCache {
            shards: (0..NSHARDS)
                .map(|_| Mutex::new(Shard { hot: HashMap::new(), cold: HashMap::new() }))
                .collect(),
            cap_per_generation,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard_of(key: u64) -> usize {
        // Fibonacci multiply-shift: the top bits mix row and tile index.
        (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 58) as usize % NSHARDS
    }

    fn key_of(row: usize, ct: usize) -> u64 {
        debug_assert!(row < (1usize << 37) && ct < (1usize << 27));
        ((row as u64) << 27) | ct as u64
    }

    /// Fetch `K(row, cols[g])` into `vals[g]` for a group of columns that
    /// all live in column-tile `ct` (`cols.len() ≤ CACHE_TILE_COLS` after
    /// deduplication). Slots not yet cached are computed by **one** call
    /// to `eval(missing_cols, out)` — a batched fill the panel engine
    /// serves as a single micro-kernel row — and written back. `eval` runs
    /// outside the shard lock.
    pub fn fetch_group(
        &self,
        row: usize,
        ct: usize,
        cols: &[u32],
        vals: &mut [f32],
        eval: &mut dyn FnMut(&[u32], &mut [f32]),
    ) {
        assert_eq!(cols.len(), vals.len());
        // Hard bound (not debug-only): the miss bookkeeping below is a u64
        // bitmask, so group width must stay ≤ CACHE_TILE_COLS (< 64).
        assert!(cols.len() <= CACHE_TILE_COLS, "dedup groups before fetching");
        debug_assert!(cols.iter().all(|&c| c as usize / CACHE_TILE_COLS == ct));
        if cols.is_empty() {
            return;
        }
        let key = Self::key_of(row, ct);
        let si = Self::shard_of(key);
        let mut missing: u64 = 0;
        {
            let mut shard = self.shards[si].lock().expect("cache shard poisoned");
            let (tile, evicted) = shard.lookup(key, self.cap_per_generation);
            match tile {
                Some(tile) => {
                    for (g, &c) in cols.iter().enumerate() {
                        let v = tile[c as usize % CACHE_TILE_COLS];
                        if v.is_nan() {
                            missing |= 1 << g;
                        } else {
                            vals[g] = v;
                        }
                    }
                }
                None => missing = (1u64 << cols.len()) - 1,
            }
            if evicted > 0 {
                self.evictions.fetch_add(evicted as u64, Ordering::Relaxed);
            }
        }
        let nmiss = missing.count_ones() as u64;
        self.hits.fetch_add(cols.len() as u64 - nmiss, Ordering::Relaxed);
        if nmiss == 0 {
            return;
        }
        self.misses.fetch_add(nmiss, Ordering::Relaxed);
        // Batch the missing columns into one eval call (stack buffers: a
        // group is at most one tile wide).
        let mut miss_cols = [0u32; CACHE_TILE_COLS];
        let mut miss_vals = [0.0f32; CACHE_TILE_COLS];
        let mut nm = 0;
        for (g, &c) in cols.iter().enumerate() {
            if missing & (1 << g) != 0 {
                miss_cols[nm] = c;
                nm += 1;
            }
        }
        eval(&miss_cols[..nm], &mut miss_vals[..nm]);
        let mut mi = 0;
        for (g, v) in vals.iter_mut().enumerate() {
            if missing & (1 << g) != 0 {
                *v = miss_vals[mi];
                mi += 1;
            }
        }
        let mut shard = self.shards[si].lock().expect("cache shard poisoned");
        // Get-or-insert in two steps (the single-`match` form trips NLL).
        let mut evicted = 0;
        if shard.peek_mut(key).is_none() {
            let (_, ev) = shard.insert_fresh(key, self.cap_per_generation);
            evicted = ev;
        }
        let tile = shard.peek_mut(key).expect("tile present after insert");
        for (g, &c) in cols.iter().enumerate() {
            if missing & (1 << g) != 0 {
                tile[c as usize % CACHE_TILE_COLS] = vals[g];
            }
        }
        drop(shard);
        if evicted > 0 {
            self.evictions.fetch_add(evicted as u64, Ordering::Relaxed);
        }
    }

    /// Snapshot of the cache counters and residency.
    pub fn stats(&self) -> CacheStats {
        let mut resident = 0;
        for shard in &self.shards {
            let s = shard.lock().expect("cache shard poisoned");
            resident += s.hot.len() + s.cold.len();
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            resident_tiles: resident,
            max_tiles: 2 * self.cap_per_generation * NSHARDS,
        }
    }
}

/// The streaming kernel provider: a base [`Gram`] behind a [`TileCache`],
/// with every value quantized to f32 (see the module docs for the
/// numerical contract).
pub struct CachedGram<'a> {
    base: Gram<'a>,
    cache: TileCache,
    /// f32-quantized diagonal (identical to what a materialized table's
    /// diagonal would hold).
    diag: Vec<f64>,
}

impl<'a> CachedGram<'a> {
    /// Wrap `base` with a tile cache bounded to `cache_budget_bytes`.
    pub fn new(base: Gram<'a>, cache_budget_bytes: usize) -> CachedGram<'a> {
        let n = base.n();
        let diag: Vec<f64> = (0..n).map(|i| (base.self_k(i) as f32) as f64).collect();
        CachedGram { base, cache: TileCache::new(cache_budget_bytes), diag }
    }

    /// Cache behaviour counters (hit rate, residency, evictions).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Quantized kernel value through the cache.
    fn value(&self, i: usize, j: usize) -> f64 {
        let mut v = [0.0f32];
        self.cache.fetch_group(
            i,
            j / CACHE_TILE_COLS,
            &[j as u32],
            &mut v,
            &mut |cols, out| self.base.eval_cols_f32(i, cols, out),
        );
        v[0] as f64
    }

    /// Tile-group a column multiset: returns `(ct, col, pos)` sorted by
    /// `(ct, col)`, where `pos` indexes the original `cols` order. Shared
    /// by every batch row of a block operation, so it is built once per
    /// call.
    fn group_cols(cols: impl Iterator<Item = u32>) -> Vec<(u32, u32, u32)> {
        let mut groups: Vec<(u32, u32, u32)> = cols
            .enumerate()
            .map(|(pos, c)| ((c as usize / CACHE_TILE_COLS) as u32, c, pos as u32))
            .collect();
        groups.sort_unstable();
        groups
    }

    /// Fetch `K(x, col)` for every grouped position into `dst[pos]`.
    /// `gcols`/`gvals` are reusable scratch buffers (≤ one tile wide).
    fn fetch_row_grouped(
        &self,
        x: usize,
        groups: &[(u32, u32, u32)],
        dst: &mut [f32],
        gcols: &mut Vec<u32>,
        gvals: &mut Vec<f32>,
    ) {
        let mut i0 = 0;
        while i0 < groups.len() {
            let ct = groups[i0].0;
            let mut i1 = i0;
            gcols.clear();
            while i1 < groups.len() && groups[i1].0 == ct {
                let c = groups[i1].1;
                if gcols.last() != Some(&c) {
                    gcols.push(c);
                }
                i1 += 1;
            }
            gvals.clear();
            gvals.resize(gcols.len(), 0.0);
            self.cache.fetch_group(x, ct as usize, gcols, gvals, &mut |cols, out| {
                self.base.eval_cols_f32(x, cols, out)
            });
            // Scatter back: entries with duplicate columns are consecutive
            // (sorted by (ct, col)), so one pointer walks the dedup list.
            let mut di = 0;
            for g in &groups[i0..i1] {
                if g.1 != gcols[di] {
                    di += 1;
                }
                dst[g.2 as usize] = gvals[di];
            }
            i0 = i1;
        }
    }
}

impl KernelProvider for CachedGram<'_> {
    fn n(&self) -> usize {
        self.base.n()
    }

    fn eval(&self, i: usize, j: usize) -> f64 {
        self.value(i, j)
    }

    fn self_k(&self, i: usize) -> f64 {
        self.diag[i]
    }

    fn label(&self) -> String {
        format!("{}+tile-lru", self.base.label())
    }

    fn gamma(&self) -> f64 {
        self.diag.iter().cloned().fold(0.0f64, f64::max).max(0.0).sqrt()
    }

    fn feature_kernel(&self) -> Option<(&Dataset, KernelFunction)> {
        // Exposes the *unquantized* base kernel: an AssignBackend that
        // routes this to the AOT graph computes from raw features, which
        // agrees with the native quantized path only statistically (same
        // tolerance as the existing OnTheFly-vs-XLA contract) — the f32
        // bit-identity guarantee applies to the native paths only.
        self.base.feature_kernel()
    }

    fn plan_gather(&self, cols: &[u32]) -> GatherPlan {
        GatherPlan {
            cols: cols.to_vec(),
            groups: Some(Self::group_cols(cols.iter().copied())),
        }
    }

    fn plan_gather_extend(&self, plan: &mut GatherPlan, new_cols: &[u32]) {
        // Incremental merge: group the appendix on its own, offset its
        // positions past the existing columns, and merge the two
        // (tile, col, pos)-sorted runs — O(plan + new) instead of the
        // O(len·log len) full re-sort, with a result identical to
        // rebuilding from scratch (all new positions sort after all old
        // ones at equal (tile, col)). Algorithm 1's lazy state leans on
        // this: its full-history plan grows by one batch per iteration.
        let offset = plan.cols.len() as u32;
        plan.cols.extend_from_slice(new_cols);
        let mut add = Self::group_cols(new_cols.iter().copied());
        for g in add.iter_mut() {
            g.2 += offset;
        }
        match plan.groups.as_mut() {
            None => plan.groups = Some(Self::group_cols(plan.cols.iter().copied())),
            Some(old) => {
                let mut merged = Vec::with_capacity(old.len() + add.len());
                let (mut i, mut j) = (0, 0);
                while i < old.len() && j < add.len() {
                    if old[i] <= add[j] {
                        merged.push(old[i]);
                        i += 1;
                    } else {
                        merged.push(add[j]);
                        j += 1;
                    }
                }
                merged.extend_from_slice(&old[i..]);
                merged.extend_from_slice(&add[j..]);
                *old = merged;
            }
        }
    }

    fn row_gather_planned(&self, x: usize, plan: &GatherPlan, out: &mut [f64]) {
        assert_eq!(plan.cols.len(), out.len(), "row_gather_planned: bad shape");
        let Some(groups) = plan.groups.as_ref() else {
            // Plan built by a different provider: plain per-element path.
            for (o, &j) in out.iter_mut().zip(plan.cols.iter()) {
                *o = self.value(x, j as usize);
            }
            return;
        };
        // Allocation-free per-row walk: the grouping/sort was hoisted into
        // the plan, and the ≤ 32-wide dedup buffers live on the stack.
        let mut gcols = [0u32; CACHE_TILE_COLS];
        let mut gvals = [0.0f32; CACHE_TILE_COLS];
        let mut i0 = 0;
        while i0 < groups.len() {
            let ct = groups[i0].0;
            let mut i1 = i0;
            let mut glen = 0;
            while i1 < groups.len() && groups[i1].0 == ct {
                let c = groups[i1].1;
                if glen == 0 || gcols[glen - 1] != c {
                    gcols[glen] = c;
                    glen += 1;
                }
                i1 += 1;
            }
            self.cache.fetch_group(
                x,
                ct as usize,
                &gcols[..glen],
                &mut gvals[..glen],
                &mut |cols, out| self.base.eval_cols_f32(x, cols, out),
            );
            let mut di = 0;
            for g in &groups[i0..i1] {
                if g.1 != gcols[di] {
                    di += 1;
                }
                out[g.2 as usize] = gvals[di] as f64;
            }
            i0 = i1;
        }
    }

    fn block_into(&self, rows: &[usize], cols: &[usize], out: &mut [f64]) {
        let nc = cols.len();
        assert_eq!(out.len(), rows.len() * nc, "block_into: bad output shape");
        if out.is_empty() {
            return;
        }
        let groups = Self::group_cols(cols.iter().map(|&c| c as u32));
        par_rows_mut(out, nc, |r0, chunk| {
            let mut scratch = vec![0.0f32; nc];
            let mut gcols = Vec::with_capacity(CACHE_TILE_COLS);
            let mut gvals = Vec::with_capacity(CACHE_TILE_COLS);
            for (r, orow) in chunk.chunks_mut(nc).enumerate() {
                let x = rows[r0 + r];
                self.fetch_row_grouped(x, &groups, &mut scratch, &mut gcols, &mut gvals);
                for (o, &v) in orow.iter_mut().zip(scratch.iter()) {
                    *o = v as f64;
                }
            }
        });
    }

    fn weighted_cross_into(
        &self,
        batch: &[usize],
        sup_idx: &[u32],
        sup_w: &[f64],
        ranges: &[(usize, usize)],
        out: &mut [f64],
    ) {
        let k = ranges.len();
        assert_eq!(sup_idx.len(), sup_w.len(), "support index/weight mismatch");
        assert_eq!(out.len(), batch.len() * k, "weighted_cross_into: bad shape");
        if out.is_empty() {
            return;
        }
        let groups = Self::group_cols(sup_idx.iter().copied());
        par_rows_mut(out, k, |r0, chunk| {
            let mut scratch = vec![0.0f32; sup_idx.len()];
            let mut gcols = Vec::with_capacity(CACHE_TILE_COLS);
            let mut gvals = Vec::with_capacity(CACHE_TILE_COLS);
            for (r, orow) in chunk.chunks_mut(k).enumerate() {
                let x = batch[r0 + r];
                self.fetch_row_grouped(x, &groups, &mut scratch, &mut gcols, &mut gvals);
                // Identical accumulation order to the materialized fast
                // path in `Gram::weighted_cross_into` — part of the
                // bit-identity contract.
                for (o, &(s, e)) in orow.iter_mut().zip(ranges.iter()) {
                    let mut acc = 0.0;
                    for (&v, &w) in scratch[s..e].iter().zip(&sup_w[s..e]) {
                        acc += w * v as f64;
                    }
                    *o = acc;
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::util::rng::Rng;

    fn fixture(n: usize) -> Dataset {
        let mut rng = Rng::seeded(91);
        blobs(&SyntheticSpec::new(n, 5, 3), &mut rng)
    }

    fn cached(ds: &Dataset, budget: usize) -> CachedGram<'_> {
        CachedGram::new(Gram::on_the_fly(ds, KernelFunction::Gaussian { kappa: 6.0 }), budget)
    }

    #[test]
    fn values_match_materialized_bit_for_bit() {
        let ds = fixture(80);
        let mat = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 6.0 }).materialize();
        let cg = cached(&ds, 1 << 20);
        for i in 0..ds.n {
            for j in 0..ds.n {
                assert_eq!(cg.eval(i, j).to_bits(), Gram::eval(&mat, i, j).to_bits(), "({i},{j})");
            }
            assert_eq!(cg.self_k(i).to_bits(), Gram::self_k(&mat, i).to_bits());
        }
        assert_eq!(KernelProvider::gamma(&cg).to_bits(), Gram::gamma(&mat).to_bits());
    }

    #[test]
    fn hits_do_not_change_values() {
        // Every repeated access must return the first-computed value even
        // after evictions (determinism contract).
        let ds = fixture(60);
        let cg = cached(&ds, 0); // minimal cache: max eviction churn
        let mut first = Vec::new();
        for i in 0..ds.n {
            first.push(cg.eval(i, (i * 7) % ds.n));
        }
        for _round in 0..3 {
            for i in 0..ds.n {
                assert_eq!(cg.eval(i, (i * 7) % ds.n).to_bits(), first[i].to_bits());
            }
        }
    }

    #[test]
    fn repeated_block_access_hits_cache() {
        let ds = fixture(100);
        let cg = cached(&ds, 4 << 20);
        let rows: Vec<usize> = (0..20).collect();
        let cols: Vec<usize> = (30..80).collect();
        let mut out = vec![0.0f64; rows.len() * cols.len()];
        cg.block_into(&rows, &cols, &mut out);
        let cold = cg.cache_stats();
        assert_eq!(cold.hits, 0, "first pass must be all misses");
        assert_eq!(cold.misses, (rows.len() * cols.len()) as u64);
        cg.block_into(&rows, &cols, &mut out);
        let warm = cg.cache_stats();
        assert_eq!(warm.misses, cold.misses, "second pass must not recompute");
        assert_eq!(warm.hits, cold.misses);
        assert!(warm.hit_rate() > 0.49);
    }

    #[test]
    fn residency_stays_within_budget_under_churn() {
        let ds = fixture(400);
        let budget = 16 * 1024; // tiny: forces constant generation turnover
        let cg = cached(&ds, budget);
        let mut rng = Rng::seeded(4);
        for _ in 0..50 {
            let rows: Vec<usize> = (0..30).map(|_| rng.below(ds.n)).collect();
            let cols: Vec<usize> = (0..60).map(|_| rng.below(ds.n)).collect();
            let mut out = vec![0.0f64; rows.len() * cols.len()];
            cg.block_into(&rows, &cols, &mut out);
            let st = cg.cache_stats();
            assert!(
                st.resident_tiles <= st.max_tiles,
                "resident {} > cap {}",
                st.resident_tiles,
                st.max_tiles
            );
        }
        assert!(cg.cache_stats().evictions > 0, "tiny budget must evict");
    }

    #[test]
    fn weighted_cross_matches_gram_with_duplicates() {
        let ds = fixture(120);
        let fly = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 6.0 });
        let mat = fly.materialize();
        let cg = cached(&ds, 1 << 20);
        let mut rng = Rng::seeded(8);
        let batch: Vec<usize> = (0..15).map(|_| rng.below(ds.n)).collect();
        // Support with heavy duplication (same point repeated within and
        // across tiles) — exercises the dedup scatter.
        let mut sup_idx: Vec<u32> = (0..40).map(|_| rng.below(ds.n) as u32).collect();
        sup_idx[5] = sup_idx[4];
        sup_idx[6] = sup_idx[4];
        let sup_w: Vec<f64> = (0..40).map(|_| rng.f64()).collect();
        let ranges = [(0usize, 7usize), (7, 7), (7, 40)];
        let mut got = vec![f64::NAN; batch.len() * ranges.len()];
        cg.weighted_cross_into(&batch, &sup_idx, &sup_w, &ranges, &mut got);
        let mut want = vec![f64::NAN; batch.len() * ranges.len()];
        Gram::weighted_cross_into(&mat, &batch, &sup_idx, &sup_w, &ranges, &mut want);
        for (g, w) in got.iter().zip(want.iter()) {
            assert_eq!(g.to_bits(), w.to_bits(), "cached vs materialized cross");
        }
    }

    #[test]
    fn concurrent_access_is_consistent() {
        // Hammer one cache from the parallel block path and check against
        // direct evaluation afterwards.
        let ds = fixture(300);
        let cg = cached(&ds, 64 * 1024);
        let rows: Vec<usize> = (0..ds.n).collect();
        let cols: Vec<usize> = (0..ds.n).step_by(3).collect();
        let mut out = vec![0.0f64; rows.len() * cols.len()];
        cg.block_into(&rows, &cols, &mut out);
        let base = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 6.0 });
        for (r, &i) in rows.iter().enumerate().step_by(17) {
            for (c, &j) in cols.iter().enumerate().step_by(13) {
                let want = (Gram::eval(&base, i, j) as f32) as f64;
                assert_eq!(out[r * cols.len() + c].to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn plan_extend_equals_rebuild() {
        // Extending a plan batch-by-batch (the lazy state's per-iteration
        // growth) must gather exactly what a from-scratch plan over the
        // concatenation gathers, duplicates and all.
        let ds = fixture(200);
        let cg = cached(&ds, 1 << 20);
        let mut rng = Rng::seeded(13);
        let mut all: Vec<u32> = (0..25).map(|_| rng.below(ds.n) as u32).collect();
        let mut grown = KernelProvider::plan_gather(&cg, &all);
        for _round in 0..4 {
            let add: Vec<u32> = (0..1 + rng.below(40)).map(|_| rng.below(ds.n) as u32).collect();
            cg.plan_gather_extend(&mut grown, &add);
            all.extend_from_slice(&add);
        }
        let rebuilt = KernelProvider::plan_gather(&cg, &all);
        assert_eq!(grown.len(), rebuilt.len());
        let x = 7;
        let mut got = vec![f64::NAN; all.len()];
        let mut want = vec![f64::NAN; all.len()];
        cg.row_gather_planned(x, &grown, &mut got);
        cg.row_gather_planned(x, &rebuilt, &mut want);
        for (m, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "col {m}");
            assert_eq!(g.to_bits(), cg.eval(x, all[m] as usize).to_bits());
        }
    }

    #[test]
    fn stats_summary_is_humane() {
        let ds = fixture(50);
        let cg = cached(&ds, 1 << 20);
        let _ = cg.eval(0, 1);
        let _ = cg.eval(0, 1);
        let s = cg.cache_stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!(s.summary().contains("50.0% hit rate"), "{}", s.summary());
    }

    #[test]
    fn planned_gather_matches_eval_with_duplicates() {
        let ds = fixture(150);
        let cg = cached(&ds, 1 << 20);
        let mut rng = Rng::seeded(13);
        // Unsorted multiset with duplicates across and within tiles.
        let mut cols: Vec<u32> = (0..50).map(|_| rng.below(ds.n) as u32).collect();
        cols[7] = cols[3];
        cols[9] = cols[3];
        let plan = cg.plan_gather(&cols);
        let mut out = vec![f64::NAN; cols.len()];
        for x in [0usize, 42, 149] {
            cg.row_gather_planned(x, &plan, &mut out);
            for (m, &c) in cols.iter().enumerate() {
                assert_eq!(out[m].to_bits(), cg.eval(x, c as usize).to_bits(), "m={m}");
            }
        }
    }

    #[test]
    fn wraps_precomputed_grams_transparently() {
        // The cache layer must be a no-op wrapper over an already
        // materialized table (used by the graph-kernel equivalence tests).
        let data = vec![1.0f32, 0.25, 0.25, 0.5];
        let base = Gram::precomputed("t", 2, data.clone());
        let cg = CachedGram::new(Gram::precomputed("t", 2, data), 1 << 16);
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(cg.eval(i, j).to_bits(), Gram::eval(&base, i, j).to_bits());
            }
        }
        assert_eq!(cg.self_k(1), 0.5);
        assert!(cg.label().contains("tile-lru"));
    }
}
