//! Closed-form kernel functions evaluated on feature vectors.
//!
//! Since the panel engine landed (DESIGN.md §7), every kernel value in the
//! crate is defined by one shared arithmetic: a sequential-f64 inner
//! product ([`crate::util::fmath::dot_f64`]) finished through
//! [`super::panel::KernelPanel::finish`] — Gaussian/Laplacian distances
//! come from the norms expansion `‖x‖² + ‖y‖² − 2⟨x,y⟩`, not the
//! difference form. [`KernelFunction::eval`] replays exactly that
//! arithmetic (deriving the norms inline), so the scalar fallback is
//! bit-identical to every blocked path.

use crate::data::Dataset;
use crate::util::fmath;
use crate::util::rng::Rng;

/// A positive-definite kernel `K(x, y)` computable from raw features.
///
/// The Gaussian kernel follows the paper's parameterization
/// `K(x,y) = exp(−‖x−y‖² / κ)` (κ plays the role usually written 2σ²).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelFunction {
    /// `exp(−‖x−y‖²/κ)` — normalized: K(x,x) = 1, so γ = 1.
    Gaussian { kappa: f64 },
    /// `exp(−‖x−y‖/σ)` — normalized: K(x,x) = 1, so γ = 1.
    Laplacian { sigma: f64 },
    /// `(g·⟨x,y⟩ + c)^p`.
    Polynomial { gamma: f64, coef0: f64, degree: u32 },
    /// `⟨x,y⟩` — plain inner product (kernel k-means degenerates to k-means).
    Linear,
}

impl KernelFunction {
    /// Gaussian kernel with κ from the mean-pairwise-squared-distance
    /// heuristic of Wang et al. (2019), as used in the paper's §6.
    pub fn gaussian_with_heuristic_sigma(ds: &Dataset, rng: &mut Rng) -> KernelFunction {
        KernelFunction::Gaussian { kappa: super::sigma::kappa_heuristic(ds, rng) }
    }

    /// Evaluate on two feature slices — the panel engine's per-value
    /// arithmetic with the squared norms derived inline (callers with a
    /// [`Dataset`] at hand should go through [`super::panel::KernelPanel`],
    /// which reuses the cached norms).
    #[inline]
    pub fn eval(&self, a: &[f32], b: &[f32]) -> f64 {
        let (na, nb) = match self {
            KernelFunction::Gaussian { .. } | KernelFunction::Laplacian { .. } => {
                (fmath::sq_norm_f64(a), fmath::sq_norm_f64(b))
            }
            _ => (0.0, 0.0), // dot kernels: finish ignores the norms
        };
        super::panel::KernelPanel::finish(*self, na, nb, fmath::dot_f64(a, b))
    }

    /// K(x, x) without touching a second row.
    #[inline]
    pub fn eval_self(&self, a: &[f32]) -> f64 {
        match *self {
            KernelFunction::Gaussian { .. } | KernelFunction::Laplacian { .. } => 1.0,
            _ => self.eval(a, a),
        }
    }

    /// Whether K(x,x) = 1 for all x (γ = 1 normalized kernels).
    pub fn is_normalized(&self) -> bool {
        matches!(
            self,
            KernelFunction::Gaussian { .. } | KernelFunction::Laplacian { .. }
        )
    }

    /// Short display name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            KernelFunction::Gaussian { .. } => "gaussian",
            KernelFunction::Laplacian { .. } => "laplacian",
            KernelFunction::Polynomial { .. } => "polynomial",
            KernelFunction::Linear => "linear",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_basics() {
        let k = KernelFunction::Gaussian { kappa: 2.0 };
        let a = [0.0f32, 0.0];
        let b = [1.0f32, 1.0];
        assert!((k.eval(&a, &a) - 1.0).abs() < 1e-12);
        assert!((k.eval(&a, &b) - (-1.0f64).exp()).abs() < 1e-12); // ‖a−b‖²=2, /κ=1
        assert_eq!(k.eval(&a, &b), k.eval(&b, &a));
    }

    #[test]
    fn gaussian_decreases_with_distance() {
        let k = KernelFunction::Gaussian { kappa: 1.0 };
        let a = [0.0f32];
        assert!(k.eval(&a, &[1.0]) > k.eval(&a, &[2.0]));
        assert!(k.eval(&a, &[10.0]) > 0.0);
    }

    #[test]
    fn laplacian_normalized() {
        let k = KernelFunction::Laplacian { sigma: 1.0 };
        assert_eq!(k.eval_self(&[3.0, 4.0]), 1.0);
        assert!((k.eval(&[0.0, 0.0], &[3.0, 4.0]) - (-5.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn polynomial_and_linear() {
        let p = KernelFunction::Polynomial { gamma: 1.0, coef0: 1.0, degree: 2 };
        assert_eq!(p.eval(&[1.0, 2.0], &[3.0, 4.0]), (11.0 + 1.0) * 12.0); // (1·11+1)² = 144
        let l = KernelFunction::Linear;
        assert_eq!(l.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(l.eval_self(&[3.0, 4.0]), 25.0);
    }

    #[test]
    fn normalization_flags() {
        assert!(KernelFunction::Gaussian { kappa: 1.0 }.is_normalized());
        assert!(!KernelFunction::Linear.is_normalized());
    }
}
