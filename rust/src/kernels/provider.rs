//! [`KernelProvider`]: the uniform kernel-access abstraction every
//! algorithm runs against.
//!
//! The trait unifies the three access modes of DESIGN.md §6:
//!
//! * **on-the-fly** — [`Gram::OnTheFly`] evaluates `K(x_i, x_j)` from
//!   features on demand (zero memory beyond the dataset),
//! * **materialized** — [`Gram::Precomputed`] reads a dense n×n f32 table
//!   (O(n²) memory, O(1) lookups; the paper's protocol),
//! * **streaming** — [`super::CachedGram`] evaluates on demand through a
//!   bounded sharded tile-LRU cache (O(cache budget) memory, amortized
//!   lookups for the hot `K(B, S)` tiles that recur across iterations).
//!
//! Algorithms, backends, and the experiment coordinator accept
//! `&dyn KernelProvider`, so which mode serves a run is a *policy* decision
//! (`coordinator::experiment::GramStrategy`) instead of a hard-coded
//! `Gram::materialize()` call — the change that lifts the O(n²) memory wall
//! off every mini-batch variant.
//!
//! Providers must be [`Sync`]: the hot paths fan batch rows out over scoped
//! worker threads that share one provider reference.

use super::{Gram, KernelFunction};
use crate::data::Dataset;
use crate::util::parallel::par_rows_mut;

/// Uniform access to the (implicit) kernel matrix of a dataset.
///
/// The four required methods are the point-wise core; the block operations
/// have straightforward default implementations that providers override
/// with tiled/cached engines. Implementations must be deterministic: the
/// value of `K(i, j)` may never depend on access history (the streaming
/// provider's cache is a pure memoization layer).
pub trait KernelProvider: Sync {
    /// Number of points.
    fn n(&self) -> usize;

    /// Kernel value `K(x_i, x_j)`.
    fn eval(&self, i: usize, j: usize) -> f64;

    /// `K(x_i, x_i)` (providers cache the diagonal).
    fn self_k(&self, i: usize) -> f64;

    /// Display name for reports.
    fn label(&self) -> String;

    /// γ = max_i ‖φ(x_i)‖ = max_i √K(x_i,x_i) — the parameter of Theorem 1.
    fn gamma(&self) -> f64 {
        let mut m = 0.0f64;
        for i in 0..self.n() {
            m = m.max(self.self_k(i));
        }
        m.max(0.0).sqrt()
    }

    /// Fast path: the full i-th row as an f32 slice, available only for
    /// materialized tables. Hot loops hoist this outside their inner loop
    /// to skip per-element dispatch.
    fn row_slice(&self, _i: usize) -> Option<&[f32]> {
        None
    }

    /// The underlying (dataset, closed-form kernel) pair for providers that
    /// evaluate a feature kernel — `None` for precomputed tables. The XLA
    /// backend uses this to marshal raw features into the AOT graph.
    fn feature_kernel(&self) -> Option<(&Dataset, KernelFunction)> {
        None
    }

    /// Build a reusable gather plan for a fixed column multiset. Pair with
    /// [`KernelProvider::row_gather_planned`] in loops that gather the
    /// *same* columns for many rows (Algorithm 1's fused px sweep): any
    /// per-call grouping/sorting a provider needs is hoisted into the plan
    /// and paid once, not once per row. Default: stores the columns
    /// verbatim.
    fn plan_gather(&self, cols: &[u32]) -> GatherPlan {
        GatherPlan { cols: cols.to_vec(), groups: None }
    }

    /// Gather one row's scattered kernel values through a plan from
    /// [`KernelProvider::plan_gather`]: `out[m] = K(x, cols[m])` in the
    /// plan's column order — values and order identical to per-element
    /// [`KernelProvider::eval`]. Default: per-element evaluation.
    fn row_gather_planned(&self, x: usize, plan: &GatherPlan, out: &mut [f64]) {
        assert_eq!(plan.cols.len(), out.len(), "row_gather_planned: bad shape");
        for (o, &j) in out.iter_mut().zip(plan.cols.iter()) {
            *o = self.eval(x, j as usize);
        }
    }

    /// Extend a plan from [`KernelProvider::plan_gather`] with columns
    /// appended to the end of its column list — semantically identical to
    /// rebuilding the plan over the concatenation, but providers with
    /// sorted internal structure (the streaming tile cache) override it to
    /// merge incrementally in O(plan + new) instead of re-sorting.
    /// Algorithm 1's lazy state extends its full-history plan by one batch
    /// per iteration through this. Default: append the columns; if the
    /// plan carries structure this provider cannot extend, rebuild.
    fn plan_gather_extend(&self, plan: &mut GatherPlan, new_cols: &[u32]) {
        plan.cols.extend_from_slice(new_cols);
        if plan.groups.is_some() {
            let rebuilt = self.plan_gather(&plan.cols);
            *plan = rebuilt;
        }
    }

    /// Fill `out` (row-major, `rows.len() × cols.len()`) with the dense
    /// block `K(rows, cols)`. Default: parallel point-wise evaluation.
    fn block_into(&self, rows: &[usize], cols: &[usize], out: &mut [f64]) {
        let nc = cols.len();
        assert_eq!(out.len(), rows.len() * nc, "block_into: bad output shape");
        if out.is_empty() {
            return;
        }
        par_rows_mut(out, nc, |r0, chunk| {
            for (r, orow) in chunk.chunks_mut(nc).enumerate() {
                let i = rows[r0 + r];
                for (o, &j) in orow.iter_mut().zip(cols.iter()) {
                    *o = self.eval(i, j);
                }
            }
        });
    }

    /// Fused weighted cross-term contraction for the assignment step:
    /// given the concatenated support of `k` centers — dataset indices
    /// `sup_idx` with coefficients `sup_w`, center `j` owning the slice
    /// `ranges[j] = (start, end)` — fills
    /// `out[r·k + j] = Σ_{m ∈ ranges[j]} w_m · K(batch[r], sup_idx[m])`.
    /// Default: parallel point-wise evaluation in support order.
    fn weighted_cross_into(
        &self,
        batch: &[usize],
        sup_idx: &[u32],
        sup_w: &[f64],
        ranges: &[(usize, usize)],
        out: &mut [f64],
    ) {
        let k = ranges.len();
        assert_eq!(sup_idx.len(), sup_w.len(), "support index/weight mismatch");
        assert_eq!(out.len(), batch.len() * k, "weighted_cross_into: bad shape");
        if out.is_empty() {
            return;
        }
        par_rows_mut(out, k, |r0, chunk| {
            for (r, orow) in chunk.chunks_mut(k).enumerate() {
                let x = batch[r0 + r];
                for (o, &(s, e)) in orow.iter_mut().zip(ranges.iter()) {
                    let mut acc = 0.0;
                    for (&y, &w) in sup_idx[s..e].iter().zip(&sup_w[s..e]) {
                        acc += w * self.eval(x, y as usize);
                    }
                    *o = acc;
                }
            }
        });
    }
}

/// A reusable column-gather plan (see [`KernelProvider::plan_gather`]):
/// the column multiset plus whatever provider-specific precomputation the
/// builder chose to hoist (the streaming provider stores its sorted tile
/// grouping here so the per-row hot path never re-sorts).
pub struct GatherPlan {
    pub(super) cols: Vec<u32>,
    /// `(tile, col, pos)` sorted by `(tile, col)` — present when built by
    /// the streaming tile-LRU provider, ignored by everything else.
    pub(super) groups: Option<Vec<(u32, u32, u32)>>,
}

impl GatherPlan {
    /// Number of columns the plan covers (the required gather width).
    pub fn len(&self) -> usize {
        self.cols.len()
    }

    /// Whether the plan covers no columns.
    pub fn is_empty(&self) -> bool {
        self.cols.is_empty()
    }
}

impl KernelProvider for Gram<'_> {
    fn n(&self) -> usize {
        Gram::n(self)
    }

    fn eval(&self, i: usize, j: usize) -> f64 {
        Gram::eval(self, i, j)
    }

    fn self_k(&self, i: usize) -> f64 {
        Gram::self_k(self, i)
    }

    fn label(&self) -> String {
        Gram::label(self)
    }

    fn gamma(&self) -> f64 {
        Gram::gamma(self)
    }

    fn row_slice(&self, i: usize) -> Option<&[f32]> {
        Gram::row_slice(self, i)
    }

    fn feature_kernel(&self) -> Option<(&Dataset, KernelFunction)> {
        Gram::feature_kernel(self)
    }

    fn row_gather_planned(&self, x: usize, plan: &GatherPlan, out: &mut [f64]) {
        Gram::row_gather_cols(self, x, &plan.cols, out)
    }

    fn block_into(&self, rows: &[usize], cols: &[usize], out: &mut [f64]) {
        Gram::block_into(self, rows, cols, out)
    }

    fn weighted_cross_into(
        &self,
        batch: &[usize],
        sup_idx: &[u32],
        sup_w: &[f64],
        ranges: &[(usize, usize)],
        out: &mut [f64],
    ) {
        Gram::weighted_cross_into(self, batch, sup_idx, sup_w, ranges, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::util::rng::Rng;

    /// A minimal provider exercising the trait defaults: a linear kernel
    /// evaluated straight off a dataset.
    struct PlainLinear<'a>(&'a Dataset);

    impl KernelProvider for PlainLinear<'_> {
        fn n(&self) -> usize {
            self.0.n
        }

        fn eval(&self, i: usize, j: usize) -> f64 {
            KernelFunction::Linear.eval(self.0.row(i), self.0.row(j))
        }

        fn self_k(&self, i: usize) -> f64 {
            self.eval(i, i)
        }

        fn label(&self) -> String {
            "plain-linear".into()
        }
    }

    fn fixture() -> Dataset {
        let mut rng = Rng::seeded(31);
        blobs(&SyntheticSpec::new(30, 3, 2), &mut rng)
    }

    #[test]
    fn default_gamma_scans_diagonal() {
        let ds = fixture();
        let p = PlainLinear(&ds);
        let want = (0..ds.n)
            .map(|i| p.self_k(i))
            .fold(0.0f64, f64::max)
            .sqrt();
        assert!((KernelProvider::gamma(&p) - want).abs() < 1e-12);
        assert!(p.row_slice(0).is_none());
        assert!(p.feature_kernel().is_none());
    }

    #[test]
    fn default_block_and_cross_match_pointwise() {
        let ds = fixture();
        let p = PlainLinear(&ds);
        let rows = [0usize, 7, 11];
        let cols = [3usize, 4, 9, 20];
        let mut blk = vec![0.0f64; rows.len() * cols.len()];
        p.block_into(&rows, &cols, &mut blk);
        for (r, &i) in rows.iter().enumerate() {
            for (c, &j) in cols.iter().enumerate() {
                assert_eq!(blk[r * cols.len() + c], p.eval(i, j));
            }
        }
        let batch = [1usize, 2, 5];
        let sup_idx = [0u32, 3, 6, 9];
        let sup_w = [0.5f64, 0.25, 0.125, 0.0625];
        let ranges = [(0usize, 2usize), (2, 4)];
        let mut out = vec![f64::NAN; batch.len() * ranges.len()];
        p.weighted_cross_into(&batch, &sup_idx, &sup_w, &ranges, &mut out);
        for (r, &x) in batch.iter().enumerate() {
            for (j, &(s, e)) in ranges.iter().enumerate() {
                let want: f64 = (s..e)
                    .map(|m| sup_w[m] * p.eval(x, sup_idx[m] as usize))
                    .sum();
                assert!((out[r * ranges.len() + j] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gram_delegates_through_the_trait() {
        let ds = fixture();
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 4.0 });
        let dynp: &dyn KernelProvider = &gram;
        assert_eq!(dynp.n(), ds.n);
        assert_eq!(dynp.eval(2, 9), Gram::eval(&gram, 2, 9));
        assert_eq!(dynp.self_k(4), 1.0);
        assert!(dynp.feature_kernel().is_some());
        let mat = gram.materialize();
        let dynm: &dyn KernelProvider = &mat;
        assert!(dynm.row_slice(3).is_some());
        assert!(dynm.feature_kernel().is_none());
    }
}
