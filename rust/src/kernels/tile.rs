//! Cache-tiling parameters for the gram/assignment hot path.
//!
//! The blocked engine (DESIGN.md §5) walks kernel evaluations in column
//! tiles: a tile of support/column feature rows is loaded once and reused
//! against every batch row in the current thread chunk, so the tile's
//! features stay L1/L2-resident instead of being streamed from DRAM once
//! per batch row. The tile width is chosen so one tile of f32 features
//! (`cols × d × 4` bytes) fits comfortably in half of a conservative
//! per-core L2 budget, leaving the other half for the batch rows and the
//! output accumulators.

/// Per-core cache budget the column tile is sized against (bytes). Half of
/// a conservative 128 KiB L2 slice — small enough to also behave well on
/// big.LITTLE parts and shared-L2 designs.
pub const TILE_BYTES: usize = 64 * 1024;

/// Hard bounds on the tile width: below 8 columns the loop overhead
/// dominates; above 1024 the index/coefficient arrays start competing with
/// the features for cache.
pub const MIN_TILE_COLS: usize = 8;

/// Upper bound companion of [`MIN_TILE_COLS`].
pub const MAX_TILE_COLS: usize = 1024;

/// Number of feature columns per tile for dimension `d` (f32 storage).
pub fn tile_cols(d: usize) -> usize {
    (TILE_BYTES / (4 * d.max(1))).clamp(MIN_TILE_COLS, MAX_TILE_COLS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_cols_bounds() {
        assert_eq!(tile_cols(0), MAX_TILE_COLS);
        assert_eq!(tile_cols(1), MAX_TILE_COLS); // 16384 clamped down
        assert_eq!(tile_cols(16), MAX_TILE_COLS);
        assert_eq!(tile_cols(128), 128);
        assert_eq!(tile_cols(1 << 20), MIN_TILE_COLS);
        // Monotone non-increasing in d.
        let mut prev = usize::MAX;
        for d in [1, 2, 8, 64, 512, 4096] {
            let t = tile_cols(d);
            assert!(t <= prev);
            prev = t;
        }
    }

    #[test]
    fn tile_fits_budget() {
        for d in [4usize, 16, 128, 784] {
            let t = tile_cols(d);
            if t > MIN_TILE_COLS {
                assert!(t * d * 4 <= TILE_BYTES, "d={d}: tile {t} overflows budget");
            }
        }
    }
}
