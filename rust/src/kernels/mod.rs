//! Kernel substrate: kernel functions, gram providers, the graph kernels
//! (k-nn and heat) from the paper's Appendix C, the σ/κ bandwidth heuristic
//! (Wang et al. 2019), and the γ = max‖φ(x)‖ statistic that parameterizes
//! Theorem 1.

mod function;
mod gram;
pub mod graph;
pub mod sigma;
pub mod tile;

pub use function::KernelFunction;
pub use gram::Gram;
