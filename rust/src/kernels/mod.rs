//! Kernel substrate: kernel functions, the panel micro-kernel engine
//! ([`KernelPanel`], DESIGN.md §7) every block fill runs through, gram
//! providers behind the [`KernelProvider`] abstraction (on-the-fly,
//! materialized, and the streaming tile-LRU-cached [`CachedGram`]), the
//! graph kernels (k-nn and heat) from the paper's Appendix C, the σ/κ
//! bandwidth heuristic (Wang et al. 2019), and the γ = max‖φ(x)‖ statistic
//! that parameterizes Theorem 1.

mod cache;
mod function;
mod gram;
pub mod graph;
pub mod panel;
mod provider;
pub mod sigma;
pub mod tile;

pub use cache::{CacheStats, CachedGram, TileCache, CACHE_TILE_COLS};
pub use function::KernelFunction;
pub use gram::Gram;
pub use panel::KernelPanel;
pub use provider::{GatherPlan, KernelProvider};
// The numerics switch lives in util::simd (the layer that implements the
// arms); re-exported here because the kernel substrate is where callers
// choose it.
pub use crate::util::simd::NumericsMode;
