//! Panel micro-kernels: blocked kernel-block evaluation (DESIGN.md §7).
//!
//! The scalar path evaluates `K(x, y)` one pair at a time; each pair is a
//! single loop-carried f64 chain, so the CPU retires roughly one
//! fused-multiply-add per FP-add latency (~4 cycles) and the SIMD units
//! idle. [`KernelPanel`] instead computes an `MR × NR` *panel* of inner
//! products per micro-kernel invocation — `MR·NR` independent accumulator
//! chains that the compiler keeps in vector registers — and derives
//! distances from cached squared norms:
//!
//! `‖x − y‖² = ‖x‖² + ‖y‖² − 2⟨x, y⟩`,
//!
//! followed by a separate batched transcendental pass (`exp` for
//! Gaussian/Laplacian, `powi` for polynomial). The column panel is packed
//! once per `NR`-block into a dimension-major f64 buffer and then streamed
//! against every row, so the pack cost amortizes over the whole row set
//! and the inner loop is branch- and gather-free.
//!
//! **Bit-identity contract.** Speed comes from parallelism *across* output
//! values only: each value's inner product is the sequential f64 chain of
//! [`fmath::dot_f64`], its distance is [`fmath::sqdist_from_norms`], and
//! its finish is [`KernelPanel::finish`] — so any tile shape, any blocking,
//! and the scalar fallback produce bit-for-bit identical f64 values, and
//! one `as f32` quantization at the storage boundary yields the identical
//! table no matter which engine filled it. The streaming-vs-materialized
//! equivalence suite (`tests/prop_stream_equivalence.rs`) pins this.
//!
//! **Numerics modes.** The contract above describes
//! [`NumericsMode::Deterministic`], the default. A panel bound with
//! [`NumericsMode::Fast`] dispatches the dot micro-kernel and the batched
//! exp finish to the runtime-detected SIMD arm in [`crate::util::simd`]:
//! dots stay bit-identical (f32-widened products are exact under FMA),
//! while Gaussian/Laplacian values move within the documented exp ulp
//! budget. [`KernelPanel::eval_idx`] is always the deterministic scalar
//! reference regardless of mode. See DESIGN.md §13.

use super::KernelFunction;
use crate::data::Dataset;
use crate::util::fmath;
use crate::util::simd::{self, NumericsMode};
use std::cell::RefCell;

thread_local! {
    /// Reused dimension-major pack buffer. Pool worker threads persist for
    /// the process lifetime, so after warm-up a fill never allocates —
    /// `resize` to an unchanged `d` is a no-op and capacity is retained
    /// across datasets. Not re-entered: nothing inside a fill calls back
    /// into another fill on the same thread.
    static PACK_BUF: RefCell<Vec<[f64; PANEL_COLS]>> = const { RefCell::new(Vec::new()) };
}

/// Rows per micro-kernel invocation (register-tile height) — alias of
/// [`simd::MR`], where the micro-kernel arms now live.
pub const PANEL_ROWS: usize = simd::MR;

/// Columns per micro-kernel invocation (register-tile width) — alias of
/// [`simd::NR`]. Together with [`PANEL_ROWS`] this yields 32 independent
/// f64 accumulator chains — 8 × 4-lane vector registers on AVX2-class
/// hardware, which both hides the FP-add latency and saturates the FMA
/// ports.
pub const PANEL_COLS: usize = simd::NR;

/// A kernel function bound to a dataset and its cached squared norms,
/// exposing blocked fill entry points. Construction is cheap (the norms
/// are memoized on the [`Dataset`]); hot loops may build one per call.
pub struct KernelPanel<'a> {
    ds: &'a Dataset,
    func: KernelFunction,
    norms: &'a [f64],
    mode: NumericsMode,
}

impl<'a> KernelPanel<'a> {
    /// Bind `func` to `ds` in [`NumericsMode::Deterministic`], computing
    /// the row-norm cache on first use.
    pub fn new(ds: &'a Dataset, func: KernelFunction) -> KernelPanel<'a> {
        Self::new_with(ds, func, NumericsMode::Deterministic)
    }

    /// [`KernelPanel::new`] with an explicit numerics mode for the block
    /// fills. [`KernelPanel::eval_idx`] stays the deterministic scalar
    /// reference either way.
    pub fn new_with(
        ds: &'a Dataset,
        func: KernelFunction,
        mode: NumericsMode,
    ) -> KernelPanel<'a> {
        let norms = match func {
            // Dot-product kernels never touch the norms.
            KernelFunction::Polynomial { .. } | KernelFunction::Linear => &[],
            _ => ds.sq_norms(),
        };
        KernelPanel { ds, func, norms, mode }
    }

    /// The bound kernel function.
    pub fn func(&self) -> KernelFunction {
        self.func
    }

    /// The numerics mode the block fills run under.
    pub fn mode(&self) -> NumericsMode {
        self.mode
    }

    /// Finish one kernel value from cached norms and an inner product —
    /// the single definition of the value-level arithmetic every engine
    /// (scalar, panel, table, cache) replays.
    #[inline]
    pub fn finish(func: KernelFunction, ni: f64, nj: f64, dot: f64) -> f64 {
        match func {
            KernelFunction::Gaussian { kappa } => {
                (-fmath::sqdist_from_norms(ni, nj, dot) / kappa).exp()
            }
            KernelFunction::Laplacian { sigma } => {
                (-fmath::sqdist_from_norms(ni, nj, dot).sqrt() / sigma).exp()
            }
            KernelFunction::Polynomial { gamma, coef0, degree } => {
                (gamma * dot + coef0).powi(degree as i32)
            }
            KernelFunction::Linear => dot,
        }
    }

    /// The exp argument of an exp-family kernel value — `Some(a)` such
    /// that [`KernelPanel::finish`] `== a.exp()` bitwise for Gaussian and
    /// Laplacian, `None` for the dot-product kernels. The Fast-mode
    /// batched finish computes these arguments with the identical
    /// association, then substitutes the SIMD exp for `f64::exp`, so the
    /// entire Fast-vs-Deterministic divergence is the exp ulp budget.
    #[inline]
    pub fn exp_arg(func: KernelFunction, ni: f64, nj: f64, dot: f64) -> Option<f64> {
        match func {
            KernelFunction::Gaussian { kappa } => {
                Some(-fmath::sqdist_from_norms(ni, nj, dot) / kappa)
            }
            KernelFunction::Laplacian { sigma } => {
                Some(-fmath::sqdist_from_norms(ni, nj, dot).sqrt() / sigma)
            }
            KernelFunction::Polynomial { .. } | KernelFunction::Linear => None,
        }
    }

    /// `K(x_i, x_j)` — the scalar reference the panels are bit-identical
    /// to.
    #[inline]
    pub fn eval_idx(&self, i: usize, j: usize) -> f64 {
        let dot = fmath::dot_f64(self.ds.row(i), self.ds.row(j));
        let (ni, nj) = self.norm_pair(i, j);
        Self::finish(self.func, ni, nj, dot)
    }

    #[inline]
    fn norm_pair(&self, i: usize, j: usize) -> (f64, f64) {
        if self.norms.is_empty() {
            (0.0, 0.0) // dot kernels: finish ignores the norms
        } else {
            (self.norms[i], self.norms[j])
        }
    }

    /// Fill `out` (row-major, `rows.len() × cols.len()`, row stride
    /// `cols.len()`) with `K(rows, cols)` as unquantized f64.
    pub fn fill_f64(&self, rows: &[usize], cols: &[usize], out: &mut [f64]) {
        self.fill_f64_strided(rows, cols, cols.len(), out);
    }

    /// [`KernelPanel::fill_f64`] with an explicit output row stride
    /// (`ostride ≥ cols.len()`); row `r` of the block lands at
    /// `out[r*ostride ..][.. cols.len()]`. Serial — callers parallelize
    /// over row chunks.
    pub fn fill_f64_strided(
        &self,
        rows: &[usize],
        cols: &[usize],
        ostride: usize,
        out: &mut [f64],
    ) {
        let nc = cols.len();
        assert!(ostride >= nc, "fill: stride narrower than the column set");
        if rows.is_empty() || nc == 0 {
            return;
        }
        assert!(
            out.len() >= (rows.len() - 1) * ostride + nc,
            "fill: output buffer too small"
        );
        if rows.len() == 1 {
            // Single-row fast path (the streaming cache's miss batches):
            // direct sequential dots, no pack, no allocation. Bit-identical
            // to the micro-kernel by the fmath reduction-order contract.
            let xi = self.ds.row(rows[0]);
            for (o, &col) in out[..nc].iter_mut().zip(cols.iter()) {
                *o = fmath::dot_f64(xi, self.ds.row(col));
            }
            self.finish_rows(rows, cols, ostride, out);
            return;
        }
        let d = self.ds.d;
        // Dimension-major packed column panel: pack[t][c] = x_{cols[c0+c]}[t],
        // zero-padded to PANEL_COLS so the micro-kernel is branch-free.
        // The buffer is thread-local: the hot paths call this once per
        // column tile per chunk, and a fresh allocation each time would be
        // avoidable traffic in the dispatch-sensitive iteration loop.
        PACK_BUF.with(|cell| {
            let mut pack = cell.borrow_mut();
            pack.resize(d, [0.0; PANEL_COLS]);
            let mut c0 = 0;
            while c0 < nc {
                let cw = PANEL_COLS.min(nc - c0);
                for (c, &col) in cols[c0..c0 + cw].iter().enumerate() {
                    for (slab, &v) in pack.iter_mut().zip(self.ds.row(col)) {
                        slab[c] = v as f64;
                    }
                }
                // Zero the padding lanes (stale from earlier blocks/calls).
                if cw < PANEL_COLS {
                    for slab in pack.iter_mut() {
                        for lane in slab.iter_mut().skip(cw) {
                            *lane = 0.0;
                        }
                    }
                }
                let mut r0 = 0;
                while r0 < rows.len() {
                    let rw = PANEL_ROWS.min(rows.len() - r0);
                    let acc = self.dot_micro_kernel(&rows[r0..r0 + rw], &pack);
                    for (r, accr) in acc.iter().enumerate().take(rw) {
                        let dst =
                            &mut out[(r0 + r) * ostride + c0..(r0 + r) * ostride + c0 + cw];
                        dst.copy_from_slice(&accr[..cw]);
                    }
                    r0 += rw;
                }
                c0 += cw;
            }
        });
        self.finish_rows(rows, cols, ostride, out);
    }

    /// Batched finish pass (the `exp` loop for Gaussian/Laplacian) over an
    /// already-filled dot block. In Fast mode the exp-family kernels
    /// compute their exp arguments in place (identical association to the
    /// deterministic path) and run the SIMD batched exp over each row;
    /// everything else — and Deterministic mode always — replays the
    /// per-value [`KernelPanel::finish`].
    fn finish_rows(&self, rows: &[usize], cols: &[usize], ostride: usize, out: &mut [f64]) {
        if matches!(self.func, KernelFunction::Linear) {
            return;
        }
        let nc = cols.len();
        let batched_exp = self.mode == NumericsMode::Fast
            && matches!(
                self.func,
                KernelFunction::Gaussian { .. } | KernelFunction::Laplacian { .. }
            );
        for (r, &row) in rows.iter().enumerate() {
            let (ni, _) = self.norm_pair(row, row);
            let orow = &mut out[r * ostride..r * ostride + nc];
            if batched_exp {
                for (o, &col) in orow.iter_mut().zip(cols.iter()) {
                    let (_, nj) = self.norm_pair(row, col);
                    // Unwrap is safe: batched_exp implies an exp kernel.
                    *o = Self::exp_arg(self.func, ni, nj, *o).unwrap();
                }
                simd::exp_slice(NumericsMode::Fast, orow);
            } else {
                for (o, &col) in orow.iter_mut().zip(cols.iter()) {
                    let (_, nj) = self.norm_pair(row, col);
                    *o = Self::finish(self.func, ni, nj, *o);
                }
            }
        }
    }

    /// The register-tiled dot micro-kernel over dataset row indices —
    /// resolves the feature slices and delegates to the mode-dispatched
    /// [`simd::dot_rows`] (bit-identical across arms for the crate's
    /// f32-widened inputs).
    #[inline]
    fn dot_micro_kernel(
        &self,
        rows: &[usize],
        pack: &[[f64; PANEL_COLS]],
    ) -> [[f64; PANEL_COLS]; PANEL_ROWS] {
        let mut slices: [&[f32]; PANEL_ROWS] = [&[]; PANEL_ROWS];
        for (s, &r) in slices.iter_mut().zip(rows.iter()) {
            *s = self.ds.row(r);
        }
        simd::dot_rows(self.mode, &slices[..rows.len().min(PANEL_ROWS)], pack)
    }

    /// Fill `out` (row-major, `rows.len() × cols.len()`) with `K(rows,
    /// cols)` quantized to f32 — the exact values a materialized table
    /// stores. `scratch` is a reusable f64 staging buffer (cleared and
    /// resized as needed).
    pub fn fill_f32(
        &self,
        rows: &[usize],
        cols: &[usize],
        scratch: &mut Vec<f64>,
        out: &mut [f32],
    ) {
        let len = rows.len() * cols.len();
        assert_eq!(out.len(), len, "fill_f32: bad output shape");
        scratch.clear();
        scratch.resize(len, 0.0);
        self.fill_f64(rows, cols, scratch);
        for (o, &v) in out.iter_mut().zip(scratch.iter()) {
            *o = v as f32;
        }
    }

    /// Fill one row's scattered kernel values as f32:
    /// `out[m] = K(x, cols[m]) as f32`. Stack-buffered for the streaming
    /// tile cache's miss batches (≤ one cache tile wide); falls back to a
    /// heap scratch above that.
    pub fn fill_row_f32(&self, x: usize, cols: &[usize], out: &mut [f32]) {
        assert_eq!(cols.len(), out.len(), "fill_row_f32: bad shape");
        const STACK: usize = 32;
        if cols.len() <= STACK {
            let mut buf = [0.0f64; STACK];
            self.fill_f64(&[x], cols, &mut buf[..cols.len()]);
            for (o, &v) in out.iter_mut().zip(buf[..cols.len()].iter()) {
                *o = v as f32;
            }
        } else {
            let mut scratch = Vec::new();
            self.fill_f32(&[x], cols, &mut scratch, out);
        }
    }

    /// [`KernelPanel::fill_row_f32`] for a `u32` column list (the streaming
    /// tile cache's index width): converts through a stack buffer in
    /// tile-sized chunks, allocation-free at any length.
    pub fn fill_row_f32_u32(&self, x: usize, cols: &[u32], out: &mut [f32]) {
        assert_eq!(cols.len(), out.len(), "fill_row_f32_u32: bad shape");
        const STACK: usize = 32;
        let mut buf = [0usize; STACK];
        let mut c0 = 0;
        while c0 < cols.len() {
            let cw = STACK.min(cols.len() - c0);
            for (b, &c) in buf[..cw].iter_mut().zip(&cols[c0..c0 + cw]) {
                *b = c as usize;
            }
            self.fill_row_f32(x, &buf[..cw], &mut out[c0..c0 + cw]);
            c0 += cw;
        }
    }

    /// One row's kernel values for a `u32` column list as **unquantized**
    /// f64 — bitwise identical to per-pair [`KernelPanel::eval_idx`] by the
    /// fmath reduction-order contract. Feeds Algorithm 1's lazy replay,
    /// which rebuilds a stale point's `⟨φ(x), C_j⟩` row against its whole
    /// update log in one gather; converts through a stack buffer in
    /// tile-sized chunks, allocation-free at any length.
    pub fn fill_row_f64_u32(&self, x: usize, cols: &[u32], out: &mut [f64]) {
        assert_eq!(cols.len(), out.len(), "fill_row_f64_u32: bad shape");
        const STACK: usize = 32;
        let mut buf = [0usize; STACK];
        let mut c0 = 0;
        while c0 < cols.len() {
            let cw = STACK.min(cols.len() - c0);
            for (b, &c) in buf[..cw].iter_mut().zip(&cols[c0..c0 + cw]) {
                *b = c as usize;
            }
            self.fill_f64(&[x], &buf[..cw], &mut out[c0..c0 + cw]);
            c0 += cw;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::util::rng::Rng;

    /// Independent reference: the pre-panel difference-form scalar kernel.
    fn reference_eval(func: KernelFunction, a: &[f32], b: &[f32]) -> f64 {
        match func {
            KernelFunction::Gaussian { kappa } => {
                let mut s = 0.0f64;
                for (x, y) in a.iter().zip(b.iter()) {
                    let d = (*x - *y) as f64;
                    s += d * d;
                }
                (-s / kappa).exp()
            }
            KernelFunction::Laplacian { sigma } => {
                let mut s = 0.0f64;
                for (x, y) in a.iter().zip(b.iter()) {
                    let d = (*x - *y) as f64;
                    s += d * d;
                }
                (-s.sqrt() / sigma).exp()
            }
            KernelFunction::Polynomial { gamma, coef0, degree } => {
                let mut s = 0.0f64;
                for (x, y) in a.iter().zip(b.iter()) {
                    s += (*x as f64) * (*y as f64);
                }
                (gamma * s + coef0).powi(degree as i32)
            }
            KernelFunction::Linear => {
                let mut s = 0.0f64;
                for (x, y) in a.iter().zip(b.iter()) {
                    s += (*x as f64) * (*y as f64);
                }
                s
            }
        }
    }

    fn kernels() -> Vec<KernelFunction> {
        vec![
            KernelFunction::Gaussian { kappa: 5.0 },
            KernelFunction::Laplacian { sigma: 2.0 },
            KernelFunction::Polynomial { gamma: 0.5, coef0: 1.0, degree: 3 },
            KernelFunction::Linear,
        ]
    }

    #[test]
    fn panel_fill_matches_eval_idx_bitwise() {
        let mut rng = Rng::seeded(21);
        for d in [1usize, 3, 16, 128] {
            let ds = blobs(&SyntheticSpec::new(60, d, 3), &mut rng);
            for func in kernels() {
                let p = KernelPanel::new(&ds, func);
                // Odd shapes: remainder rows (5 % 4) and cols (13 % 8).
                let rows: Vec<usize> = (0..5).map(|_| rng.below(ds.n)).collect();
                let cols: Vec<usize> = (0..13).map(|_| rng.below(ds.n)).collect();
                let mut out = vec![f64::NAN; rows.len() * cols.len()];
                p.fill_f64(&rows, &cols, &mut out);
                for (r, &i) in rows.iter().enumerate() {
                    for (c, &j) in cols.iter().enumerate() {
                        let got = out[r * cols.len() + c];
                        let want = p.eval_idx(i, j);
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "d={d} {func:?} ({i},{j}): {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn panel_matches_difference_form_reference() {
        let mut rng = Rng::seeded(33);
        for d in [1usize, 3, 16, 128] {
            let ds = blobs(&SyntheticSpec::new(40, d, 2), &mut rng);
            for func in kernels() {
                let p = KernelPanel::new(&ds, func);
                for _ in 0..50 {
                    let (i, j) = (rng.below(ds.n), rng.below(ds.n));
                    let got = p.eval_idx(i, j);
                    let want = reference_eval(func, ds.row(i), ds.row(j));
                    let tol = 1e-6 * want.abs().max(1.0);
                    assert!(
                        (got - want).abs() <= tol,
                        "d={d} {func:?} ({i},{j}): {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn strided_fill_writes_only_its_window() {
        let mut rng = Rng::seeded(7);
        let ds = blobs(&SyntheticSpec::new(30, 6, 2), &mut rng);
        let p = KernelPanel::new(&ds, KernelFunction::Gaussian { kappa: 4.0 });
        let rows = [2usize, 9, 17];
        let cols = [1usize, 4, 7, 11, 20];
        let stride = 9;
        let mut out = vec![f64::NAN; rows.len() * stride];
        p.fill_f64_strided(&rows, &cols, stride, &mut out);
        for (r, &i) in rows.iter().enumerate() {
            for (c, &j) in cols.iter().enumerate() {
                assert_eq!(out[r * stride + c].to_bits(), p.eval_idx(i, j).to_bits());
            }
            for c in cols.len()..stride {
                if r * stride + c < out.len() {
                    assert!(out[r * stride + c].is_nan(), "wrote outside window");
                }
            }
        }
    }

    #[test]
    fn f32_fill_and_row_fill_agree() {
        let mut rng = Rng::seeded(11);
        let ds = blobs(&SyntheticSpec::new(50, 16, 2), &mut rng);
        for func in kernels() {
            let p = KernelPanel::new(&ds, func);
            let rows: Vec<usize> = (0..7).map(|_| rng.below(ds.n)).collect();
            let cols: Vec<usize> = (0..37).map(|_| rng.below(ds.n)).collect();
            let mut scratch = Vec::new();
            let mut block = vec![0.0f32; rows.len() * cols.len()];
            p.fill_f32(&rows, &cols, &mut scratch, &mut block);
            let mut row_out = vec![0.0f32; cols.len()];
            for (r, &i) in rows.iter().enumerate() {
                p.fill_row_f32(i, &cols, &mut row_out);
                for (c, (&a, &b)) in
                    block[r * cols.len()..(r + 1) * cols.len()].iter().zip(&row_out).enumerate()
                {
                    assert_eq!(a.to_bits(), b.to_bits(), "r={r} c={c}");
                    assert_eq!(a.to_bits(), (p.eval_idx(i, cols[c]) as f32).to_bits());
                }
            }
        }
    }

    #[test]
    fn normalized_diagonal_is_exactly_one() {
        let mut rng = Rng::seeded(2);
        let ds = blobs(&SyntheticSpec::new(20, 8, 2), &mut rng);
        for func in [
            KernelFunction::Gaussian { kappa: 3.0 },
            KernelFunction::Laplacian { sigma: 1.5 },
        ] {
            let p = KernelPanel::new(&ds, func);
            for i in 0..ds.n {
                assert_eq!(p.eval_idx(i, i), 1.0, "{func:?} diag({i})");
            }
        }
    }

    #[test]
    fn fill_row_f64_u32_is_bitwise_eval() {
        // The lazy-replay gather must reproduce eval_idx to the bit at any
        // length, across the 32-wide staging chunk boundary.
        let mut rng = Rng::seeded(6);
        let ds = blobs(&SyntheticSpec::new(80, 5, 2), &mut rng);
        for func in [
            KernelFunction::Gaussian { kappa: 3.0 },
            KernelFunction::Linear,
        ] {
            let p = KernelPanel::new(&ds, func);
            for len in [1usize, 31, 32, 33, 77] {
                let cols: Vec<u32> = (0..len).map(|_| rng.below(ds.n) as u32).collect();
                let mut out = vec![f64::NAN; len];
                p.fill_row_f64_u32(3, &cols, &mut out);
                for (m, &c) in cols.iter().enumerate() {
                    assert_eq!(
                        out[m].to_bits(),
                        p.eval_idx(3, c as usize).to_bits(),
                        "{func:?} len={len} m={m}"
                    );
                }
            }
        }
    }

    #[test]
    fn empty_shapes_are_noops() {
        let mut rng = Rng::seeded(4);
        let ds = blobs(&SyntheticSpec::new(10, 3, 1), &mut rng);
        let p = KernelPanel::new(&ds, KernelFunction::Linear);
        let mut out: Vec<f64> = vec![];
        p.fill_f64(&[], &[], &mut out);
        p.fill_f64(&[1, 2], &[], &mut out);
        p.fill_f64(&[], &[1, 2], &mut out);
    }

    #[test]
    fn exp_arg_composes_to_finish_bitwise() {
        // The Fast finish substitutes batched exp for f64::exp over these
        // arguments, so exp_arg ∘ exp must reproduce finish exactly.
        let mut rng = Rng::seeded(9);
        let ds = blobs(&SyntheticSpec::new(30, 8, 2), &mut rng);
        for func in kernels() {
            let p = KernelPanel::new(&ds, func);
            for _ in 0..40 {
                let (i, j) = (rng.below(ds.n), rng.below(ds.n));
                let dot = fmath::dot_f64(ds.row(i), ds.row(j));
                let (ni, nj) = if matches!(
                    func,
                    KernelFunction::Polynomial { .. } | KernelFunction::Linear
                ) {
                    (0.0, 0.0)
                } else {
                    (ds.sq_norms()[i], ds.sq_norms()[j])
                };
                let fin = KernelPanel::finish(func, ni, nj, dot);
                match KernelPanel::exp_arg(func, ni, nj, dot) {
                    Some(a) => assert_eq!(a.exp().to_bits(), fin.to_bits(), "{func:?}"),
                    None => assert!(matches!(
                        func,
                        KernelFunction::Polynomial { .. } | KernelFunction::Linear
                    )),
                }
            }
        }
    }

    #[test]
    fn fast_mode_fills_respect_ulp_contract() {
        use crate::util::simd::{ulp_distance, EXP_ULP_BUDGET};
        let mut rng = Rng::seeded(57);
        for d in [1usize, 3, 7, 16] {
            let ds = blobs(&SyntheticSpec::new(50, d, 3), &mut rng);
            for func in kernels() {
                let det = KernelPanel::new(&ds, func);
                let fast = KernelPanel::new_with(&ds, func, NumericsMode::Fast);
                assert_eq!(fast.mode(), NumericsMode::Fast);
                let rows: Vec<usize> = (0..6).map(|_| rng.below(ds.n)).collect();
                let cols: Vec<usize> = (0..11).map(|_| rng.below(ds.n)).collect();
                let mut a = vec![f64::NAN; rows.len() * cols.len()];
                let mut b = vec![f64::NAN; rows.len() * cols.len()];
                det.fill_f64(&rows, &cols, &mut a);
                fast.fill_f64(&rows, &cols, &mut b);
                let exp_family = matches!(
                    func,
                    KernelFunction::Gaussian { .. } | KernelFunction::Laplacian { .. }
                );
                for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
                    if exp_family {
                        // Dots and exp arguments are bitwise equal across
                        // arms; only the exp itself may move.
                        let ud = ulp_distance(x, y).unwrap();
                        assert!(ud <= EXP_ULP_BUDGET, "{func:?} d={d} i={i}: {x} vs {y}");
                    } else {
                        // Dot-product kernels have no exp: Fast must be
                        // bit-identical on every arm.
                        assert_eq!(x.to_bits(), y.to_bits(), "{func:?} d={d} i={i}");
                    }
                }
            }
        }
    }
}
