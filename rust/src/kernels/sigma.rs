//! Bandwidth (κ) heuristic for the Gaussian kernel.
//!
//! The paper sets κ "using the heuristic of (Wang et al., 2019) followed by
//! some manual tuning": the mean squared pairwise distance over a uniform
//! sample of point pairs. We expose the sample size and a multiplier so the
//! "manual tuning" is a reproducible config knob.

use crate::data::Dataset;
use crate::util::rng::Rng;

/// Number of random pairs used to estimate the mean squared distance.
pub const DEFAULT_PAIR_SAMPLES: usize = 2000;

/// κ = mean ‖x−y‖² over sampled pairs (≥ tiny positive floor).
pub fn kappa_heuristic(ds: &Dataset, rng: &mut Rng) -> f64 {
    kappa_heuristic_with(ds, rng, DEFAULT_PAIR_SAMPLES, 1.0)
}

/// κ heuristic with explicit sample count and tuning multiplier.
pub fn kappa_heuristic_with(
    ds: &Dataset,
    rng: &mut Rng,
    pairs: usize,
    multiplier: f64,
) -> f64 {
    assert!(ds.n >= 2, "need at least 2 points");
    let mut total = 0.0f64;
    let mut count = 0usize;
    for _ in 0..pairs {
        let i = rng.below(ds.n);
        let mut j = rng.below(ds.n);
        if i == j {
            j = (j + 1) % ds.n;
        }
        total += ds.sqdist(i, j);
        count += 1;
    }
    let mean = total / count as f64;
    (mean * multiplier).max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};

    #[test]
    fn kappa_close_to_true_mean_sqdist() {
        let mut rng = Rng::seeded(1);
        let ds = blobs(&SyntheticSpec::new(300, 4, 3), &mut rng);
        // Exact mean over all pairs.
        let mut total = 0.0;
        let mut count = 0.0;
        for i in 0..ds.n {
            for j in 0..ds.n {
                if i != j {
                    total += ds.sqdist(i, j);
                    count += 1.0;
                }
            }
        }
        let exact = total / count;
        let mut rng2 = Rng::seeded(2);
        let est = kappa_heuristic_with(&ds, &mut rng2, 5000, 1.0);
        assert!((est - exact).abs() / exact < 0.15, "est={est} exact={exact}");
    }

    #[test]
    fn multiplier_scales() {
        let mut rng = Rng::seeded(3);
        let ds = blobs(&SyntheticSpec::new(100, 2, 2), &mut rng);
        let mut r1 = Rng::seeded(4);
        let mut r2 = Rng::seeded(4);
        let a = kappa_heuristic_with(&ds, &mut r1, 500, 1.0);
        let b = kappa_heuristic_with(&ds, &mut r2, 500, 2.0);
        assert!((b / a - 2.0).abs() < 1e-9);
    }

    #[test]
    fn positive_even_on_duplicate_points() {
        let ds = Dataset::new("dup", vec![1.0, 1.0, 1.0, 1.0], 2, 2);
        let mut rng = Rng::seeded(5);
        assert!(kappa_heuristic(&ds, &mut rng) > 0.0);
    }
}
