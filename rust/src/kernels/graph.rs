//! Graph kernels from the paper's Appendix C.
//!
//! * **k-nn kernel**: `K = D⁻¹ A D⁻¹`, where `A` is the symmetrized k-nearest-
//!   neighbour adjacency matrix (with self-loops so `K(x,x) > 0`) and `D` the
//!   degree matrix. Empirically γ ≪ 1, which *shrinks* the batch size
//!   Theorem 1 requires.
//! * **heat kernel** (Chung 1997): `K = exp(−t · D^{−1/2} A D^{−1/2})` for a
//!   temperature `0 < t < ∞`, computed with the Padé scaling-and-squaring
//!   [`crate::linalg::expm`].
//!
//! Both materialize a dense n×n [`Gram::Precomputed`]; the O(n²) construction
//! cost is reported separately in the figures (the paper's black "kernel
//! time" bars).

use super::Gram;
use crate::data::Dataset;
use crate::linalg::{expm, Matrix};
use crate::util::parallel::par_map_indexed;

/// Build the symmetrized k-nn adjacency (with self-loops) as a dense 0/1
/// matrix plus the degree vector. Brute-force neighbour search, parallel
/// over query points — O(n²·d), the same cost class as one gram pass.
pub fn knn_adjacency(ds: &Dataset, k_neighbors: usize) -> (Vec<f32>, Vec<f64>) {
    let n = ds.n;
    assert!(k_neighbors >= 1 && k_neighbors < n, "bad k_neighbors");
    // For each point, indices of its k nearest neighbours (excluding self).
    let neighbor_lists: Vec<Vec<usize>> = par_map_indexed(n, |i| {
        // Max-heap of (dist, idx) capped at k: O(n log k).
        let mut heap: std::collections::BinaryHeap<(ordered, usize)> =
            std::collections::BinaryHeap::with_capacity(k_neighbors + 1);
        for j in 0..n {
            if j == i {
                continue;
            }
            let d = ds.sqdist(i, j);
            heap.push((ordered::from(d), j));
            if heap.len() > k_neighbors {
                heap.pop();
            }
        }
        heap.into_iter().map(|(_, j)| j).collect()
    });
    let mut a = vec![0.0f32; n * n];
    for i in 0..n {
        a[i * n + i] = 1.0; // self-loop keeps K(x,x) > 0
        for &j in &neighbor_lists[i] {
            a[i * n + j] = 1.0;
            a[j * n + i] = 1.0; // symmetrize: i~j if either lists the other
        }
    }
    let degrees: Vec<f64> = (0..n)
        .map(|i| a[i * n..(i + 1) * n].iter().map(|&v| v as f64).sum())
        .collect();
    (a, degrees)
}

/// k-nn kernel `K = D⁻¹ A D⁻¹` as a precomputed gram.
pub fn knn_kernel(ds: &Dataset, k_neighbors: usize) -> Gram<'static> {
    let n = ds.n;
    let (a, degrees) = knn_adjacency(ds, k_neighbors);
    let mut data = a;
    for i in 0..n {
        for j in 0..n {
            data[i * n + j] = (data[i * n + j] as f64 / (degrees[i] * degrees[j])) as f32;
        }
    }
    Gram::precomputed(&format!("{}:knn{k_neighbors}", ds.name), n, data)
}

/// Heat kernel `K = exp(−t·L̃)`, `L̃ = I − D^{−1/2} A D^{−1/2}`, as a
/// precomputed gram.
///
/// The paper's Appendix C writes `exp(−t·D^{−1/2}AD^{−1/2})`, but that
/// matrix has eigenvalues up to `e^{+t}` (the normalized adjacency has
/// spectrum in [−1,1]), contradicting the γ ≪ 1 values the paper reports in
/// Table 1. Chung (1997) — the reference the paper cites — defines the heat
/// kernel on the normalized *Laplacian* `L̃ = I − N`, whose exponential has
/// spectrum in `[e^{−2t}, 1]`: symmetric positive definite, diagonal < 1,
/// and empirically γ ≪ 1 for moderate t, matching Table 1. We implement
/// Chung's definition; DESIGN.md §4 records the full discrepancy argument
/// and the integration test that pins the resulting γ ordering.
pub fn heat_kernel(ds: &Dataset, k_neighbors: usize, t: f64) -> Gram<'static> {
    assert!(t > 0.0, "heat kernel temperature must be positive");
    let n = ds.n;
    let (a, degrees) = knn_adjacency(ds, k_neighbors);
    // −t·L̃ = −t·I + t·N
    let mut nrm = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = a[i * n + j] as f64;
            if v != 0.0 {
                nrm.data[i * n + j] = t * v / (degrees[i].sqrt() * degrees[j].sqrt());
            }
        }
        nrm.data[i * n + i] -= t;
    }
    let e = expm(&nrm);
    let data: Vec<f32> = e.data.iter().map(|&v| v as f32).collect();
    Gram::precomputed(&format!("{}:heat{k_neighbors}@{t}", ds.name), n, data)
}

/// Ordered f64 wrapper so distances can live in a BinaryHeap.
#[derive(PartialEq, Copy, Clone)]
#[allow(non_camel_case_types)]
struct ordered(f64);

impl ordered {
    fn from(v: f64) -> Self {
        ordered(v)
    }
}

impl Eq for ordered {}

impl PartialOrd for ordered {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ordered {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, rings, SyntheticSpec};
    use crate::util::rng::Rng;

    fn fixture(n: usize) -> Dataset {
        let mut rng = Rng::seeded(21);
        blobs(&SyntheticSpec::new(n, 3, 3).with_separation(6.0), &mut rng)
    }

    #[test]
    fn adjacency_symmetric_with_self_loops() {
        let ds = fixture(60);
        let (a, deg) = knn_adjacency(&ds, 5);
        let n = ds.n;
        for i in 0..n {
            assert_eq!(a[i * n + i], 1.0);
            for j in 0..n {
                assert_eq!(a[i * n + j], a[j * n + i]);
            }
            // Degree ≥ k+1 (self + k out-neighbours), counts its row.
            assert!(deg[i] >= 6.0, "deg[{i}]={}", deg[i]);
            let row_sum: f64 = a[i * n..(i + 1) * n].iter().map(|&v| v as f64).sum();
            assert_eq!(row_sum, deg[i]);
        }
    }

    #[test]
    fn knn_neighbors_are_actually_nearest() {
        let ds = fixture(50);
        let (a, _) = knn_adjacency(&ds, 3);
        let n = ds.n;
        // For point 0, every non-neighbour j (in 0's own out-list sense)
        // must be no closer than the farthest of its 3 nearest. We verify the
        // weaker symmetric property: the 3 nearest of 0 are adjacent.
        let mut dists: Vec<(f64, usize)> =
            (1..n).map(|j| (ds.sqdist(0, j), j)).collect();
        dists.sort_by(|x, y| x.0.total_cmp(&y.0));
        for &(_, j) in dists.iter().take(3) {
            assert_eq!(a[j], 1.0, "nearest neighbour {j} not adjacent");
        }
    }

    #[test]
    fn knn_kernel_gamma_much_less_than_one() {
        let ds = fixture(80);
        let g = knn_kernel(&ds, 8);
        // K(x,x) = 1/deg² ⇒ γ = 1/min-degree ≤ 1/9.
        assert!(g.gamma() <= 1.0 / 9.0 + 1e-9, "gamma={}", g.gamma());
        assert!(g.gamma() > 0.0);
    }

    #[test]
    fn knn_kernel_symmetric() {
        let ds = fixture(40);
        let g = knn_kernel(&ds, 4);
        for i in 0..ds.n {
            for j in 0..ds.n {
                assert!((g.eval(i, j) - g.eval(j, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn heat_kernel_spd_diagonal_and_gamma() {
        let ds = fixture(50);
        let g = heat_kernel(&ds, 5, 3.0);
        // exp of symmetric matrix: diagonal strictly positive.
        for i in 0..ds.n {
            assert!(g.self_k(i) > 0.0);
        }
        // γ ≪ 1 for moderate t on a connected-ish graph (paper Table 1).
        assert!(g.gamma() < 1.0, "gamma={}", g.gamma());
    }

    #[test]
    fn heat_kernel_symmetric() {
        let ds = fixture(40);
        let g = heat_kernel(&ds, 4, 2.0);
        for i in (0..ds.n).step_by(3) {
            for j in (0..ds.n).step_by(5) {
                assert!((g.eval(i, j) - g.eval(j, i)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn knn_kernel_connects_ring_neighbors_not_far_rings() {
        // On concentric rings, knn edges stay within a ring, so kernel
        // affinity between points of different rings should be ~0.
        let mut rng = Rng::seeded(9);
        let ds = rings(150, 2, 2, 0.02, &mut rng);
        let labels = ds.labels.clone().unwrap();
        let g = knn_kernel(&ds, 4);
        let mut cross_max = 0.0f64;
        for i in 0..ds.n {
            for j in 0..ds.n {
                if labels[i] != labels[j] {
                    cross_max = cross_max.max(g.eval(i, j));
                }
            }
        }
        assert_eq!(cross_max, 0.0, "knn graph leaked across rings");
    }
}
