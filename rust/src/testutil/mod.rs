//! Test-only infrastructure: a small property-based testing harness
//! (proptest is unavailable in this offline build) and shared fixtures.

pub mod prop;
