//! Property-based testing harness (proptest replacement).
//!
//! Model: a [`Gen<T>`] produces random values from an [`Rng`]; [`check`]
//! runs a property over many generated cases and, on failure, greedily
//! shrinks the input via the generator's `shrink` candidates before
//! panicking with the minimal counterexample and the seed to reproduce it.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla_extension rpath on this image)
//! use mbkk::testutil::prop::{check, usize_in, vec_of};
//! check("reverse twice is identity", vec_of(usize_in(0..100), 0..20), |v| {
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     w == *v
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::Range;

/// Number of cases per property (override with MBKK_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("MBKK_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// A generator of random values with shrinking.
pub trait Gen<T> {
    /// Produce one random value.
    fn generate(&self, rng: &mut Rng) -> T;
    /// Candidate smaller values; the checker tries them in order.
    fn shrink(&self, value: &T) -> Vec<T> {
        let _ = value;
        Vec::new()
    }
}

/// Run `prop` over `default_cases()` generated inputs. Panics with the
/// (shrunk) counterexample on failure.
pub fn check<T: std::fmt::Debug + Clone>(
    name: &str,
    gen: impl Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    check_with_seed(name, gen, prop, 0xC0FFEE, default_cases());
}

/// [`check`] with explicit seed and case count, for reproducing failures.
pub fn check_with_seed<T: std::fmt::Debug + Clone>(
    name: &str,
    gen: impl Gen<T>,
    prop: impl Fn(&T) -> bool,
    seed: u64,
    cases: usize,
) {
    let mut rng = Rng::seeded(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(&gen, input, &prop);
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}).\n\
                 minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<T: Clone>(gen: &impl Gen<T>, mut failing: T, prop: &impl Fn(&T) -> bool) -> T {
    // Greedy descent, bounded to avoid pathological loops.
    for _ in 0..1000 {
        let mut improved = false;
        for cand in gen.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                improved = true;
                break;
            }
        }
        if !improved {
            break;
        }
    }
    failing
}

// ---- primitive generators --------------------------------------------------

/// Generator of `usize` values in a range (see [`usize_in`]).
pub struct UsizeIn(pub Range<usize>);

/// usize in [lo, hi).
pub fn usize_in(r: Range<usize>) -> UsizeIn {
    assert!(!r.is_empty());
    UsizeIn(r)
}

impl Gen<usize> for UsizeIn {
    fn generate(&self, rng: &mut Rng) -> usize {
        self.0.start + rng.below(self.0.end - self.0.start)
    }

    fn shrink(&self, value: &usize) -> Vec<usize> {
        let lo = self.0.start;
        let mut out = Vec::new();
        if *value > lo {
            out.push(lo);
            out.push(lo + (value - lo) / 2);
            out.push(value - 1);
        }
        out.dedup();
        out
    }
}

/// Generator of `f64` values in a range (see [`f64_in`]).
pub struct F64In(pub Range<f64>);

/// f64 uniform in [lo, hi).
pub fn f64_in(r: Range<f64>) -> F64In {
    assert!(r.start < r.end);
    F64In(r)
}

impl Gen<f64> for F64In {
    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.range_f64(self.0.start, self.0.end)
    }

    fn shrink(&self, value: &f64) -> Vec<f64> {
        let lo = self.0.start;
        if *value > lo + 1e-12 {
            vec![lo, lo + (value - lo) / 2.0]
        } else {
            vec![]
        }
    }
}

/// Vec of T with length drawn from `len`.
pub struct VecOf<G> {
    elem: G,
    len: Range<usize>,
}

/// Generator of vectors of `elem` with length drawn from `len`.
pub fn vec_of<G>(elem: G, len: Range<usize>) -> VecOf<G> {
    VecOf { elem, len }
}

impl<T: Clone, G: Gen<T>> Gen<Vec<T>> for VecOf<G> {
    fn generate(&self, rng: &mut Rng) -> Vec<T> {
        let span = self.len.end - self.len.start;
        let n = self.len.start + if span > 0 { rng.below(span) } else { 0 };
        (0..n).map(|_| self.elem.generate(rng)).collect()
    }

    fn shrink(&self, value: &Vec<T>) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        // Structural shrinks: drop halves, drop single elements.
        if value.len() > self.len.start {
            out.push(value[..value.len() / 2.max(self.len.start)].to_vec());
            if value.len() >= 1 {
                let mut v = value.clone();
                v.pop();
                if v.len() >= self.len.start {
                    out.push(v);
                }
            }
        }
        // Element-wise shrinks on the first shrinkable element.
        for (i, x) in value.iter().enumerate() {
            let cands = self.elem.shrink(x);
            if !cands.is_empty() {
                for c in cands.into_iter().take(2) {
                    let mut v = value.clone();
                    v[i] = c;
                    out.push(v);
                }
                break;
            }
        }
        out
    }
}

/// Pair of independent generators.
pub struct PairOf<G1, G2>(pub G1, pub G2);

/// Generator of pairs from two independent generators.
pub fn pair_of<G1, G2>(a: G1, b: G2) -> PairOf<G1, G2> {
    PairOf(a, b)
}

impl<A: Clone, B: Clone, G1: Gen<A>, G2: Gen<B>> Gen<(A, B)> for PairOf<G1, G2> {
    fn generate(&self, rng: &mut Rng) -> (A, B) {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, value: &(A, B)) -> Vec<(A, B)> {
        let mut out: Vec<(A, B)> = self
            .0
            .shrink(&value.0)
            .into_iter()
            .map(|a| (a, value.1.clone()))
            .collect();
        out.extend(self.1.shrink(&value.1).into_iter().map(|b| (value.0.clone(), b)));
        out
    }
}

/// Generator defined by a closure (no shrinking).
pub struct FromFn<F>(pub F);

/// Wrap a closure as a [`Gen`] (no shrinking).
pub fn from_fn<T, F: Fn(&mut Rng) -> T>(f: F) -> FromFn<F> {
    FromFn(f)
}

impl<T, F: Fn(&mut Rng) -> T> Gen<T> for FromFn<F> {
    fn generate(&self, rng: &mut Rng) -> T {
        (self.0)(rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", pair_of(usize_in(0..100), usize_in(0..100)), |(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            check("all < 50", usize_in(0..100), |&x| x < 50);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrink should land on exactly 50 (the smallest failure).
        assert!(msg.contains("counterexample: 50"), "msg: {msg}");
    }

    #[test]
    fn vec_generator_respects_length_bounds() {
        let gen = vec_of(usize_in(0..10), 2..5);
        let mut rng = Rng::seeded(1);
        for _ in 0..100 {
            let v = gen.generate(&mut rng);
            assert!((2..5).contains(&v.len()));
            assert!(v.iter().all(|&x| x < 10));
        }
    }

    #[test]
    fn vec_shrink_reduces_length() {
        let result = std::panic::catch_unwind(|| {
            check("vecs shorter than 3", vec_of(usize_in(0..5), 0..20), |v| v.len() < 3);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Minimal failing vec has length exactly 3.
        let needle = "counterexample: [";
        let idx = msg.find(needle).unwrap();
        let tail = &msg[idx + needle.len()..];
        let count = tail.split(']').next().unwrap().split(',').count();
        assert_eq!(count, 3, "msg: {msg}");
    }

    #[test]
    fn f64_generator_in_range() {
        let gen = f64_in(-1.0..2.0);
        let mut rng = Rng::seeded(2);
        for _ in 0..200 {
            let x = gen.generate(&mut rng);
            assert!((-1.0..2.0).contains(&x));
        }
    }
}
