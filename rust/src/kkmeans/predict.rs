//! Out-of-sample prediction and streaming clustering.
//!
//! The truncated representation makes kernel k-means *servable*: a fitted
//! model is just k sliding windows over ≤ τ+b support points each, so
//! assigning a new, unseen point costs O(k·(τ+b)) kernel evaluations —
//! no access to the training set beyond the support points.
//!
//! * [`KernelKMeansModel`] — a frozen model: support features + weights +
//!   ⟨Ĉ,Ĉ⟩ per center, detached from the training gram. `predict` works on
//!   arbitrary new feature vectors.
//! * [`StreamingKernelKMeans`] — the online variant the mini-batch setting
//!   enables: consume batches from an unbounded stream (no dataset in
//!   memory at all); each `partial_fit` is one Algorithm 2 iteration whose
//!   "batch" is whatever the stream delivered.
//!
//! Both ride the [`crate::kernels::KernelProvider`] abstraction: the
//! reservoir gram here is on-the-fly (the reservoir is tiny by
//! construction), while offline million-point fits go through the
//! streaming tile-LRU provider selected by the experiment coordinator's
//! n-threshold policy (DESIGN.md §6).

use super::learning_rate::{LearningRate, RateState};
use super::state::CenterWindow;
use crate::data::Dataset;
use crate::kernels::{Gram, KernelFunction};

/// A frozen, servable kernel k-means model (feature kernels only — the
/// support points are materialized as raw feature vectors).
#[derive(Clone, Debug)]
pub struct KernelKMeansModel {
    /// The feature kernel the model was trained with.
    pub kernel: KernelFunction,
    /// Feature dimension.
    pub d: usize,
    /// Per center: support feature rows (flattened s×d), coefficients,
    /// and cached squared norms `‖s‖²` (one per support row) for the
    /// panel-style distance expansion in [`KernelKMeansModel::distances`].
    /// `pub(crate)` for the `serve` layer (artifact format + batch engine).
    pub(crate) centers: Vec<(Vec<f32>, Vec<f64>, Vec<f64>)>,
    /// ⟨Ĉ_j, Ĉ_j⟩ per center.
    pub(crate) cc: Vec<f64>,
}

impl KernelKMeansModel {
    /// Freeze fitted windows into a servable model.
    pub fn freeze(
        ds: &Dataset,
        kernel: KernelFunction,
        windows: &mut [CenterWindow],
    ) -> KernelKMeansModel {
        let gram = Gram::on_the_fly(ds, kernel);
        let centers = windows
            .iter()
            .map(|w| {
                let mut feats = Vec::new();
                let mut coefs = Vec::new();
                let mut norms = Vec::new();
                for (y, c) in w.support() {
                    feats.extend_from_slice(ds.row(y));
                    coefs.push(c);
                    // Per-row O(d) — bit-identical to Dataset::sq_norms
                    // without forcing the full-store cache build (which
                    // dot-product kernels would never read).
                    norms.push(crate::util::fmath::sq_norm_f64(ds.row(y)));
                }
                (feats, coefs, norms)
            })
            .collect();
        let cc = windows.iter_mut().map(|w| w.self_inner(&gram)).collect();
        KernelKMeansModel { kernel, d: ds.d, centers, cc }
    }

    /// Number of centers.
    pub fn k(&self) -> usize {
        self.centers.len()
    }

    /// Squared feature-space distances of one new point to every center.
    ///
    /// The query norm `‖x‖²` is computed once and each support norm comes
    /// from the freeze-time cache, so every kernel value costs a single
    /// inner product — bit-identical to `KernelFunction::eval` (the panel
    /// arithmetic, `KernelPanel::finish` over the same sequential dot).
    pub fn distances(&self, x: &[f32]) -> Vec<f64> {
        assert_eq!(x.len(), self.d, "feature dimension mismatch");
        let kxx = self.kernel.eval_self(x);
        let nx = crate::util::fmath::sq_norm_f64(x);
        self.centers
            .iter()
            .zip(self.cc.iter())
            .map(|((feats, coefs, norms), &cc)| {
                let mut cross = 0.0;
                for ((s, &c), &ns) in
                    feats.chunks_exact(self.d).zip(coefs.iter()).zip(norms.iter())
                {
                    let dot = crate::util::fmath::dot_f64(x, s);
                    cross += c * crate::kernels::KernelPanel::finish(self.kernel, nx, ns, dot);
                }
                (kxx - 2.0 * cross + cc).max(0.0)
            })
            .collect()
    }

    /// Hard assignment of one new point.
    pub fn predict(&self, x: &[f32]) -> usize {
        let dist = self.distances(x);
        dist.iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(j, _)| j)
            .unwrap()
    }

    /// Batch prediction.
    pub fn predict_all(&self, ds: &Dataset) -> Vec<usize> {
        assert_eq!(ds.d, self.d);
        crate::util::parallel::par_map_indexed(ds.n, |i| self.predict(ds.row(i)))
    }

    /// Total support size (model footprint in points).
    pub fn support_points(&self) -> usize {
        self.centers.iter().map(|(_, c, _)| c.len()).sum()
    }

    // ---- persistence (serve::format, DESIGN.md §8) -------------------------

    /// Serialize into the versioned serving artifact (kind `model`).
    pub fn to_bytes(&self) -> Vec<u8> {
        crate::serve::format::model_to_bytes(self)
    }

    /// Parse an artifact produced by [`KernelKMeansModel::to_bytes`] /
    /// [`KernelKMeansModel::save`]. Validates magic, format version, kernel
    /// parameters, and exact payload shape; malformed input is an error,
    /// never a panic.
    pub fn from_bytes(bytes: &[u8]) -> crate::util::error::Result<KernelKMeansModel> {
        crate::serve::format::model_from_bytes(bytes)
    }

    /// Write the versioned model artifact to `path`.
    pub fn save(&self, path: &std::path::Path) -> crate::util::error::Result<()> {
        crate::serve::format::save_model(self, path)
    }

    /// Load a model artifact from `path` (see [`KernelKMeansModel::from_bytes`]).
    pub fn load(path: &std::path::Path) -> crate::util::error::Result<KernelKMeansModel> {
        crate::serve::format::load_model(path)
    }
}

/// Online truncated mini-batch kernel k-means over an unbounded stream.
///
/// Feed feature batches with [`StreamingKernelKMeans::partial_fit`]; the
/// model keeps only the support windows (O(k·(τ+b)) points), never the
/// stream. Internally the stream is buffered into a bounded reservoir
/// dataset holding exactly the live support + current batch.
pub struct StreamingKernelKMeans {
    pub(crate) kernel: KernelFunction,
    pub(crate) k: usize,
    pub(crate) tau: usize,
    pub(crate) batch_size: usize,
    pub(crate) rate: RateState,
    /// Reservoir of feature rows referenced by windows (compacted
    /// periodically); windows index into it.
    pub(crate) store: Dataset,
    pub(crate) windows: Option<Vec<CenterWindow>>,
    /// Batches consumed.
    pub iterations: usize,
}

impl StreamingKernelKMeans {
    /// Fresh streaming clusterer for `d`-dimensional rows.
    pub fn new(
        kernel: KernelFunction,
        d: usize,
        k: usize,
        batch_size: usize,
        tau: usize,
        lr: LearningRate,
    ) -> StreamingKernelKMeans {
        StreamingKernelKMeans {
            kernel,
            k,
            tau,
            batch_size,
            rate: RateState::new(lr, k),
            store: Dataset::new("stream", Vec::new(), 0, d),
            windows: None,
            iterations: 0,
        }
    }

    fn append_rows(&mut self, rows: &[f32]) -> Vec<usize> {
        let d = self.store.d;
        assert_eq!(rows.len() % d, 0, "ragged batch");
        let n0 = self.store.n;
        self.store.features.extend_from_slice(rows);
        self.store.n += rows.len() / d;
        // The store grew in place: drop the cached row norms so the panel
        // engine rebuilds them at the new length.
        self.store.invalidate_caches();
        (n0..self.store.n).collect()
    }

    /// Drop store rows no longer referenced by any window (keeps the
    /// memory footprint bounded by O(k·(τ+b)) regardless of stream length).
    fn compact(&mut self) {
        let Some(windows) = &self.windows else { return };
        let d = self.store.d;
        // Collect referenced indices (sorted, deduped).
        let mut referenced: Vec<usize> = windows
            .iter()
            .flat_map(|w| w.support().map(|(y, _)| y))
            .collect();
        referenced.sort_unstable();
        referenced.dedup();
        if referenced.len() * 4 > self.store.n * 3 {
            return; // not worth compacting yet
        }
        let mut remap = std::collections::HashMap::with_capacity(referenced.len());
        let mut features = Vec::with_capacity(referenced.len() * d);
        for (new_idx, &old_idx) in referenced.iter().enumerate() {
            remap.insert(old_idx, new_idx);
            features.extend_from_slice(self.store.row(old_idx));
        }
        let store = Dataset::new("stream", features, referenced.len(), d);
        // Rebuild windows against the new indexing.
        let rebuilt = windows
            .iter()
            .map(|w| w.remap_indices(&remap, self.tau))
            .collect();
        self.store = store;
        self.windows = Some(rebuilt);
    }

    /// Consume one batch of rows (row-major, length multiple of d). The
    /// first batches are used for initialization (k distinct-ish seeds);
    /// afterwards each call is one Algorithm 2 iteration.
    pub fn partial_fit(&mut self, rows: &[f32], rng: &mut crate::util::rng::Rng) {
        let ids = self.append_rows(rows);
        if ids.is_empty() {
            return;
        }
        if self.windows.is_none() {
            // Initialize from the first batch: kernel k-means++ over it.
            let gram = Gram::on_the_fly(&self.store, self.kernel);
            let k = self.k.min(ids.len());
            let seeds = super::init::choose_centers(
                &gram,
                k,
                super::Init::KMeansPlusPlusOnSample(ids.len()),
                rng,
            );
            self.windows =
                Some(seeds.iter().map(|&s| CenterWindow::new(s, self.tau)).collect());
            if ids.len() <= self.k {
                return;
            }
        }
        let gram = Gram::on_the_fly(&self.store, self.kernel);
        let mut windows = self.windows.take().unwrap();
        // Assign the batch.
        let mut backend = super::backend::NativeBackend;
        let dist = {
            use super::backend::AssignBackend;
            backend.distances(&gram, &ids, &mut windows)
        };
        let (assign, _) = super::backend::argmin_rows(&dist, windows.len());
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); windows.len()];
        for (r, &j) in assign.iter().enumerate() {
            members[j].push(ids[r]);
        }
        let b = self.batch_size.max(ids.len());
        for (j, w) in windows.iter_mut().enumerate() {
            let alpha = self.rate.alpha(j, members[j].len(), b);
            if alpha > 0.0 {
                w.apply_update_cc(alpha, &members[j], None, &gram);
            }
        }
        self.windows = Some(windows);
        self.iterations += 1;
        if self.store.n > 4 * self.k * (self.tau + self.batch_size) {
            self.compact();
        }
    }

    /// Freeze into a servable model (panics before the first batch).
    pub fn to_model(&mut self) -> KernelKMeansModel {
        let windows = self.windows.as_mut().expect("no data consumed yet");
        KernelKMeansModel::freeze(&self.store, self.kernel, windows)
    }

    /// Current bounded memory footprint in stored rows.
    pub fn stored_rows(&self) -> usize {
        self.store.n
    }

    // ---- checkpointing (serve::format, DESIGN.md §8) -----------------------
    //
    // Snapshot/resume go through the same versioned artifact format as
    // frozen models (kind `stream`): the reservoir, every window's raw
    // entry structure, the learning-rate counters, and the iteration count
    // are captured exactly, so `resume` + further `partial_fit` calls are
    // bit-for-bit the uninterrupted run (the caller keeps the RNG stream —
    // `partial_fit` only draws from it before the first batch).

    /// Serialize the full streaming state into a checkpoint artifact.
    pub fn snapshot_bytes(&self) -> Vec<u8> {
        crate::serve::format::stream_to_bytes(self)
    }

    /// Restore a stream from [`StreamingKernelKMeans::snapshot_bytes`]
    /// output. Malformed input is an error, never a panic.
    pub fn resume_bytes(bytes: &[u8]) -> crate::util::error::Result<StreamingKernelKMeans> {
        crate::serve::format::stream_from_bytes(bytes)
    }

    /// Write a checkpoint artifact to `path`.
    pub fn snapshot(&self, path: &std::path::Path) -> crate::util::error::Result<()> {
        crate::serve::format::save_stream(self, path)
    }

    /// Resume from a checkpoint written by [`StreamingKernelKMeans::snapshot`].
    pub fn resume(path: &std::path::Path) -> crate::util::error::Result<StreamingKernelKMeans> {
        crate::serve::format::load_stream(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::kkmeans::{TruncatedConfig, TruncatedMiniBatchKernelKMeans};
    use crate::metrics::ari;
    use crate::util::rng::Rng;

    fn fixture(n: usize) -> Dataset {
        let mut rng = Rng::seeded(8);
        blobs(
            &SyntheticSpec::new(n, 6, 3).with_std(0.4).with_separation(7.0),
            &mut rng,
        )
    }

    #[test]
    fn frozen_model_agrees_with_training_assignments() {
        let ds = fixture(600);
        let kernel = KernelFunction::Gaussian { kappa: 12.0 };
        let gram = Gram::on_the_fly(&ds, kernel);
        let cfg = TruncatedConfig { k: 3, batch_size: 128, tau: 100, max_iters: 40, ..Default::default() };
        let mut rng = Rng::seeded(1);
        let mut fit = TruncatedMiniBatchKernelKMeans::new(cfg)
            .fit_with_backend(&gram, &mut super::super::backend::NativeBackend, &mut rng);
        let model = KernelKMeansModel::freeze(&ds, kernel, &mut fit.centers);
        assert_eq!(model.k(), 3);
        let same = (0..ds.n)
            .filter(|&i| model.predict(ds.row(i)) == fit.result.assignments[i])
            .count();
        assert_eq!(same, ds.n, "frozen model must replicate training assignments");
    }

    #[test]
    fn predicts_held_out_points() {
        let train = fixture(600);
        let test = fixture(300); // same generator/seed family ⇒ same blobs
        let kernel = KernelFunction::Gaussian { kappa: 12.0 };
        let gram = Gram::on_the_fly(&train, kernel);
        let cfg = TruncatedConfig { k: 3, batch_size: 128, tau: 100, max_iters: 40, ..Default::default() };
        let mut rng = Rng::seeded(2);
        let mut fit = TruncatedMiniBatchKernelKMeans::new(cfg)
            .fit_with_backend(&gram, &mut super::super::backend::NativeBackend, &mut rng);
        let model = KernelKMeansModel::freeze(&train, kernel, &mut fit.centers);
        let pred = model.predict_all(&test);
        let score = ari(test.labels.as_ref().unwrap(), &pred);
        assert!(score > 0.9, "held-out ARI={score}");
        assert!(model.support_points() <= 3 * (100 + 128 + 1));
    }

    #[test]
    fn streaming_clusters_an_unbounded_stream_with_bounded_memory() {
        let ds = fixture(4000);
        let kernel = KernelFunction::Gaussian { kappa: 12.0 };
        let mut stream = StreamingKernelKMeans::new(
            kernel,
            ds.d,
            3,
            128,
            60,
            LearningRate::Beta,
        );
        let mut rng = Rng::seeded(3);
        // Feed 60 batches of 128 rows sampled from the generator.
        for _ in 0..60 {
            let idx = rng.sample_with_replacement(ds.n, 128);
            let mut rows = Vec::with_capacity(128 * ds.d);
            for &i in &idx {
                rows.extend_from_slice(ds.row(i));
            }
            stream.partial_fit(&rows, &mut rng);
        }
        assert_eq!(stream.iterations, 60);
        // Memory bounded: far less than the 60·128 rows consumed.
        assert!(
            stream.stored_rows() < 4 * 3 * (60 + 128),
            "stored {} rows",
            stream.stored_rows()
        );
        let model = stream.to_model();
        let pred = model.predict_all(&ds);
        let score = ari(ds.labels.as_ref().unwrap(), &pred);
        assert!(score > 0.9, "streaming ARI={score}");
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn predict_checks_dimension() {
        let ds = fixture(100);
        let kernel = KernelFunction::Gaussian { kappa: 4.0 };
        let mut windows = vec![CenterWindow::new(0, 10)];
        let model = KernelKMeansModel::freeze(&ds, kernel, &mut windows);
        let _ = model.predict(&[0.0; 3]);
    }
}
