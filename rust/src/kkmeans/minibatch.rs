//! **Algorithm 1** — mini-batch kernel k-means with the recursive distance
//! update rule (paper §4), served by lazy generation-stamped state.
//!
//! The centers are never materialized. The algorithm maintains, by dynamic
//! programming across iterations,
//!
//! * `px[x][j] = ⟨φ(x), C_j⟩` — updated via
//!   `⟨φ(x), C'_j⟩ = (1−α)⟨φ(x), C_j⟩ + α⟨φ(x), cm(B^j)⟩`, and
//! * `cc[j] = ⟨C_j, C_j⟩` — updated via the expanded square.
//!
//! Earlier revisions applied the `px` recursion *eagerly* to every dataset
//! point each iteration — an `O(n(b+k))` sweep that kept iteration time
//! linear in `n`. The sweep is gone: `px` now lives in a
//! [`LazyAssignState`], which stamps every point with the generation (log
//! length) it was last refreshed at and replays only the updates appended
//! since, on demand. An iteration touches exactly the `b` sampled points
//! and costs `O(kb + b·Δ)` kernel evaluations, where `Δ` is the support
//! appended since those points' last refresh — `Õ(kb²)` in the paper's
//! regime, with `n` appearing nowhere in the loop. `n` is visited exactly
//! twice: optionally at init (k-means++ seeding) and once in the finalize
//! pass, which replays the whole log against every point as one blocked
//! engine-served sweep with the argmin fused in (DESIGN.md §9). The lazy
//! replay performs the same recursion steps, in the same order, over the
//! same kernel values as the removed eager sweep, so results are
//! bit-identical to it — pinned by `rust/tests/prop_lazy_eager.rs`.

use super::backend::argmin_rows_into;
use super::init::choose_centers;
use super::learning_rate::{LearningRate, RateState};
use super::schedule::ScheduleSpec;
use super::state::LazyAssignState;
use super::termination::{EpsilonStopper, TerminationMode};
use super::{FitResult, Init};
use crate::kernels::KernelProvider;
use crate::util::rng::Rng;
use crate::util::timing::{Profiler, Stopwatch};

/// Configuration for [`MiniBatchKernelKMeans`] (Algorithm 1).
#[derive(Clone, Debug)]
pub struct MiniBatchConfig {
    /// Number of clusters.
    pub k: usize,
    /// Batch size `b` (sampled uniformly with repetitions). Under a
    /// nested schedule this is the starting size `b₀`.
    pub batch_size: usize,
    /// Batch schedule: fixed-b (the paper's protocol) or nested geometric
    /// growth with deterministic sample reuse.
    pub schedule: ScheduleSpec,
    /// Iteration budget.
    pub max_iters: usize,
    /// Early-stopping threshold ε on batch improvement
    /// `f_{B_i}(C_i) − f_{B_i}(C_{i+1})`; `None` runs `max_iters` fixed
    /// iterations (the paper's experimental protocol).
    pub epsilon: Option<f64>,
    /// How ε is interpreted (windowed confidence estimator by default;
    /// [`TerminationMode::SingleBatch`] for the legacy one-batch rule).
    pub termination: TerminationMode,
    /// Learning-rate schedule for the center updates.
    pub learning_rate: LearningRate,
    /// Center initialization method.
    pub init: Init,
    /// Optional per-point weights (weighted variant, footnote 1).
    pub weights: Option<Vec<f64>>,
}

impl Default for MiniBatchConfig {
    fn default() -> Self {
        MiniBatchConfig {
            k: 2,
            batch_size: 1024,
            schedule: ScheduleSpec::Fixed,
            max_iters: 200,
            epsilon: None,
            termination: TerminationMode::default(),
            learning_rate: LearningRate::Beta,
            init: Init::default(),
            weights: None,
        }
    }
}

/// Algorithm 1 runner.
pub struct MiniBatchKernelKMeans {
    cfg: MiniBatchConfig,
}

impl MiniBatchKernelKMeans {
    /// Wrap a configuration.
    pub fn new(cfg: MiniBatchConfig) -> Self {
        MiniBatchKernelKMeans { cfg }
    }

    /// Run Algorithm 1 over the gram.
    pub fn fit(&self, gram: &dyn KernelProvider, rng: &mut Rng) -> FitResult {
        let n = gram.n();
        let k = self.cfg.k;
        assert!(k >= 1 && k <= n);
        let mut prof = Profiler::new();
        let weights = self.cfg.weights.as_deref();
        let mut schedule = self.cfg.schedule.build(self.cfg.batch_size);
        let b_max = schedule.max_batch(n);
        let mut stopper = self
            .cfg
            .epsilon
            .map(|eps| EpsilonStopper::new(eps, self.cfg.termination));

        // ---- init: seeds only — the old O(n·k) px table build is gone; a
        // point's initial row K(x, seed_j) materializes on first refresh.
        let sw = Stopwatch::start();
        let seeds = choose_centers(gram, k, self.cfg.init, rng);
        let mut state = LazyAssignState::new(n, &seeds);
        let mut cc: Vec<f64> = seeds.iter().map(|&s| gram.self_k(s)).collect();
        prof.add("init", sw.secs());

        let mut rate = RateState::new(self.cfg.learning_rate, k);
        let mut history = Vec::new();
        let mut iterations = 0;
        let mut converged = false;

        // Buffers hoisted out of the iteration loop (§Perf): beyond the
        // update log's append-only growth, the loop performs no
        // per-iteration allocations.
        let mut batch: Vec<usize> = Vec::with_capacity(b_max);
        let mut batch_dist: Vec<f64> = Vec::with_capacity(b_max * k);
        let mut assign: Vec<usize> = Vec::with_capacity(b_max);
        let mut mins: Vec<f64> = Vec::with_capacity(b_max);
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
        let mut alphas = vec![0.0f64; k];
        let mut mass = vec![0.0f64; k];
        let mut c_dot_cm = vec![0.0f64; k];
        let mut cm_dot_cm = vec![0.0f64; k];

        for iter in 0..self.cfg.max_iters {
            iterations += 1;
            // ---- sample + refresh: touch ONLY the b sampled points ----------
            // The refresh replays each sampled point's pending log suffix —
            // the work the eager sweep used to do for all n points, deferred
            // to the moment (and the points) the iteration actually needs.
            // Under a nested schedule, carried points were refreshed last
            // iteration, so their suffix is a single iteration of entries.
            let sw = Stopwatch::start();
            schedule.next_batch(iter, n, rng, &mut batch);
            let b = batch.len();
            state.refresh(gram, &batch, weights);
            prof.add("refresh", sw.secs());
            batch_dist.resize(b * k, 0.0);

            // ---- assign the batch under the current centers -----------------
            let sw = Stopwatch::start();
            for (r, &x) in batch.iter().enumerate() {
                let kxx = gram.self_k(x);
                let row = state.px_row(x);
                for (j, (&pxj, &ccj)) in row.iter().zip(cc.iter()).enumerate() {
                    batch_dist[r * k + j] = (kxx - 2.0 * pxj + ccj).max(0.0);
                }
            }
            argmin_rows_into(&batch_dist, k, &mut assign, &mut mins);
            let f_before = super::objective::weighted_mean(&batch, &mins, weights);
            history.push(f_before);
            prof.add("assign", sw.secs());

            // ---- per-cluster members, rates & O(b²) batch moments -----------
            let sw = Stopwatch::start();
            for m in members.iter_mut() {
                m.clear();
            }
            for (r, &j) in assign.iter().enumerate() {
                members[j].push(batch[r]);
            }
            for j in 0..k {
                alphas[j] = rate.alpha(j, members[j].len(), b);
            }
            // Weighted masses of each batch cluster (for weighted cm).
            for (j, m) in members.iter().enumerate() {
                mass[j] = match weights {
                    None => m.len() as f64,
                    Some(w) => m.iter().map(|&x| w[x]).sum(),
                };
            }
            // ⟨C_j, cm(B^j)⟩ from the refreshed (pre-update) px — O(b).
            for j in 0..k {
                c_dot_cm[j] = if members[j].is_empty() {
                    0.0
                } else {
                    let mut s = 0.0;
                    for &y in &members[j] {
                        let wy = weights.map(|w| w[y]).unwrap_or(1.0);
                        s += wy * state.px_row(y)[j];
                    }
                    s / mass[j]
                };
            }
            // ⟨cm(B^j), cm(B^j)⟩ — O(Σ b_j²) ≤ O(b²).
            for j in 0..k {
                cm_dot_cm[j] = if members[j].is_empty() {
                    0.0
                } else {
                    let pts = &members[j];
                    let mut s = 0.0;
                    for (a, &y) in pts.iter().enumerate() {
                        let wy = weights.map(|w| w[y]).unwrap_or(1.0);
                        s += wy * wy * gram.self_k(y);
                        for &z in pts.iter().skip(a + 1) {
                            let wz = weights.map(|w| w[z]).unwrap_or(1.0);
                            s += 2.0 * wy * wz * gram.eval(y, z);
                        }
                    }
                    s / (mass[j] * mass[j])
                };
            }
            prof.add("moments", sw.secs());

            // ---- cc recursion + log append (O(kb) — n appears nowhere) ------
            // cc needs only the O(b) moments above; px is *not* swept —
            // each center's update is appended to the replay log, to be
            // applied to a point's row the next time that point is touched.
            let sw = Stopwatch::start();
            for j in 0..k {
                let a = alphas[j];
                if a == 0.0 {
                    continue;
                }
                cc[j] = (1.0 - a) * (1.0 - a) * cc[j]
                    + 2.0 * a * (1.0 - a) * c_dot_cm[j]
                    + a * a * cm_dot_cm[j];
                state.append_update(j, a, mass[j], &members[j]);
            }
            prof.add("update", sw.secs());

            // ---- early stopping on the same batch ---------------------------
            if let Some(stopper) = stopper.as_mut() {
                let sw = Stopwatch::start();
                // Replay just this iteration's entries onto the batch and
                // re-score it under the updated centers — O(b·Σb_j), still
                // independent of n.
                state.refresh(gram, &batch, weights);
                for (r, &x) in batch.iter().enumerate() {
                    let kxx = gram.self_k(x);
                    let row = state.px_row(x);
                    let mut bestv = f64::INFINITY;
                    for (&pxj, &ccj) in row.iter().zip(cc.iter()) {
                        let d = (kxx - 2.0 * pxj + ccj).max(0.0);
                        if d < bestv {
                            bestv = d;
                        }
                    }
                    mins[r] = bestv;
                }
                let f_after = super::objective::weighted_mean(&batch, &mins, weights);
                prof.add("stopping", sw.secs());
                if stopper.observe(iter, f_before - f_after) {
                    converged = true;
                    break;
                }
            }
        }

        // ---- finalize: the single full-dataset pass -------------------------
        // Every point replays its pending log suffix (most points: the whole
        // log, as one blocked engine-served gather) and gets its assignment
        // in the same fused visit — the only place n re-enters after init.
        let sw = Stopwatch::start();
        let (assignments, mins_all) = state.finalize(gram, &cc, weights);
        let objective = super::objective::weighted_mean_all(&mins_all, weights);
        prof.add("finalize", sw.secs());

        FitResult {
            assignments,
            objective,
            history,
            iterations,
            converged,
            decisions: stopper.map(EpsilonStopper::into_decisions).unwrap_or_default(),
            profiler: prof,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{blobs, SyntheticSpec};
    use crate::kernels::{Gram, KernelFunction};
    use crate::metrics::ari;

    fn fixture(n: usize) -> crate::data::Dataset {
        let mut rng = Rng::seeded(7);
        blobs(
            &SyntheticSpec::new(n, 4, 3).with_std(0.4).with_separation(7.0),
            &mut rng,
        )
    }

    #[test]
    fn recovers_blobs_with_beta_rate() {
        let ds = fixture(600);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let cfg = MiniBatchConfig { k: 3, batch_size: 128, max_iters: 60, ..Default::default() };
        let mut rng = Rng::seeded(1);
        let res = MiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        let score = ari(ds.labels.as_ref().unwrap(), &res.assignments);
        assert!(score > 0.9, "ARI={score}");
    }

    #[test]
    fn recovers_blobs_with_sklearn_rate() {
        let ds = fixture(600);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let cfg = MiniBatchConfig {
            k: 3,
            batch_size: 128,
            max_iters: 60,
            learning_rate: LearningRate::Sklearn,
            ..Default::default()
        };
        let mut rng = Rng::seeded(2);
        let res = MiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        let score = ari(ds.labels.as_ref().unwrap(), &res.assignments);
        assert!(score > 0.9, "ARI={score}");
    }

    #[test]
    fn early_stopping_fires_on_converged_data() {
        let ds = fixture(400);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let cfg = MiniBatchConfig {
            k: 3,
            batch_size: 200,
            max_iters: 200,
            epsilon: Some(1e-3),
            ..Default::default()
        };
        let mut rng = Rng::seeded(3);
        let res = MiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        assert!(res.converged, "should stop early; ran {}", res.iterations);
        assert!(res.iterations < 200);
    }

    #[test]
    fn nested_schedule_recovers_blobs() {
        let ds = fixture(600);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let cfg = MiniBatchConfig {
            k: 3,
            batch_size: 32,
            schedule: crate::kkmeans::ScheduleSpec::Nested { growth: 2.0 },
            max_iters: 40,
            ..Default::default()
        };
        let mut rng = Rng::seeded(8);
        let res = MiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        let score = ari(ds.labels.as_ref().unwrap(), &res.assignments);
        assert!(score > 0.9, "ARI={score}");
    }

    #[test]
    fn epsilon_run_records_one_decision_per_iteration() {
        let ds = fixture(400);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 20.0 });
        let cfg = MiniBatchConfig {
            k: 3,
            batch_size: 200,
            max_iters: 200,
            epsilon: Some(1e-3),
            ..Default::default()
        };
        let mut rng = Rng::seeded(3);
        let res = MiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        assert_eq!(res.decisions.len(), res.iterations);
        assert_eq!(res.decisions.last().unwrap().stop, res.converged);
        assert!(res.decisions.iter().take(res.iterations - 1).all(|d| !d.stop));
        assert!(!res.decisions[0].stop, "the rule must never fire on iteration 0");
    }

    #[test]
    fn no_epsilon_means_no_decisions() {
        let ds = fixture(200);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 10.0 });
        let cfg = MiniBatchConfig { k: 3, batch_size: 64, max_iters: 5, ..Default::default() };
        let mut rng = Rng::seeded(4);
        let res = MiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        assert!(res.decisions.is_empty());
    }

    #[test]
    fn px_cc_invariants_vs_bruteforce_window() {
        // Cross-check Algorithm 1's DP tables against an explicit
        // CenterWindow fed the same update stream.
        use crate::kkmeans::state::CenterWindow;
        let ds = fixture(120);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 10.0 });
        let n = ds.n;
        let k = 2;
        let b = 16;
        let seeds = [3usize, 77];
        let mut px = vec![0.0f64; n * k];
        for x in 0..n {
            for (j, &s) in seeds.iter().enumerate() {
                px[x * k + j] = gram.eval(x, s);
            }
        }
        let mut cc: Vec<f64> = seeds.iter().map(|&s| gram.self_k(s)).collect();
        let mut windows: Vec<CenterWindow> =
            seeds.iter().map(|&s| CenterWindow::new(s, usize::MAX)).collect();
        let mut rng = Rng::seeded(5);
        for _ in 0..10 {
            let batch = rng.sample_with_replacement(n, b);
            // Assign by px/cc.
            let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
            for &x in &batch {
                let mut best = 0;
                let mut bestv = f64::INFINITY;
                for j in 0..k {
                    let d = gram.self_k(x) - 2.0 * px[x * k + j] + cc[j];
                    if d < bestv {
                        best = j;
                        bestv = d;
                    }
                }
                members[best].push(x);
            }
            for j in 0..k {
                let bj = members[j].len();
                if bj == 0 {
                    continue;
                }
                let a = (bj as f64 / b as f64).sqrt();
                // DP update.
                let mut c_dot_cm = 0.0;
                for &y in &members[j] {
                    c_dot_cm += px[y * k + j];
                }
                c_dot_cm /= bj as f64;
                let mut cm2 = 0.0;
                for &y in &members[j] {
                    for &z in &members[j] {
                        cm2 += gram.eval(y, z);
                    }
                }
                cm2 /= (bj * bj) as f64;
                for x in 0..n {
                    let mut cross = 0.0;
                    for &y in &members[j] {
                        cross += gram.eval(x, y);
                    }
                    px[x * k + j] = (1.0 - a) * px[x * k + j] + a * cross / bj as f64;
                }
                cc[j] = (1.0 - a) * (1.0 - a) * cc[j]
                    + 2.0 * a * (1.0 - a) * c_dot_cm
                    + a * a * cm2;
                windows[j].apply_update(a, &members[j], None);
            }
        }
        // Compare against the explicit representation.
        for j in 0..k {
            let cc_win = windows[j].self_inner(&gram);
            assert!((cc[j] - cc_win).abs() < 1e-8, "cc[{j}]: {} vs {cc_win}", cc[j]);
            for x in (0..n).step_by(13) {
                let px_win = windows[j].cross_with_point(&gram, x);
                assert!(
                    (px[x * k + j] - px_win).abs() < 1e-8,
                    "px[{x},{j}]: {} vs {px_win}",
                    px[x * k + j]
                );
            }
        }
    }

    #[test]
    fn history_has_one_entry_per_iteration() {
        let ds = fixture(200);
        let gram = Gram::on_the_fly(&ds, KernelFunction::Gaussian { kappa: 10.0 });
        let cfg = MiniBatchConfig { k: 3, batch_size: 64, max_iters: 17, ..Default::default() };
        let mut rng = Rng::seeded(6);
        let res = MiniBatchKernelKMeans::new(cfg).fit(&gram, &mut rng);
        assert_eq!(res.iterations, 17);
        assert_eq!(res.history.len(), 17);
        assert!(!res.converged);
    }
}
